"""`repro.reliability` — the unified protection API (DESIGN.md §12).

Two layers:

  backend.py — ONE registry for every dispatchable op (diag_parity,
               inject_scrub, tmr_vote, netlist_exec, crossbar_nor), with a
               per-call ``impl=`` override and the ``REPRO_IMPL`` env var.
               Subsumes the old ``ReliableStore(backend=...)``, the legacy
               netlist-engine env var and per-module interpret plumbing.
  scheme.py  — the composable `Scheme` protocol (`Unprotected`, the
               `ArenaEcc` code zoo — `DiagParityEcc`, `HsiaoSecDed` —
               `Tmr` in all three paper disciplines, `Compose`) over
               `Protected` pytree stores, plus the spec-token registry
               every CLI surface enumerates from.

Consumers: `runtime.loop.LoopConfig.scheme`, `launch.serve --scheme`,
`faults.campaign.sweep_schemes`, and the benchmark grid sweeps.
"""
from . import backend
from .scheme import (SCHEME_CHOICES, ArenaEcc, Compose, CostReport,
                     DiagParityEcc, HsiaoSecDed, Protected, Scheme, Tmr,
                     Unprotected, parse_scheme, register_scheme,
                     scheme_choices, scheme_help, standard_grid)

__all__ = [
    "backend",
    "Scheme", "Protected", "CostReport",
    "Unprotected", "ArenaEcc", "DiagParityEcc", "HsiaoSecDed", "Tmr",
    "Compose", "parse_scheme", "SCHEME_CHOICES", "standard_grid",
    "register_scheme", "scheme_choices", "scheme_help",
]
