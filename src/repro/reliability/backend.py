"""One backend registry for every dispatchable op (DESIGN.md §12).

Before this module the repo had three uncoordinated dispatch mechanisms:
`ReliableStore(backend=...)` for the ECC kernels, `impl={scan,level,kernel}`
plus a netlist-specific env var, and the
per-module `interpret` plumbing of `kernels/`.  They are unified here as a
single table mapping op names to named implementations:

    op            implementations (default first)
    ------------  ---------------------------------
    diag_parity   kernel | jnp     encode/scrub the packed ECC arena
    inject_scrub  kernel | jnp     fused corrupt+scrub of the arena
    tmr_vote      kernel | jnp     per-bit 2-of-3 majority
    netlist_exec  level | scan | kernel   netlist execution engines
    crossbar_nor  kernel | jnp     gate-serial in-VMEM netlist interpreter

Resolution order for `resolve(op, impl)`:

1. the per-call ``impl=`` argument (threaded through by `Scheme`s and
   `multpim.execute_netlist`);
2. the ``REPRO_IMPL`` environment variable — either a bare implementation
   name applied to every op that has it (``REPRO_IMPL=jnp``) or a
   comma-separated list of ``op=impl`` pairs
   (``REPRO_IMPL=netlist_exec=kernel,diag_parity=jnp``);
3. the registered default.

Every implementation is registered as a lazy loader so importing this
module never drags in the Pallas kernel packages; `dispatch(op, impl)`
imports on first use and caches the resolved callable.

The Pallas interpret flag also lives here: `use_interpret()` reads
``REPRO_PALLAS_INTERPRET`` (default on — this container is CPU-only) and
`kernels.use_interpret` delegates to it.
"""
from __future__ import annotations

import os
from types import SimpleNamespace
from typing import Callable, Dict, Optional, Tuple

__all__ = ["register", "ops", "implementations", "default_impl", "resolve",
           "dispatch", "use_interpret", "ENV_VAR"]

ENV_VAR = "REPRO_IMPL"
_INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_LOADERS: Dict[str, Dict[str, Callable[[], Callable]]] = {}
_DEFAULTS: Dict[str, str] = {}
_CACHE: Dict[Tuple[str, str], Callable] = {}


def use_interpret() -> bool:
    """Run Pallas kernels in interpret mode (CPU)?  Single env read for all
    kernel packages; on a real TPU set REPRO_PALLAS_INTERPRET=0."""
    return os.environ.get(_INTERPRET_ENV, "1") != "0"


def register(op: str, impl: str, loader: Callable[[], Callable],
             default: bool = False) -> None:
    """Register implementation `impl` of `op` behind a zero-arg loader."""
    _LOADERS.setdefault(op, {})[impl] = loader
    if default or op not in _DEFAULTS:
        _DEFAULTS[op] = impl


def ops() -> Tuple[str, ...]:
    return tuple(sorted(_LOADERS))


def implementations(op: str) -> Tuple[str, ...]:
    if op not in _LOADERS:
        raise KeyError(f"unknown op {op!r} (registered: {ops()})")
    return tuple(_LOADERS[op])


def default_impl(op: str) -> str:
    implementations(op)          # raise on unknown op
    return _DEFAULTS[op]


def _env_overrides() -> Tuple[Dict[str, str], Optional[str]]:
    """Parse REPRO_IMPL into (op=impl pairs, bare token)."""
    pairs: Dict[str, str] = {}
    bare: Optional[str] = None
    for token in filter(None, (t.strip() for t in
                               os.environ.get(ENV_VAR, "").split(","))):
        if "=" in token:
            op, impl = token.split("=", 1)
            pairs[op.strip()] = impl.strip()
        else:
            bare = token
    return pairs, bare


def resolve(op: str, impl: Optional[str] = None) -> str:
    """Implementation name for `op`: per-call > REPRO_IMPL (pair, then bare
    token) > registered default."""
    avail = implementations(op)
    if impl is None:
        pairs, bare = _env_overrides()
        impl = pairs.get(op)
        if impl is None and bare in avail:
            impl = bare
    if impl is None:
        impl = _DEFAULTS[op]
    if impl not in avail:
        raise ValueError(f"unknown implementation {impl!r} for op {op!r} "
                         f"(available: {avail})")
    return impl


def dispatch(op: str, impl: Optional[str] = None) -> Callable:
    """Resolve and load the implementation of `op` (cached)."""
    name = resolve(op, impl)
    key = (op, name)
    if key not in _CACHE:
        _CACHE[key] = _LOADERS[op][name]()
    return _CACHE[key]


# --------------------------------------------------------------------------
# built-in registrations (lazy loaders; kernels import only on first use)
# --------------------------------------------------------------------------

def _load_diag_parity_kernel():
    from ..kernels.diag_parity import encode_parity, scrub, scrub_sharded

    def encode(buf, slopes=(1, 2, -1)):
        return encode_parity(buf, slopes=tuple(slopes))

    def scrub_(buf, parity, slopes=(1, 2, -1), mesh=None):
        if mesh is not None:
            return scrub_sharded(buf, parity, slopes=tuple(slopes), mesh=mesh)
        return scrub(buf, parity, slopes=tuple(slopes))

    return SimpleNamespace(encode=encode, scrub=scrub_)


def _load_diag_parity_jnp():
    from ..kernels.diag_parity import scrub_sharded
    from ..kernels.diag_parity.ref import encode_parity_ref, scrub_ref

    def encode(buf, slopes=(1, 2, -1)):
        return encode_parity_ref(buf, slopes=tuple(slopes))

    def scrub_(buf, parity, slopes=(1, 2, -1), mesh=None):
        def local(b, p):
            return scrub_ref(b, p, slopes=tuple(slopes))
        if mesh is not None:
            return scrub_sharded(buf, parity, slopes=tuple(slopes),
                                 mesh=mesh, local_scrub=local)
        return local(buf, parity)

    return SimpleNamespace(encode=encode, scrub=scrub_)


def _load_hsiao_secded_kernel():
    from ..kernels.hsiao_secded import encode_hsiao, scrub, scrub_sharded

    def encode(buf):
        return encode_hsiao(buf)

    def scrub_(buf, parity, mesh=None):
        if mesh is not None:
            return scrub_sharded(buf, parity, mesh=mesh)
        return scrub(buf, parity)

    return SimpleNamespace(encode=encode, scrub=scrub_)


def _load_hsiao_secded_jnp():
    from ..kernels.hsiao_secded import scrub_sharded
    from ..kernels.hsiao_secded.ref import encode_hsiao_ref, scrub_hsiao_ref

    def scrub_(buf, parity, mesh=None):
        if mesh is not None:
            return scrub_sharded(buf, parity, mesh=mesh,
                                 local_scrub=scrub_hsiao_ref)
        return scrub_hsiao_ref(buf, parity)

    return SimpleNamespace(encode=encode_hsiao_ref, scrub=scrub_)


def _load_inject_scrub_kernel():
    from ..kernels.inject_scrub import inject_scrub, inject_scrub_sharded

    def run(buf, parity, mask, slopes=(1, 2, -1), mesh=None):
        if mesh is not None:
            return inject_scrub_sharded(buf, parity, mask,
                                        slopes=tuple(slopes), mesh=mesh)
        return inject_scrub(buf, parity, mask, slopes=tuple(slopes))

    return run


def _load_inject_scrub_jnp():
    from ..kernels.inject_scrub import inject_scrub_sharded
    from ..kernels.inject_scrub.ref import inject_scrub_ref

    def run(buf, parity, mask, slopes=(1, 2, -1), mesh=None):
        def local(b, p, m):
            return inject_scrub_ref(b, p, m, slopes=tuple(slopes))
        if mesh is not None:
            return inject_scrub_sharded(buf, parity, mask,
                                        slopes=tuple(slopes), mesh=mesh,
                                        local_op=local)
        return local(buf, parity, mask)

    return run


def _load_tmr_vote_kernel():
    from ..kernels.tmr_vote import vote
    return vote


def _load_tmr_vote_jnp():
    from ..core.tmr import vote_array
    return vote_array


def _load_netlist_scan():
    from ..core.netlist import execute
    return execute


def _load_netlist_level():
    from ..core.scheduler import execute_levelized
    return execute_levelized


def _load_netlist_kernel():
    from ..kernels.netlist_exec import execute_packed
    return execute_packed


def _load_crossbar_nor_kernel():
    from ..kernels.crossbar_nor import execute_netlist
    return execute_netlist


def _load_crossbar_nor_jnp():
    from ..kernels.crossbar_nor.ref import execute_netlist_ref
    return execute_netlist_ref


register("diag_parity", "kernel", _load_diag_parity_kernel, default=True)
register("diag_parity", "jnp", _load_diag_parity_jnp)
register("hsiao_secded", "kernel", _load_hsiao_secded_kernel, default=True)
register("hsiao_secded", "jnp", _load_hsiao_secded_jnp)
register("inject_scrub", "kernel", _load_inject_scrub_kernel, default=True)
register("inject_scrub", "jnp", _load_inject_scrub_jnp)
register("tmr_vote", "kernel", _load_tmr_vote_kernel, default=True)
register("tmr_vote", "jnp", _load_tmr_vote_jnp)
register("netlist_exec", "level", _load_netlist_level, default=True)
register("netlist_exec", "scan", _load_netlist_scan)
register("netlist_exec", "kernel", _load_netlist_kernel)
register("crossbar_nor", "kernel", _load_crossbar_nor_kernel, default=True)
register("crossbar_nor", "jnp", _load_crossbar_nor_jnp)
