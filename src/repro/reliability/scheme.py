"""Composable protection schemes — the single public API (DESIGN.md §12).

The paper's point (§V–§VI) is that diagonal-parity ECC and TMR are not
alternatives but a *design space*: ECC for short-term scrubbing, TMR
disciplines for long-term protection, and joint configurations evaluated
together on NN workloads.  This module expresses that space as one small
protocol so every consumer — train loop, serving, fault campaigns,
benchmarks — can sweep protection schemes instead of hard-coding one:

    scheme = parse_scheme("ecc+tmr-serial")
    prot   = scheme.protect(params)          # Protected pytree node
    prot, report = scheme.scrub(prot)        # verify/correct redundancy
    prot   = scheme.refresh(new_params)      # after a parameter rewrite
    params = scheme.read(prot)               # decode/vote the payload
    cost   = scheme.overhead()               # CostReport (paper §IV/§V)

Schemes: `Unprotected`, `DiagParityEcc` (the arena-backed §IV word code),
`Tmr` with all three paper disciplines (serial / parallel / semi-parallel),
and `Compose(ecc, tmr)` for the joint long-term configurations.  Each is a
frozen dataclass (hashable — usable as a static jit argument and as pytree
aux data) and every array op dispatches through the backend registry
(`reliability.backend`), so ``impl=`` / ``REPRO_IMPL`` select kernel vs jnp
paths uniformly.

`Protected` is a registered pytree node carrying payload + redundancy +
scheme metadata, so it flows through `jit`, `vmap` and the checkpointer
unchanged.  All schemes are bit-exact against the pre-redesign
`ReliableStore` / `core.tmr` paths (golden tests in tests/test_scheme.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import arena
from ..core.reliability import ScrubReport
from ..core.tmr import TMR_COSTS
from . import backend

__all__ = ["CostReport", "Protected", "Scheme", "Unprotected", "ArenaEcc",
           "DiagParityEcc", "HsiaoSecDed", "Tmr", "Compose", "parse_scheme",
           "SCHEME_CHOICES", "standard_grid", "register_scheme",
           "scheme_choices", "scheme_help"]


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Protection overheads relative to the unprotected baseline.

    storage_x counts held redundancy (parity words, extra copies);
    latency/area/throughput follow the paper's §IV/§V accounting.
    """
    storage_x: float = 1.0
    latency_x: float = 1.0
    area_x: float = 1.0
    throughput_x: float = 1.0

    def describe(self) -> str:
        return (f"storage={self.storage_x:.3f}x latency={self.latency_x:.2f}x "
                f"area={self.area_x:.0f}x throughput={self.throughput_x:.2f}x")


@jax.tree_util.register_pytree_node_class
class Protected:
    """A protected parameter pytree: payload + scheme-specific redundancy.

    Registered pytree node — children are (payload, redundancy), aux data is
    the (hashable, frozen) scheme — so a Protected store crosses `jit`,
    `vmap` and `Checkpointer.save/restore` boundaries unchanged.  The
    `_packed` attribute is a best-effort (arena, spec) cache for the payload
    as stored; it is dropped by tree_flatten, so instances crossing a jit
    boundary simply repack.
    """

    def __init__(self, payload: Any, redundancy: Any, scheme: "Scheme"):
        self.payload = payload
        self.redundancy = redundancy
        self.scheme = scheme
        self._packed: Optional[Tuple[jax.Array, arena.ArenaSpec]] = None

    def read(self) -> Any:
        return self.scheme.read(self)

    def scrub(self, mesh=None) -> Tuple["Protected", ScrubReport]:
        return self.scheme.scrub(self, mesh=mesh)

    # pytree plumbing
    def tree_flatten(self):
        return (self.payload, self.redundancy), self.scheme

    @classmethod
    def tree_unflatten(cls, scheme, children):
        return cls(children[0], children[1], scheme)

    def __repr__(self) -> str:
        return f"Protected(scheme={self.scheme.name})"


def _zero_report() -> ScrubReport:
    z = jnp.zeros((), jnp.int32)
    return ScrubReport(corrected=z, parity_fixed=z, uncorrectable=z)


def _ns_tree(pspecs: Any, mesh) -> Any:
    """PartitionSpec tree -> NamedSharding tree (specs are leaves)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def _vote_counts(a: Any, b: Any, c: Any) -> Tuple[jax.Array, jax.Array]:
    """(corrected, uncorrectable) word counts for a 3-copy vote, disjoint
    like the ECC convention: `corrected` counts words where a majority
    exists and the minority copy was repaired (each word once, however
    many copies diverged); `uncorrectable` counts words where all three
    copies pairwise differ — multiple independent corruptions landed on
    the same word, so the per-bit majority may itself be wrong there (the
    danger signal TMR can actually *detect*; a clean 2-of-3 double flip
    is inherently silent)."""
    corrected = jnp.zeros((), jnp.int32)
    conflicts = jnp.zeros((), jnp.int32)
    for x, y, z in zip(jax.tree.leaves(a), jax.tree.leaves(b),
                       jax.tree.leaves(c)):
        xw, yw, zw = (arena.leaf_to_words(v) for v in (x, y, z))
        d01, d02, d12 = xw != yw, xw != zw, yw != zw
        conflict = d01 & d02 & d12
        corrected = corrected + ((d01 | d02 | d12)
                                 & ~conflict).sum(dtype=jnp.int32)
        conflicts = conflicts + conflict.sum(dtype=jnp.int32)
    return corrected, conflicts


class Scheme:
    """Protection-scheme protocol.  Subclasses are frozen dataclasses."""

    @property
    def name(self) -> str:
        raise NotImplementedError

    def protect(self, payload: Any) -> Protected:
        raise NotImplementedError

    def refresh(self, payload: Any) -> Protected:
        """Re-protect after the payload was rewritten (optimizer step)."""
        return self.protect(payload)

    def adopt(self, payload: Any, redundancy: Any) -> Protected:
        """Rebuild a Protected from externally stored payload+redundancy
        (checkpoint restore) without re-encoding."""
        return Protected(payload, redundancy, self)

    def scrub(self, prot: Protected,
              mesh=None) -> Tuple[Protected, ScrubReport]:
        """Verify/correct the redundancy.  With a mesh, arena-wide scrubs
        run as per-shard shard_map launches with psum'd counters
        (DESIGN.md §14) — bit-exact vs mesh=None."""
        raise NotImplementedError

    def vote_share(self, report: ScrubReport):
        """The copy-vote share of a scrub report — the scheme knows which
        of its counters are vote outcomes.  None for non-voting schemes;
        an on-device int32 otherwise (fetch with the rest)."""
        return None

    def scrub_into(self, prot: Protected, metrics, mesh=None,
                   registry=None) -> Tuple[Protected, dict]:
        """Scrub and fold the report into a metrics-registry accumulator
        dict (obs.MetricsRegistry schema names, device-side adds):

            metrics = DEFAULT_REGISTRY.zeros(["ecc_corrected", ...])
            prot, metrics = scheme.scrub_into(prot, metrics)
            ...
            stats = fetch_telemetry(metrics)     # ONE host sync at the end

        Counters never touch the host between scrubs — the accumulation is
        `registry.accumulate`, all jnp adds."""
        from ..obs import DEFAULT_REGISTRY
        registry = registry if registry is not None else DEFAULT_REGISTRY
        fixed, report = self.scrub(prot, mesh=mesh)
        updates = registry.from_report(report)
        vd = self.vote_share(report)
        if vd is not None:
            updates["tmr_final_disagreements"] = vd
        return fixed, registry.accumulate(metrics, updates)

    def read(self, prot: Protected) -> Any:
        """Decode/vote the protected payload back to a plain pytree."""
        return prot.payload

    def shardings(self, payload: Any, pspecs: Any, mesh,
                  rules=None) -> Protected:
        """NamedSharding tree shaped like ``protect(payload)`` — pass to
        `jax.device_put` to place a Protected store on `mesh`.

        `payload` may be abstract (ShapeDtypeStructs); `pspecs` is its
        PartitionSpec tree (e.g. from `models.params.partition_specs`).
        Redundancy placement is scheme-aware: parity tables shard their
        arena-block axis across the whole mesh, TMR copies shard exactly
        like the payload they mirror (each copy lands on its replica group
        when the engine later stacks them under a copy-axis spec).
        """
        return Protected(_ns_tree(pspecs, mesh),
                         self._redundancy_shardings(payload, pspecs, mesh,
                                                    rules), self)

    def _redundancy_shardings(self, payload, pspecs, mesh, rules):
        return None

    def corrupt_store(self, prot: Protected, model, key: jax.Array,
                      dt: float = 1.0) -> Protected:
        """Inject storage faults into every held *data* copy (payload and,
        for TMR-style schemes, the redundant copies — each under an
        independent subkey), leaving parity tables untouched, matching the
        paper's exposure model where check words are scrub-verified.
        Campaign trials drive one exposure interval per call."""
        return self.adopt(model.corrupt(prot.payload, key, dt),
                          prot.redundancy)

    def overhead(self) -> CostReport:
        raise NotImplementedError

    def cost_events(self, base, profile, spec):
        """mMPU cost-model hookup (costmodel.compile.lower_step): extend
        or transform a redundancy-free step event stream with this
        scheme's redundancy traffic.  `base` is a sequence of
        `costmodel.MmpuEvent`; `profile` a `costmodel.StepProfile`;
        `spec` a `costmodel.DeviceSpec`.  The analytical `overhead()`
        CostReport is the closed form these streams must agree with
        (tests/test_costmodel.py holds both to each other)."""
        return tuple(base)

    #: does the redundancy belong in a checkpoint?  True for compact parity
    #: tables; False when redundancy is full copies (rebuilt on restore).
    checkpoint_redundancy: bool = False


@dataclasses.dataclass(frozen=True)
class Unprotected(Scheme):
    """No redundancy — the baseline every CostReport is relative to."""

    @property
    def name(self) -> str:
        return "unprotected"

    def protect(self, payload: Any) -> Protected:
        return Protected(payload, None, self)

    def scrub(self, prot: Protected,
              mesh=None) -> Tuple[Protected, ScrubReport]:
        return prot, _zero_report()

    def overhead(self) -> CostReport:
        return CostReport()


class ArenaEcc(Scheme):
    """Shared machinery for packed-arena word codes (the code zoo,
    DESIGN.md §18): everything that depends only on the arena layout —
    pack/protect, fused scrub, copy concatenation, parity sharding,
    checkpointing — lives here; subclasses supply the code itself
    (`_encode` / `_scrub` / `n_parity_words` and the cost accounting).

    Subclasses are frozen dataclasses carrying at least ``impl``
    (backend override) and ``write_back`` (the correct-on-read serving
    discipline: `read_corrected` is meaningful for every ArenaEcc, but
    a True flag tells serving paths — the paged KV pool, the batcher —
    to correct-and-persist hot state on access instead of waiting for
    the periodic scrub).
    """

    # spec-string token of the code family ("ecc", "hsiao") — a plain
    # class attribute, deliberately unannotated so dataclass subclasses
    # do not inherit it as a field
    code_name = "ecc"

    @property
    def name(self) -> str:
        return self.code_name + ("-wb" if self.write_back else "")

    @property
    def n_parity_words(self) -> int:
        """Redundancy words per 32-word block (the parity-table width)."""
        raise NotImplementedError

    def _encode(self, buf: jax.Array) -> jax.Array:
        raise NotImplementedError

    def _scrub(self, buf: jax.Array, parity: jax.Array, mesh=None):
        raise NotImplementedError

    def _ecc_events(self, profile, spec, copies: int = 1):
        """This code's mMPU redundancy traffic (costmodel hookup)."""
        raise NotImplementedError

    def protect(self, payload: Any) -> Protected:
        buf, spec = arena.pack(payload)
        parity = self._encode(buf)
        prot = Protected(payload, parity, self)
        prot._packed = (buf, spec)
        return prot

    def scrub(self, prot: Protected,
              mesh=None) -> Tuple[Protected, ScrubReport]:
        buf, spec = prot._packed if prot._packed is not None \
            else arena.pack(prot.payload)
        fixed, par2, counts = self._scrub(buf, prot.redundancy, mesh=mesh)
        out = Protected(arena.unpack(fixed, spec), par2, self)
        out._packed = (fixed, spec)
        report = ScrubReport(corrected=counts[0], parity_fixed=counts[1],
                             uncorrectable=counts[2])
        return out, report

    def read_corrected(self, prot: Protected, mesh=None):
        """The write-back-on-read discipline at the scheme level: decode
        through a fused scrub so the caller gets *corrected* bits AND the
        corrected store persists.  Returns (payload, prot', report)."""
        fixed, report = self.scrub(prot, mesh=mesh)
        return fixed.payload, fixed, report

    def _redundancy_shardings(self, payload, pspecs, mesh, rules):
        from jax.sharding import NamedSharding
        from ..optim.sharding_rules import parity_pspec
        spec = arena.arena_spec(payload)
        return NamedSharding(mesh, parity_pspec(spec.n_blocks,
                                                self.n_parity_words, mesh,
                                                rules))

    def encode_arena(self, buf: jax.Array) -> jax.Array:
        """Parity table for a packed uint32 arena.

        The write-back discipline for *mutable* arena state (the paged KV
        pool, which rewrites pages every scheduler tick): re-encode after
        each legitimate write so a later scrub never "corrects" fresh data
        back toward a stale parity.  Device op; jit-safe."""
        return self._encode(buf)

    def scrub_arena(self, buf: jax.Array, parity: jax.Array,
                    mesh=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Fused scrub over a packed uint32 arena that is NOT wrapped in a
        `Protected` pytree — mutable arena-resident state such as the
        paged KV pool.  Returns (fixed arena, fixed parity, counts) with
        counts the (3,) int32 (corrected, parity_fixed, uncorrectable)
        vector, all on device.  Because the word code is block-local,
        several same-layout arenas may be concatenated along the block
        axis and scrubbed in this ONE launch (how the pool covers all
        three TMR copies)."""
        return self._scrub(buf, parity, mesh=mesh)

    def inject_scrub_arena(self, buf: jax.Array, parity: jax.Array,
                           mask: jax.Array, mesh=None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Fused corrupt+repair over a packed arena: XOR the fault mask in,
        then run the code's scrub, all inside one jit region.  Returns
        (fixed arena, fixed parity, counts) with counts the (4,) int32
        (injected, corrected, parity_fixed, uncorrectable) vector — the
        fault-campaign convention.  Codes with a dedicated fused kernel
        override this (diagonal parity routes to kernels/inject_scrub);
        the default is correct for every block-local word code."""
        injected = jnp.sum(
            jax.lax.population_count(mask).astype(jnp.int32))
        fixed, par2, counts = self._scrub(buf ^ mask, parity, mesh=mesh)
        return fixed, par2, jnp.concatenate([injected[None], counts])

    def scrub_copies(self, bufs, parities,
                     mesh=None) -> Tuple[list, list, jax.Array]:
        """Scrub N same-layout packed copies in ONE fused launch.

        The word code is block-local (every 32-word block carries its own
        parity row), so N copies of one arena concatenate along the block
        axis into a single buffer and the fused encode->syndrome->correct
        pass covers all of them in one kernel launch — replacing the
        Python loop of per-copy scrubs that serialized the TMR copy axis.

        bufs: list of (n_words,) uint32 arenas sharing one ArenaSpec;
        parities: matching list of (n_blocks, F) tables.  Returns
        (fixed bufs, fixed parities, counts) with counts the (3,) int32
        vector summed across copies — all on device, nothing fetched.
        """
        n = bufs[0].shape[0]
        nb = parities[0].shape[0]
        fixed, par2, counts = self._scrub(
            jnp.concatenate(arena.canonical_parts(list(bufs))),
            jnp.concatenate(arena.canonical_parts(list(parities))),
            mesh=mesh)
        return ([fixed[i * n:(i + 1) * n] for i in range(len(bufs))],
                [par2[i * nb:(i + 1) * nb] for i in range(len(parities))],
                counts)

    def cost_events(self, base, profile, spec):
        return tuple(base) + self._ecc_events(profile, spec)

    checkpoint_redundancy = True


@dataclasses.dataclass(frozen=True)
class DiagParityEcc(ArenaEcc):
    """Diagonal-parity word ECC over the packed arena (paper §IV).

    Wraps the `core.arena` + `kernels/diag_parity` machinery behind the
    scheme protocol; bit-exact against `core.reliability.ReliableStore`
    (same pack, same encode, same fused scrub, same counts).  `impl`
    overrides the `diag_parity` backend (None -> registry default).
    Corrects one flipped bit per 32-word *block* at 3 parity words of
    storage; multi-flip blocks are flagged uncorrectable.
    """

    slopes: Tuple[int, ...] = (1, 2, -1)
    impl: Optional[str] = None
    write_back: bool = False

    code_name = "ecc"

    @property
    def n_parity_words(self) -> int:
        return len(self.slopes)

    def _op(self):
        return backend.dispatch("diag_parity", self.impl)

    def _encode(self, buf: jax.Array) -> jax.Array:
        return self._op().encode(buf, slopes=self.slopes)

    def _scrub(self, buf: jax.Array, parity: jax.Array, mesh=None):
        return self._op().scrub(buf, parity, slopes=self.slopes, mesh=mesh)

    def inject_scrub_arena(self, buf: jax.Array, parity: jax.Array,
                           mask: jax.Array, mesh=None):
        # diagonal parity has a dedicated fused corrupt+repair kernel
        op = backend.dispatch("inject_scrub", self.impl)
        return op(buf, parity, mask, slopes=self.slopes, mesh=mesh)

    def overhead(self) -> CostReport:
        # storage: len(slopes) parity words per 32-word block; latency: the
        # paper's ~26% average ECC overhead with the dedicated extension
        return CostReport(storage_x=1.0 + len(self.slopes) / arena.BLOCK,
                          latency_x=1.26)

    def _ecc_events(self, profile, spec, copies: int = 1):
        from ..costmodel.compile import ecc_events
        return ecc_events(profile, spec, self.slopes, copies=copies)


@dataclasses.dataclass(frozen=True)
class HsiaoSecDed(ArenaEcc):
    """(39,32) Hsiao SEC-DED word code over the packed arena.

    The second code of the zoo (kernels/hsiao_secded, DESIGN.md §18):
    7 odd-weight-column check bits per 32-bit word, packed as 7 parity
    words per block.  Every word decodes independently — one flip in
    each of a block's 32 words is still corrected, where diagonal
    parity corrects one flip per block — and double errors are
    *detected* (reported uncorrectable through `ScrubReport`) instead
    of silently miscorrected.  Storage 1+7/32 vs diag's 1+3/32, and a
    denser encode tree (7 masked-parity families vs 3 rotate-XOR
    slopes): higher coverage, higher maintenance tax.
    """

    impl: Optional[str] = None
    write_back: bool = False

    code_name = "hsiao"

    @property
    def n_parity_words(self) -> int:
        from ..kernels.hsiao_secded.code import N_CHECKS
        return N_CHECKS

    def _op(self):
        return backend.dispatch("hsiao_secded", self.impl)

    def _encode(self, buf: jax.Array) -> jax.Array:
        return self._op().encode(buf)

    def _scrub(self, buf: jax.Array, parity: jax.Array, mesh=None):
        return self._op().scrub(buf, parity, mesh=mesh)

    def overhead(self) -> CostReport:
        # 7 check bits per word of storage; latency follows the denser
        # encode (7 families of masked parities vs 3 diagonal slopes —
        # arXiv:2105.04212's Hamming-vs-parity gap), still well under
        # any TMR discipline's 3x
        return CostReport(storage_x=1.0 + 7.0 / arena.BLOCK,
                          latency_x=1.42)

    def _ecc_events(self, profile, spec, copies: int = 1):
        from ..costmodel.compile import secded_events
        return secded_events(profile, spec, copies=copies)


@dataclasses.dataclass(frozen=True)
class Tmr(Scheme):
    """Triple modular redundancy with per-bit voting (paper §V).

    All three paper disciplines are selectable — 'serial' (3x latency),
    'parallel' (3x area) and 'semi_parallel' (1/3 throughput) — with
    identical output semantics: the discipline changes the execution shape
    of `wrap()` and the `overhead()` accounting, never the voted bits.
    Voting dispatches through the `tmr_vote` backend (kernel | jnp).
    """

    discipline: str = "serial"
    impl: Optional[str] = None

    def __post_init__(self):
        if self.discipline not in TMR_COSTS:
            raise ValueError(f"discipline must be one of {sorted(TMR_COSTS)}")

    @property
    def name(self) -> str:
        return f"tmr-{self.discipline.replace('_', '-')}"

    def _vote(self):
        return backend.dispatch("tmr_vote", self.impl)

    def protect(self, payload: Any) -> Protected:
        # three copies; as immutable jax arrays they alias until corrupted
        return Protected(payload, (payload, payload), self)

    def read(self, prot: Protected) -> Any:
        vote = self._vote()
        c1, c2 = prot.redundancy
        return jax.tree.map(vote, prot.payload, c1, c2)

    def scrub(self, prot: Protected,
              mesh=None) -> Tuple[Protected, ScrubReport]:
        # voting is elementwise — under a mesh GSPMD keeps it shard-local,
        # so there is no explicit shard_map path (mesh accepted for
        # protocol uniformity)
        voted = self.read(prot)
        c1, c2 = prot.redundancy
        # three-way disagreements feed the runtime's RESTART path — the
        # voted word is best-effort there, like an ECC uncorrectable block
        corrected, conflicts = _vote_counts(prot.payload, c1, c2)
        report = ScrubReport(corrected=corrected,
                             parity_fixed=jnp.zeros((), jnp.int32),
                             uncorrectable=conflicts)
        return Protected(voted, (voted, voted), self), report

    def vote_share(self, report: ScrubReport):
        # every TMR repair and every conflict is a copy disagreement
        return report.corrected + report.uncorrectable

    def _redundancy_shardings(self, payload, pspecs, mesh, rules):
        ns = _ns_tree(pspecs, mesh)
        return (ns, ns)

    def corrupt_store(self, prot: Protected, model, key: jax.Array,
                      dt: float = 1.0) -> Protected:
        c1, c2 = prot.redundancy
        k0, k1, k2 = jax.random.split(key, 3)
        return self.adopt(model.corrupt(prot.payload, k0, dt),
                          (model.corrupt(c1, k1, dt),
                           model.corrupt(c2, k2, dt)))

    def wrap(self, serve_fn, sequential: bool = False):
        """TMR-voted serving: `serve_fn(params, *inputs) -> pytree`, called
        as wrapped(p1, p2, p3, *inputs) with per-copy parameter versions.

        serial: three sequential evaluations; parallel/semi_parallel: one
        vmapped evaluation over the stacked replica axis (on a real mesh
        the axis is sharded over 3 replica groups for 'parallel', folded
        into the row/batch capacity for 'semi_parallel').  The voted bits
        are identical either way, so ``sequential=True`` forces the
        serial execution shape regardless of discipline — for single-host
        drivers where stacking three full copies would 3x peak memory —
        while `cost` keeps reporting the discipline's accounting.
        """
        vote = self._vote()

        def serial(p1, p2, p3, *inputs):
            outs = [serve_fn(p, *inputs) for p in (p1, p2, p3)]
            return jax.tree.map(vote, *outs)

        def replicated(p1, p2, p3, *inputs):
            stacked = jax.tree.map(lambda a, b, c: jnp.stack([a, b, c]),
                                   p1, p2, p3)
            outs = jax.vmap(lambda p: serve_fn(p, *inputs))(stacked)
            o1, o2, o3 = (jax.tree.map(lambda x, i=i: x[i], outs)
                          for i in range(3))
            return jax.tree.map(vote, o1, o2, o3)

        wrapped = serial if (sequential or self.discipline == "serial") \
            else replicated
        wrapped.cost = self.overhead()
        return wrapped

    def overhead(self) -> CostReport:
        c = TMR_COSTS[self.discipline]
        return CostReport(storage_x=3.0, latency_x=c.latency_x,
                          area_x=c.area_x, throughput_x=c.throughput_x)

    def cost_events(self, base, profile, spec):
        from ..costmodel.compile import tmr_transform, vote_events
        return tmr_transform(base, self.discipline) \
            + vote_events(profile, spec)


@dataclasses.dataclass(frozen=True)
class Compose(Scheme):
    """Joint configuration: a per-copy arena word code under TMR voting
    (the paper's combined long-term protection, §VI) — any `ArenaEcc`
    (diagonal parity or Hsiao SEC-DED) composes identically.

    Each of the three copies carries its own parity table; `scrub` first
    runs the fused ECC scrub on every copy (correcting all single-bit
    flips per block), then votes per-bit across the scrubbed copies — so
    blocks the word code flags uncorrectable are still recovered whenever
    at least two copies agree.  The report sums the three per-copy ECC
    corrected/parity_fixed counts plus the voted word repairs; its
    `uncorrectable` counts only words still three-way-disagreeing AFTER
    the per-copy scrub (per-copy ECC uncorrectables that the vote
    recovers are demoted to corrections — they no longer trigger the
    runtime's checkpoint-restore path).
    """

    ecc: ArenaEcc = DiagParityEcc()
    tmr: Tmr = Tmr()

    @property
    def name(self) -> str:
        return f"{self.ecc.name}+{self.tmr.name}"

    def protect(self, payload: Any) -> Protected:
        buf, spec = arena.pack(payload)
        parity = self.ecc._encode(buf)
        prot = Protected(payload, ((payload, payload),
                                   (parity, parity, parity)), self)
        prot._packed = (buf, spec)
        return prot

    def read(self, prot: Protected) -> Any:
        (c1, c2), _ = prot.redundancy
        vote = self.tmr._vote()
        return jax.tree.map(vote, prot.payload, c1, c2)

    def scrub(self, prot: Protected,
              mesh=None) -> Tuple[Protected, ScrubReport]:
        # scrub and vote directly on the packed arenas: all three copies
        # share one layout, so the per-copy ECC pass is ONE fused launch
        # over the concatenated copies (scrub_copies) and the vote is three
        # uint32 buffers through the tmr_vote backend; only the voted
        # result is unpacked once.  Counts stay on device (no per-copy
        # Python accumulation).
        (c1, c2), (p0, p1, p2) = prot.redundancy
        packed, spec = [], None
        for i, copy in enumerate((prot.payload, c1, c2)):
            buf, spec = prot._packed if i == 0 and prot._packed is not None \
                else arena.pack(copy)
            packed.append(buf)
        bufs, _, counts = self.ecc.scrub_copies(packed, (p0, p1, p2),
                                                mesh=mesh)
        vbuf = self.tmr._vote()(*bufs)
        voted = arena.unpack(vbuf, spec)
        vpar = self.ecc._encode(vbuf)
        out = Protected(voted, ((voted, voted), (vpar, vpar, vpar)), self)
        out._packed = (vbuf, spec)
        d01, d02, d12 = (bufs[0] != bufs[1], bufs[0] != bufs[2],
                         bufs[1] != bufs[2])
        conflict = d01 & d02 & d12
        report = ScrubReport(
            corrected=counts[0]
            + ((d01 | d02 | d12) & ~conflict).sum(dtype=jnp.int32),
            parity_fixed=counts[1],
            uncorrectable=conflict.sum(dtype=jnp.int32))
        return out, report

    def vote_share(self, report: ScrubReport):
        # only the post-ECC three-way conflicts are separable from the
        # merged report (repaired pairwise disagreements are folded into
        # `corrected` with the per-copy ECC counts)
        return report.uncorrectable

    def corrupt_store(self, prot: Protected, model, key: jax.Array,
                      dt: float = 1.0) -> Protected:
        (c1, c2), parities = prot.redundancy
        k0, k1, k2 = jax.random.split(key, 3)
        return self.adopt(model.corrupt(prot.payload, k0, dt),
                          ((model.corrupt(c1, k1, dt),
                            model.corrupt(c2, k2, dt)), parities))

    def _redundancy_shardings(self, payload, pspecs, mesh, rules):
        ns = _ns_tree(pspecs, mesh)
        pns = self.ecc._redundancy_shardings(payload, pspecs, mesh, rules)
        return ((ns, ns), (pns, pns, pns))

    def overhead(self) -> CostReport:
        e, t = self.ecc.overhead(), self.tmr.overhead()
        return CostReport(storage_x=e.storage_x * t.storage_x,
                          latency_x=e.latency_x * t.latency_x,
                          area_x=e.area_x * t.area_x,
                          throughput_x=e.throughput_x * t.throughput_x)

    def cost_events(self, base, profile, spec):
        # execution triplicates under the TMR discipline; each copy
        # carries its own parity table, so the word-code traffic covers
        # copies=3 blocks (scrub_copies fuses them in one pass)
        from ..costmodel.compile import tmr_transform, vote_events
        return (tmr_transform(base, self.tmr.discipline)
                + vote_events(profile, spec)
                + self.ecc._ecc_events(profile, spec, copies=3))


# --------------------------------------------------------------------------
# scheme registry + spec strings (serve --scheme, campaign grids)
# --------------------------------------------------------------------------
#
# One registry maps spec tokens to scheme factories; everything user-facing
# (serve --scheme validation and help, campaign grids, SCHEME_CHOICES) is
# derived from it, so a new code registered here appears everywhere at once.

_SCHEME_FACTORIES: "dict[str, Tuple[Any, str]]" = {}
_SCHEME_ALIASES: "dict[str, str]" = {}


def register_scheme(token: str, factory, help: str = "",
                    aliases: Tuple[str, ...] = ()) -> None:
    """Register `factory(impl) -> Scheme` under spec token `token`."""
    _SCHEME_FACTORIES[token] = (factory, help)
    for a in aliases:
        _SCHEME_ALIASES[a] = token


def scheme_choices() -> Tuple[str, ...]:
    """Every registered spec token, plus the composition grammar (one
    arena code + one TMR discipline joined by '+')."""
    return tuple(_SCHEME_FACTORIES) + ("ecc+tmr", "hsiao+tmr")


def scheme_help() -> str:
    """One-line-per-token help text assembled from the registry (the
    serve --scheme flag renders this, never a hardcoded list)."""
    lines = [f"{tok}: {hlp}" for tok, (_, hlp) in _SCHEME_FACTORIES.items()]
    lines.append("<code>+tmr[-<discipline>]: per-copy arena code under "
                 "TMR voting (e.g. ecc+tmr-serial, hsiao+tmr)")
    return "; ".join(lines)


register_scheme("off", lambda impl: Unprotected(),
                "no redundancy (baseline)", aliases=("none", "unprotected"))
register_scheme("ecc", lambda impl: DiagParityEcc(impl=impl),
                "diagonal-parity word code, 1 correction per 32-word block,"
                " +3/32 storage")
register_scheme("ecc-wb", lambda impl: DiagParityEcc(impl=impl,
                                                     write_back=True),
                "diagonal parity with write-back-on-read serving")
register_scheme("hsiao", lambda impl: HsiaoSecDed(impl=impl),
                "(39,32) Hsiao SEC-DED, per-word correct + double-error "
                "detect, +7/32 storage")
register_scheme("hsiao-wb", lambda impl: HsiaoSecDed(impl=impl,
                                                     write_back=True),
                "Hsiao SEC-DED with write-back-on-read serving")

_TMR_ALIASES = {"serial": "serial", "parallel": "parallel",
                "semi": "semi_parallel", "semi-parallel": "semi_parallel",
                "semi_parallel": "semi_parallel"}

for _disc, _canon in (("serial", "serial"), ("parallel", "parallel"),
                      ("semi", "semi_parallel")):
    register_scheme(
        f"tmr-{_disc}",
        lambda impl, d=_canon: Tmr(discipline=d, impl=impl),
        f"triple modular redundancy, {_canon.replace('_', '-')} discipline")

SCHEME_CHOICES = scheme_choices()


def _parse_one(token: str, impl: Optional[str]) -> Scheme:
    token = token.strip().lower()
    token = _SCHEME_ALIASES.get(token, token)
    if token in _SCHEME_FACTORIES:
        return _SCHEME_FACTORIES[token][0](impl)
    if token == "tmr" or token.startswith("tmr-"):
        disc = _TMR_ALIASES.get(token[4:] or "serial")
        if disc is None:
            raise ValueError(f"unknown TMR discipline {token[4:]!r} "
                             f"(expected one of {sorted(_TMR_ALIASES)})")
        return Tmr(discipline=disc, impl=impl)
    raise ValueError(f"unknown scheme {token!r} "
                     f"(expected one of {scheme_choices()})")


def standard_grid(impl: Optional[str] = None,
                  include_hsiao: bool = False) -> Tuple[Scheme, ...]:
    """The canonical sweep grid (every scheme family, all disciplines) —
    shared by the campaign benchmarks so they all walk one design space.
    `include_hsiao` extends it with the SEC-DED code zoo variants (solo
    and composed with TMR) behind one flag."""
    grid = (Unprotected(), DiagParityEcc(impl=impl),
            Tmr("serial", impl=impl), Tmr("parallel", impl=impl),
            Tmr("semi_parallel", impl=impl),
            Compose(DiagParityEcc(impl=impl), Tmr("serial", impl=impl)))
    if include_hsiao:
        grid += (HsiaoSecDed(impl=impl),
                 Compose(HsiaoSecDed(impl=impl), Tmr("serial", impl=impl)))
    return grid


def parse_scheme(spec: str, impl: Optional[str] = None) -> Scheme:
    """Parse a scheme spec string: any registered token (``off | ecc |
    ecc-wb | hsiao | hsiao-wb | tmr-<discipline>``) or a composition
    ``<code>+tmr[-<discipline>]`` with discipline serial | parallel |
    semi.  `impl` threads a backend override into every constructed
    scheme."""
    parts = [_parse_one(t, impl) for t in spec.split("+")]
    if len(parts) == 1:
        return parts[0]
    if len(parts) == 2:
        eccs = [p for p in parts if isinstance(p, ArenaEcc)]
        tmrs = [p for p in parts if isinstance(p, Tmr)]
        if len(eccs) == 1 and len(tmrs) == 1:
            return Compose(ecc=eccs[0], tmr=tmrs[0])
    raise ValueError(f"cannot compose scheme spec {spec!r} "
                     "(expected <code>+tmr[-<discipline>])")
