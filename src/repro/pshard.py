"""Logical-axis sharding: one place that maps model-logical dimension names
to physical mesh axes.

Models annotate tensors with *logical* axes ("batch", "ff", "kv_seq", ...);
the launcher installs an ambient mesh + a ShardingRules table; resolution
checks divisibility so small/odd dims degrade to replication instead of
erroring.  The §Perf hillclimb edits ShardingRules, not model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "ambient_mesh", "use_mesh_and_rules",
           "spec_for", "constrain", "named_sharding"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical dim name -> tuple of mesh axis names (in sharding order)."""

    table: Dict[str, Tuple[str, ...]] = dataclasses.field(default_factory=dict)

    def axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return self.table.get(logical, ())

    def replace(self, **updates) -> "ShardingRules":
        t = dict(self.table)
        for k, v in updates.items():
            t[k] = tuple(v) if v else ()
        return ShardingRules(t)


#: default GSPMD strategy: DP over (pod, data); TP/EP/vocab over model;
#: FSDP (weight d_model dim over data) — activations keep d_model
#: replicated because the batch dim claims the data axis first.
DEFAULT_RULES = ShardingRules({
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "vocab_in": (),   # input embedding gather: see models/nn.embed_specs
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "model_dim": ("data",),   # FSDP: weight matrices 2-D sharded (data x model)
    "kv_seq": ("model",),     # decode KV caches: shard sequence when heads can't be
    "seq": (),
    "zero": ("data",),        # optimizer-state ZeRO-1 axis
    # reliability placement (DESIGN.md §14): the TMR leading copy axis rides
    # a "copy" mesh axis (present only on meshes folded by
    # launch.mesh.fold_copy_axis — on plain data x model meshes the copies
    # degrade to replication), and redundancy tables (ECC parity) shard
    # their leading arena-block axis across the whole mesh.
    "copy": ("copy",),
    "arena_block": ("data", "model"),
})


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: ShardingRules = DEFAULT_RULES


_CTX = _Ctx()


def ambient_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def ambient_rules() -> ShardingRules:
    return _CTX.rules


@contextlib.contextmanager
def use_mesh_and_rules(mesh: Optional[Mesh], rules: ShardingRules = DEFAULT_RULES):
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = old


def _resolve_dim(size: int, logical: Optional[str], mesh: Mesh,
                 rules: ShardingRules):
    axes = [a for a in rules.axes_for(logical) if a in mesh.axis_names]
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if size % total != 0:
        return None  # degrade to replication rather than erroring
    return tuple(axes) if len(axes) > 1 else axes[0]


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None,
             rules: Optional[ShardingRules] = None) -> P:
    """PartitionSpec for a tensor with the given logical axes, with
    divisibility-checked degradation.  Mesh axes are never used twice."""
    mesh = mesh or ambient_mesh()
    rules = rules or ambient_rules()
    if mesh is None:
        return P()
    parts, used = [], set()
    for size, name in zip(shape, logical):
        r = _resolve_dim(size, name, mesh, rules)
        flat = r if isinstance(r, tuple) else ((r,) if r else ())
        if r is not None and not (set(flat) & used):
            parts.append(r)
            used.update(flat)
        else:
            parts.append(None)
    return P(*parts)


def named_sharding(shape, logical, mesh=None, rules=None) -> Optional[NamedSharding]:
    mesh = mesh or ambient_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical, mesh, ambient_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree, pspec_tree):
    """with_sharding_constraint a pytree against a PartitionSpec tree
    (used to pin e.g. gradient accumulators to the parameter shardings);
    no-op when pspec_tree is None or there is no ambient mesh."""
    mesh = ambient_mesh()
    if mesh is None or pspec_tree is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree, pspec_tree)
