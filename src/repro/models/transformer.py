"""Model assembly for all architecture families.

Families and their layer layouts (scan-over-layers with remat everywhere):

  dense   : L x [self-attn, MLP]
  moe     : L x [self-attn, MoE (+ optional shared expert)]
  ssm     : L x [Mamba-2 SSD block]
  hybrid  : tiles of cfg.layer_pattern, e.g. (R, R, A) — RG-LRU blocks +
            local (sliding-window) attention blocks, each followed by MLP
  vlm     : blocks of [1 gated cross-attn layer + (every-1) self layers]
  encdec  : enc_layers x [bidir self-attn, MLP] + L x [causal self-attn,
            cross-attn, MLP]  (audio frontend stubbed: frame embeddings in)

Public entry points:
  model_specs(cfg)                  -> Spec pytree (shapes + logical axes)
  forward(params, cfg, batch)       -> (final hidden states, aux losses)
  cache_specs(cfg, batch, cache_len)-> Spec pytree for the decode cache
  prefill(params, cfg, batch)       -> (hidden_last, cache)
  decode_step(params, cfg, token, cache) -> (hidden (B,1,D), cache)

Logits are intentionally NOT produced here — steps.py computes the loss in
sequence chunks against the (possibly vocab-sharded) head to bound memory.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attn_specs, cross_attn_specs, cross_attention,
                        decode_self_attention, self_attention)
from .config import ModelConfig
from .moe import moe_apply, moe_specs
from .nn import embed_specs, mlp_apply, mlp_specs, rms_norm
from .params import Spec
from .rglru import (rglru_cache_specs, rglru_decode_step, rglru_forward,
                    rglru_specs)
from .ssm import (mamba_cache_specs, mamba_decode_step, mamba_forward,
                  mamba_specs)
from ..pshard import constrain

__all__ = ["model_specs", "forward", "cache_specs", "prefill", "decode_step",
           "hybrid_counts"]


# --------------------------------------------------------------------------
# spec helpers
# --------------------------------------------------------------------------

def stack_specs(tree: Any, n: int, extra_axes: Tuple[int, ...] = ()) -> Any:
    """Prepend stacked layer dims (n, *extra) to every Spec in the tree."""
    dims = (n,) + extra_axes

    def f(s: Spec) -> Spec:
        return Spec(dims + s.shape, (None,) * len(dims) + s.axes, s.init,
                    s.scale, s.dtype)

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Spec))


def hybrid_counts(cfg: ModelConfig):
    pat = cfg.layer_pattern
    tiles = cfg.n_layers // len(pat)
    rem = cfg.layer_pattern[: cfg.n_layers % len(pat)]
    n_r = tiles * pat.count("R") + rem.count("R")
    n_a = tiles * pat.count("A") + rem.count("A")
    return tiles, rem, n_r, n_a


def _dense_layer_specs(cfg: ModelConfig) -> dict:
    return {"attn": attn_specs(cfg),
            "mlp": {"ln": Spec((cfg.d_model,), ("model_dim",), "zeros"),
                    **mlp_specs(cfg)}}


def _moe_layer_specs(cfg: ModelConfig) -> dict:
    return {"attn": attn_specs(cfg),
            "moe": {"ln": Spec((cfg.d_model,), ("model_dim",), "zeros"),
                    **moe_specs(cfg)}}


def model_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs: Dict[str, Any] = {"embed": embed_specs(cfg),
                             "final_ln": Spec((d,), ("model_dim",), "zeros")}
    if not cfg.tie_embeddings:
        pass  # head included by embed_specs
    if cfg.family == "dense":
        specs["layers"] = stack_specs(_dense_layer_specs(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        n_moe = cfg.n_layers // cfg.moe_every
        specs["layers"] = stack_specs(_moe_layer_specs(cfg), n_moe)
        if cfg.moe_every > 1:   # interleaved: (moe_every-1) dense per MoE
            specs["dense_layers"] = stack_specs(_dense_layer_specs(cfg), n_moe,
                                                (cfg.moe_every - 1,))
    elif cfg.family == "ssm":
        specs["layers"] = stack_specs(mamba_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        tiles, rem, n_r, n_a = hybrid_counts(cfg)
        rl = {"temporal": rglru_specs(cfg),
              "mlp": {"ln": Spec((d,), ("model_dim",), "zeros"), **mlp_specs(cfg)}}
        al = _dense_layer_specs(cfg)
        specs["r_layers"] = stack_specs(rl, n_r)
        specs["a_layers"] = stack_specs(al, n_a)
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        nb = cfg.n_layers // every
        xl = {"xattn": cross_attn_specs(cfg, cfg.vis_dim),
              "mlp": {"ln": Spec((d,), ("model_dim",), "zeros"), **mlp_specs(cfg)},
              "gate_mlp": Spec((), (), "zeros")}
        specs["x_layers"] = stack_specs(xl, nb)
        specs["self_layers"] = stack_specs(_dense_layer_specs(cfg), nb, (every - 1,))
    elif cfg.family == "encdec":
        el = _dense_layer_specs(cfg)
        dl = {"attn": attn_specs(cfg),
              "xattn": cross_attn_specs(cfg),
              "mlp": {"ln": Spec((d,), ("model_dim",), "zeros"), **mlp_specs(cfg)}}
        specs["enc_layers"] = stack_specs(el, cfg.enc_layers)
        specs["dec_layers"] = stack_specs(dl, cfg.n_layers)
        specs["enc_final_ln"] = Spec((d,), ("model_dim",), "zeros")
        if cfg.audio_frontend:
            specs["audio_proj"] = Spec((cfg.d_model, d), (None, "model_dim"), "scaled")
    else:
        raise ValueError(cfg.family)
    return specs


# --------------------------------------------------------------------------
# layer bodies (training / prefill)
# --------------------------------------------------------------------------

def _dense_body(cfg: ModelConfig, x, wl, *, causal=True, window=0):
    a, _ = self_attention(wl["attn"], cfg, x, causal=causal, window=window)
    x = constrain(x + a, "batch", None, "model_dim")
    h = rms_norm(x, wl["mlp"]["ln"], cfg.norm_eps)
    x = x + mlp_apply(wl["mlp"], cfg, h)
    return constrain(x, "batch", None, "model_dim")


def _moe_body(cfg: ModelConfig, x, wl):
    a, _ = self_attention(wl["attn"], cfg, x)
    x = constrain(x + a, "batch", None, "model_dim")
    h = rms_norm(x, wl["moe"]["ln"], cfg.norm_eps)
    mo, aux = moe_apply(wl["moe"], cfg, h)
    return constrain(x + mo, "batch", None, "model_dim"), aux


def _rg_body(cfg: ModelConfig, x, wl):
    t, _ = rglru_forward(wl["temporal"], cfg, x)
    x = x + t
    h = rms_norm(x, wl["mlp"]["ln"], cfg.norm_eps)
    return x + mlp_apply(wl["mlp"], cfg, h)


def _xattn_body(cfg: ModelConfig, x, wl, memory):
    x = x + cross_attention(wl["xattn"], cfg, x, memory)
    h = rms_norm(x, wl["mlp"]["ln"], cfg.norm_eps)
    gate = jnp.tanh(wl["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * mlp_apply(wl["mlp"], cfg, h)


def _decdec_body(cfg: ModelConfig, x, wl, memory):
    a, _ = self_attention(wl["attn"], cfg, x, causal=True)
    x = x + a
    x = x + cross_attention(wl["xattn"], cfg, x, memory)
    h = rms_norm(x, wl["mlp"]["ln"], cfg.norm_eps)
    return x + mlp_apply(wl["mlp"], cfg, h)


def _scan_layers(body, x, stacked, *static):
    """scan over stacked layer weights with full remat."""
    wrapped = jax.checkpoint(lambda x, wl: body(x, wl, *static))

    def f(x, wl):
        return wrapped(x, wl), None

    x, _ = jax.lax.scan(f, x, stacked)
    return x


def _embed(params, cfg: ModelConfig, tokens):
    x = params["embed"]["tok"].astype(cfg.cdtype)[tokens]
    return constrain(x, "batch", None, "model_dim")


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Full-sequence forward to final hidden states.

    batch keys: tokens (B,S) [decoder tokens]; vlm: vis_emb (B,M,vis_dim);
    encdec: enc_emb (B,M,d_model) — stubbed modality frontends."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        mem = batch["enc_emb"].astype(cfg.cdtype)
        mem = _scan_layers(functools.partial(_dense_body, cfg, causal=False),
                           mem, params["enc_layers"])
        mem = rms_norm(mem, params["enc_final_ln"], cfg.norm_eps)
        x = _embed(params, cfg, batch["tokens"])
        x = _scan_layers(lambda x, wl: _decdec_body(cfg, x, wl, mem),
                         x, params["dec_layers"])
    elif cfg.family == "vlm":
        mem = batch["vis_emb"]
        x = _embed(params, cfg, batch["tokens"])
        every = cfg.cross_attn_every

        def block(x, wl):
            x = jax.checkpoint(lambda x, w: _xattn_body(cfg, x, w, mem))(x, wl["x"])
            return _scan_layers(functools.partial(_dense_body, cfg), x, wl["s"]), None

        x, _ = jax.lax.scan(block, x, {"x": params["x_layers"],
                                       "s": params["self_layers"]})
    elif cfg.family == "hybrid":
        x = _embed(params, cfg, batch["tokens"])
        tiles, rem, n_r, n_a = hybrid_counts(cfg)
        pat = cfg.layer_pattern
        rpt, apt = pat.count("R"), pat.count("A")
        r_main = jax.tree.map(lambda w: w[: tiles * rpt].reshape((tiles, rpt) + w.shape[1:]),
                              params["r_layers"])
        a_main = jax.tree.map(lambda w: w[: tiles * apt].reshape((tiles, apt) + w.shape[1:]),
                              params["a_layers"])

        def tile(x, wl):
            ri = ai = 0
            for kind in pat:
                if kind == "R":
                    w = jax.tree.map(lambda v, i=ri: v[i], wl["r"])
                    x = jax.checkpoint(lambda x, w: _rg_body(cfg, x, w))(x, w)
                    ri += 1
                else:
                    w = jax.tree.map(lambda v, i=ai: v[i], wl["a"])
                    x = jax.checkpoint(functools.partial(
                        _dense_body, cfg, window=cfg.local_window))(x, w)
                    ai += 1
            return x, None

        x, _ = jax.lax.scan(tile, x, {"r": r_main, "a": a_main})
        # remainder layers (pattern prefix)
        ri, ai = tiles * rpt, tiles * apt
        for kind in rem:
            if kind == "R":
                w = jax.tree.map(lambda v, i=ri: v[i], params["r_layers"])
                x = _rg_body(cfg, x, w)
                ri += 1
            else:
                w = jax.tree.map(lambda v, i=ai: v[i], params["a_layers"])
                x = _dense_body(cfg, x, w, window=cfg.local_window)
                ai += 1
    elif cfg.family == "ssm":
        x = _embed(params, cfg, batch["tokens"])

        def body(x, wl):
            o, _ = mamba_forward(wl, cfg, x)
            return constrain(x + o, "batch", None, "model_dim")

        x = _scan_layers(body, x, params["layers"])
    elif cfg.family == "moe":
        x = _embed(params, cfg, batch["tokens"])
        moe_wrapped = jax.checkpoint(lambda x, wl: _moe_body(cfg, x, wl))
        if cfg.moe_every > 1:
            dense_wrapped = jax.checkpoint(functools.partial(_dense_body, cfg))

            def f(x, wl):
                for j in range(cfg.moe_every - 1):
                    dj = jax.tree.map(lambda w, j=j: w[j], wl["d"])
                    x = dense_wrapped(x, dj)
                return moe_wrapped(x, wl["m"])

            x, auxs = jax.lax.scan(f, x, {"d": params["dense_layers"],
                                          "m": params["layers"]})
        else:
            x, auxs = jax.lax.scan(moe_wrapped, x, params["layers"])
        aux = auxs.mean()
    else:  # dense
        x = _embed(params, cfg, batch["tokens"])
        x = _scan_layers(functools.partial(_dense_body, cfg), x, params["layers"])

    return rms_norm(x, params["final_ln"], cfg.norm_eps), aux


# --------------------------------------------------------------------------
# decode cache
# --------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, cache_len: int,
                mem_len: int = 0) -> dict:
    """Decode-cache Spec tree.  mem_len: cross-attention memory length
    (image tokens / encoder frames) for vlm/encdec."""
    KV, hd = cfg.n_kv, cfg.head_dim
    kv_axes = ("batch", "kv_seq", "kv_heads", None)

    def kv(n_layers, length):
        return {
            "k": Spec((n_layers, batch, length, KV, hd), (None,) + kv_axes, "zeros"),
            "v": Spec((n_layers, batch, length, KV, hd), (None,) + kv_axes, "zeros"),
        }

    specs: Dict[str, Any] = {"pos": Spec((), (), "zeros", dtype="int32")}
    if cfg.family in ("dense", "moe"):
        specs.update(kv(cfg.n_layers, cache_len))
    elif cfg.family == "ssm":
        specs["ssm"] = stack_specs(mamba_cache_specs(cfg, batch), cfg.n_layers)
    elif cfg.family == "hybrid":
        tiles, rem, n_r, n_a = hybrid_counts(cfg)
        length = min(cache_len, cfg.local_window) if cfg.local_window else cache_len
        specs.update(kv(n_a, length))
        specs["rg"] = stack_specs(rglru_cache_specs(cfg, batch), n_r)
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        nb = cfg.n_layers // every
        specs.update(kv(nb * (every - 1), cache_len))
        mem = mem_len or cfg.vis_tokens
        # precomputed cross K/V over the image memory
        specs["xk"] = Spec((nb, batch, mem, KV, hd), (None,) + kv_axes, "zeros")
        specs["xv"] = Spec((nb, batch, mem, KV, hd), (None,) + kv_axes, "zeros")
    elif cfg.family == "encdec":
        specs.update(kv(cfg.n_layers, cache_len))
        mem = mem_len or 1
        specs["xk"] = Spec((cfg.n_layers, batch, mem, KV, hd), (None,) + kv_axes, "zeros")
        specs["xv"] = Spec((cfg.n_layers, batch, mem, KV, hd), (None,) + kv_axes, "zeros")
    return specs


# --------------------------------------------------------------------------
# decode bodies
# --------------------------------------------------------------------------

def _mlp_res(cfg, x, wl):
    h = rms_norm(x, wl["mlp"]["ln"], cfg.norm_eps)
    return x + mlp_apply(wl["mlp"], cfg, h)


def _dense_decode(cfg, x, wl, ck, cv, pos, window=0):
    a, ck, cv = decode_self_attention(wl["attn"], cfg, x, ck, cv, pos,
                                      window=window)
    return _mlp_res(cfg, x + a, wl), ck, cv


def _moe_decode(cfg, x, wl, ck, cv, pos):
    a, ck, cv = decode_self_attention(wl["attn"], cfg, x, ck, cv, pos)
    x = x + a
    h = rms_norm(x, wl["moe"]["ln"], cfg.norm_eps)
    mo, _ = moe_apply(wl["moe"], cfg, h)
    return x + mo, ck, cv


def _cross_cached(p, cfg: ModelConfig, x, xk, xv):
    """Cross-attention against precomputed memory K/V.  x: (B,1,D)."""
    from .attention import NEG_INF  # local import to avoid cycle at module top
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    B = h.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    G = H // KV
    dt = x.dtype
    q = (h @ p["wq"].astype(dt)).reshape(B, 1, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, xk.astype(jnp.float32)) / (hd ** 0.5)
    pr = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr, xv.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(dt)
    out = o @ p["wo"].astype(dt)
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(dt) * out


def _precompute_cross_kv(p, cfg: ModelConfig, memory):
    KV, hd = cfg.n_kv, cfg.head_dim
    B, M, _ = memory.shape
    kv = memory.astype(cfg.cdtype) @ p["wkv"].astype(cfg.cdtype)
    k = kv[..., : KV * hd].reshape(B, M, KV, hd)
    v = kv[..., KV * hd:].reshape(B, M, KV, hd)
    return k, v


# --------------------------------------------------------------------------
# prefill: forward + cache construction
# --------------------------------------------------------------------------

def _ring_from_prefill(k, window: int, S: int):
    """Arrange the last `window` keys so that slot(p) = p % window."""
    last = k[:, -window:]
    return jnp.roll(last, S % window, axis=1)


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache_len: Optional[int] = None):
    """Run the full prompt and build the decode cache.

    Returns (hidden_last (B,1,D), cache).  cache_len >= S (kv families)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    aux = jnp.zeros((), jnp.float32)
    cache: Dict[str, Any] = {"pos": jnp.asarray(S, jnp.int32)}

    def pad_kv(k):
        if k.shape[2] == cache_len:
            return k
        pad = cache_len - k.shape[2]
        return jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))

    if cfg.family in ("dense", "moe"):
        x = _embed(params, cfg, tokens)

        def f_one(x, wl, is_moe):
            a, kv = self_attention(wl["attn"], cfg, x)
            x = constrain(x + a, "batch", None, "model_dim")
            if is_moe:
                h = rms_norm(x, wl["moe"]["ln"], cfg.norm_eps)
                mo, _ = moe_apply(wl["moe"], cfg, h)
                x = x + mo
            else:
                x = _mlp_res(cfg, x, wl)
            return x, kv

        if cfg.family == "moe" and cfg.moe_every > 1:
            def f(x, wl):
                kvs = []
                for j in range(cfg.moe_every - 1):
                    dj = jax.tree.map(lambda w, j=j: w[j], wl["d"])
                    x, kv = f_one(x, dj, False)
                    kvs.append(kv)
                x, kv = f_one(x, wl["m"], True)
                kvs.append(kv)
                ks = jnp.stack([k for k, _ in kvs])
                vs = jnp.stack([v for _, v in kvs])
                return x, (ks, vs)

            x, (ks, vs) = jax.lax.scan(
                f, x, {"d": params["dense_layers"], "m": params["layers"]})
            # (n_pairs, moe_every, B, S, KV, hd) -> (n_layers, ...)
            ks = ks.reshape((cfg.n_layers,) + ks.shape[2:])
            vs = vs.reshape((cfg.n_layers,) + vs.shape[2:])
        else:
            is_moe = cfg.family == "moe"
            x, (ks, vs) = jax.lax.scan(
                lambda x, wl: f_one(x, wl, is_moe), x, params["layers"])
        cache["k"] = pad_kv(ks)
        cache["v"] = pad_kv(vs)
    elif cfg.family == "ssm":
        x = _embed(params, cfg, tokens)

        def f(x, wl):
            o, (conv, state) = mamba_forward(wl, cfg, x)
            return x + o, {"conv": conv, "state": state}

        x, ssm_cache = jax.lax.scan(f, x, params["layers"])
        cache["ssm"] = ssm_cache
    elif cfg.family == "hybrid":
        x = _embed(params, cfg, tokens)
        tiles, rem, n_r, n_a = hybrid_counts(cfg)
        pat = cfg.layer_pattern
        W = min(cache_len, cfg.local_window) if cfg.local_window else cache_len
        rpt, apt = pat.count("R"), pat.count("A")
        r_main = jax.tree.map(lambda w: w[: tiles * rpt].reshape((tiles, rpt) + w.shape[1:]),
                              params["r_layers"])
        a_main = jax.tree.map(lambda w: w[: tiles * apt].reshape((tiles, apt) + w.shape[1:]),
                              params["a_layers"])

        def r_step(x, wl):
            t, (conv, hlast) = rglru_forward(wl["temporal"], cfg, x)
            return _mlp_res(cfg, x + t, wl), {"conv": conv, "h": hlast}

        def a_step(x, wl):
            a, (k, v) = self_attention(wl["attn"], cfg, x, window=cfg.local_window)
            return _mlp_res(cfg, x + a, wl), (k, v)

        def tile(x, wl):
            ri = ai = 0
            rgs, kvs = [], []
            for kind in pat:
                if kind == "R":
                    x, c = r_step(x, jax.tree.map(lambda v, i=ri: v[i], wl["r"]))
                    rgs.append(c)
                    ri += 1
                else:
                    x, kv = a_step(x, jax.tree.map(lambda v, i=ai: v[i], wl["a"]))
                    kvs.append(kv)
                    ai += 1
            rg = jax.tree.map(lambda *xs: jnp.stack(xs), *rgs)
            ks = jnp.stack([k for k, _ in kvs])
            vs = jnp.stack([v for _, v in kvs])
            return x, (rg, ks, vs)

        x, (rg_c, ks, vs) = jax.lax.scan(tile, x, {"r": r_main, "a": a_main})
        rg_list = [jax.tree.map(lambda w: w.reshape((tiles * rpt,) + w.shape[2:]), rg_c)]
        k_parts = [ks.reshape((tiles * apt,) + ks.shape[2:])]
        v_parts = [vs.reshape((tiles * apt,) + vs.shape[2:])]
        ri, ai = tiles * rpt, tiles * apt
        for kind in rem:   # remainder layers (pattern prefix), unrolled
            if kind == "R":
                wl = jax.tree.map(lambda v, i=ri: v[i], params["r_layers"])
                x, c = r_step(x, wl)
                rg_list.append(jax.tree.map(lambda w: w[None], c))
                ri += 1
            else:
                wl = jax.tree.map(lambda v, i=ai: v[i], params["a_layers"])
                x, (k, v) = a_step(x, wl)
                k_parts.append(k[None])
                v_parts.append(v[None])
                ai += 1
        k_all = jnp.concatenate(k_parts) if len(k_parts) > 1 else k_parts[0]
        v_all = jnp.concatenate(v_parts) if len(v_parts) > 1 else v_parts[0]
        if cfg.local_window and S >= W:
            k_all = jnp.roll(k_all[:, :, -W:], S % W, axis=2)
            v_all = jnp.roll(v_all[:, :, -W:], S % W, axis=2)
        cache["k"] = k_all
        cache["v"] = v_all
        cache["rg"] = jax.tree.map(lambda *xs: jnp.concatenate(xs), *rg_list) \
            if len(rg_list) > 1 else rg_list[0]
    elif cfg.family == "vlm":
        mem = batch["vis_emb"]
        x = _embed(params, cfg, tokens)
        every = cfg.cross_attn_every
        nb = cfg.n_layers // every

        def block(x, wl):
            xk, xv = _precompute_cross_kv(wl["x"]["xattn"], cfg, mem)
            x = _xattn_body(cfg, x, wl["x"], mem)

            def self_step(x, ws):
                a, kv = self_attention(ws["attn"], cfg, x)
                return _mlp_res(cfg, x + a, ws), kv

            x, (ks, vs) = jax.lax.scan(self_step, x, wl["s"])
            return x, (ks, vs, xk, xv)

        x, (ks, vs, xks, xvs) = jax.lax.scan(
            block, x, {"x": params["x_layers"], "s": params["self_layers"]})
        cache["k"] = pad_kv(ks.reshape((nb * (every - 1),) + ks.shape[2:]))
        cache["v"] = pad_kv(vs.reshape((nb * (every - 1),) + vs.shape[2:]))
        cache["xk"] = xks
        cache["xv"] = xvs
    elif cfg.family == "encdec":
        mem = batch["enc_emb"].astype(cfg.cdtype)
        mem = _scan_layers(functools.partial(_dense_body, cfg, causal=False),
                           mem, params["enc_layers"])
        mem = rms_norm(mem, params["enc_final_ln"], cfg.norm_eps)
        x = _embed(params, cfg, tokens)

        def dec_step(x, wl):
            a, (k, v) = self_attention(wl["attn"], cfg, x)
            x = x + a
            xk, xv = _precompute_cross_kv(wl["xattn"], cfg, mem)
            x = x + cross_attention(wl["xattn"], cfg, x, mem)
            return _mlp_res(cfg, x, wl), (k, v, xk, xv)

        x, (ks, vs, xks, xvs) = jax.lax.scan(dec_step, x, params["dec_layers"])
        cache["k"] = pad_kv(ks)
        cache["v"] = pad_kv(vs)
        cache["xk"] = xks
        cache["xv"] = xvs
    else:
        raise ValueError(cfg.family)

    # pin the stacked caches to the decode sharding (kv_seq/kv_heads over
    # "model"): the per-layer k/v are batch-sharded only (kv heads often
    # don't divide the model axis), and without this constraint the stacked
    # prefill output cache materializes seq-replicated — measured
    # 11.9 GiB/dev instead of 0.75 GiB/dev on deepseek-67b prefill_32k.
    for name in ("k", "v", "xk", "xv"):
        if name in cache:
            cache[name] = constrain(cache[name], None, "batch", "kv_seq",
                                    "kv_heads", None)

    h = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return h[:, -1:, :], cache


# --------------------------------------------------------------------------
# single-token decode
# --------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, token: jax.Array, cache: dict):
    """token: (B,1) int32 -> (hidden (B,1,D), updated cache)."""
    pos = cache["pos"]
    x = _embed(params, cfg, token)
    new_cache = dict(cache)
    new_cache["pos"] = pos + 1

    if cfg.family in ("dense", "moe"):
        if cfg.family == "moe" and cfg.moe_every > 1:
            E = cfg.moe_every
            n_pairs = cfg.n_layers // E
            ck_p = jax.tree.map(
                lambda w: w.reshape((n_pairs, E) + w.shape[1:]), cache["k"])
            cv_p = jax.tree.map(
                lambda w: w.reshape((n_pairs, E) + w.shape[1:]), cache["v"])

            def f(x, wl_c):
                wl, ck, cv = wl_c
                ks, vs = [], []
                for j in range(E - 1):
                    dj = jax.tree.map(lambda w, j=j: w[j], wl["d"])
                    x, k1, v1 = _dense_decode(cfg, x, dj, ck[j], cv[j], pos)
                    ks.append(k1)
                    vs.append(v1)
                x, k1, v1 = _moe_decode(cfg, x, wl["m"], ck[E - 1], cv[E - 1], pos)
                ks.append(k1)
                vs.append(v1)
                return x, (jnp.stack(ks), jnp.stack(vs))

            x, (ks, vs) = jax.lax.scan(
                f, x, ({"d": params["dense_layers"], "m": params["layers"]},
                       ck_p, cv_p))
            new_cache["k"] = ks.reshape((cfg.n_layers,) + ks.shape[2:])
            new_cache["v"] = vs.reshape((cfg.n_layers,) + vs.shape[2:])
        else:
            def f(x, wl_c):
                wl, ck, cv = wl_c
                if cfg.family == "moe":
                    x, ck, cv = _moe_decode(cfg, x, wl, ck, cv, pos)
                else:
                    x, ck, cv = _dense_decode(cfg, x, wl, ck, cv, pos)
                return x, (ck, cv)

            x, (ks, vs) = jax.lax.scan(
                f, x, (params["layers"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = ks, vs
    elif cfg.family == "ssm":
        def f(x, wl_c):
            wl, c = wl_c
            o, c2 = mamba_decode_step(wl, cfg, x, c)
            return x + o, c2

        x, ssm_cache = jax.lax.scan(f, x, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = ssm_cache
    elif cfg.family == "hybrid":
        pat = cfg.layer_pattern
        ri = ai = 0
        rg_new, k_new, v_new = [], [], []
        for li in range(cfg.n_layers):
            kind = (pat * cfg.n_layers)[li]
            if kind == "R":
                wl = jax.tree.map(lambda v, i=ri: v[i], params["r_layers"])
                c = jax.tree.map(lambda v, i=ri: v[i], cache["rg"])
                t, c2 = rglru_decode_step(wl["temporal"], cfg, x, c)
                x = _mlp_res(cfg, x + t, wl)
                rg_new.append(c2)
                ri += 1
            else:
                wl = jax.tree.map(lambda v, i=ai: v[i], params["a_layers"])
                x, ck, cv = _dense_decode(cfg, x, wl, cache["k"][ai],
                                          cache["v"][ai], pos,
                                          window=cfg.local_window)
                k_new.append(ck)
                v_new.append(cv)
                ai += 1
        new_cache["rg"] = jax.tree.map(lambda *xs: jnp.stack(xs), *rg_new)
        new_cache["k"] = jnp.stack(k_new)
        new_cache["v"] = jnp.stack(v_new)
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        nb = cfg.n_layers // every
        k_new, v_new = [], []
        si_flat = 0
        for bi in range(nb):
            wx = jax.tree.map(lambda v, i=bi: v[i], params["x_layers"])
            x = x + _cross_cached(wx["xattn"], cfg, x, cache["xk"][bi],
                                  cache["xv"][bi])
            h = rms_norm(x, wx["mlp"]["ln"], cfg.norm_eps)
            gate = jnp.tanh(wx["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * mlp_apply(wx["mlp"], cfg, h)
            for si in range(every - 1):
                ws = jax.tree.map(lambda v, i=bi, j=si: v[i, j], params["self_layers"])
                x, ck, cv = _dense_decode(cfg, x, ws, cache["k"][si_flat],
                                          cache["v"][si_flat], pos)
                k_new.append(ck)
                v_new.append(cv)
                si_flat += 1
        new_cache["k"] = jnp.stack(k_new)
        new_cache["v"] = jnp.stack(v_new)
    elif cfg.family == "encdec":
        def f(x, wl_c):
            wl, ck, cv, xk, xv = wl_c
            a, ck, cv = decode_self_attention(wl["attn"], cfg, x, ck, cv, pos)
            x = x + a
            x = x + _cross_cached(wl["xattn"], cfg, x, xk, xv)
            x = _mlp_res(cfg, x, wl)
            return x, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            f, x, (params["dec_layers"], cache["k"], cache["v"],
                   cache["xk"], cache["xv"]))
        new_cache["k"], new_cache["v"] = ks, vs
    else:
        raise ValueError(cfg.family)

    return rms_norm(x, params["final_ln"], cfg.norm_eps), new_cache
