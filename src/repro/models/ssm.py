"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation within chunks, a linear `lax.scan` recurrence across chunk
states (O(S) memory, sub-quadratic compute — this is why the ssm family
runs the 500K-token shape).  Decode is the pure recurrence on a
(B, H, P, N) state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .nn import rms_norm
from .params import Spec
from ..pshard import constrain

__all__ = ["mamba_specs", "mamba_forward", "mamba_decode_step", "mamba_cache_specs"]


def mamba_specs(cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    return {
        "ln": Spec((d,), ("model_dim",), "zeros"),
        # order: [z (di) | x (di) | B (n) | C (n) | dt (h)]
        "in_proj": Spec((d, 2 * di + 2 * n + h), ("model_dim", "ff"), "scaled"),
        "conv_w": Spec((cfg.conv_width, conv_dim), (None, "ff"), "scaled"),
        "conv_b": Spec((conv_dim,), ("ff",), "zeros"),
        "A_log": Spec((h,), (None,), "ones"),
        "D": Spec((h,), (None,), "ones"),
        "dt_bias": Spec((h,), (None,), "zeros"),
        "norm": Spec((di,), ("ff",), "zeros"),
        "out_proj": Spec((di, d), ("ff", "model_dim"), "scaled"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. xbc: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for t in range(W):
        out = out + pad[:, t: t + xbc.shape[1], :].astype(jnp.float32) * w[t].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD scan.  x: (B,S,H,P); dt: (B,S,H) (post-softplus);
    A: (H,) negative; Bm/Cm: (B,S,N) (single group).  Returns (B,S,H,P) and
    the final state (B,H,P,N)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    S_orig = S
    pad = (-S) % chunk
    if pad:
        # identity padding: dt = 0 -> zero input contribution and unit decay,
        # so the final state is exact and padded outputs are discarded
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    c = S // chunk
    f32 = jnp.float32
    xd = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(Bsz, c, chunk, H, P)
    a = (dt.astype(f32) * A.astype(f32)).reshape(Bsz, c, chunk, H)   # log-decay
    B_ = Bm.astype(f32).reshape(Bsz, c, chunk, N)
    C_ = Cm.astype(f32).reshape(Bsz, c, chunk, N)

    a_cum = jnp.cumsum(a, axis=2)                                   # (B,c,T,H)
    # intra-chunk (attention-like): L[i,j] = exp(a_cum[i] - a_cum[j]) for j<=i
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]         # (B,c,T,T,H)
    ti = jnp.arange(chunk)
    causal = (ti[:, None] >= ti[None, :])[None, None, :, :, None]
    # mask BEFORE exp: the masked (j > i) entries are positive and overflow,
    # and inf in the untaken where-branch poisons the backward with NaNs
    L = jnp.exp(jnp.where(causal, seg, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", C_, B_)                  # (B,c,T,T)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xd)

    # chunk summary states: sum_j exp(a_cum[last] - a_cum[j]) * B_j x_j
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)             # (B,c,T,H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", B_, decay_states, xd)

    # inter-chunk linear recurrence (lax.scan -> O(c), not O(c^2))
    total_decay = jnp.exp(a_cum[:, :, -1, :])                        # (B,c,H)

    def step(s, inp):
        dec, cs = inp                                               # (B,H), (B,H,P,N)
        s_new = s * dec[..., None, None] + cs
        return s_new, s                                             # emit state BEFORE chunk

    s0 = jnp.zeros((Bsz, H, P, N), f32)
    final_state, prev_states = jax.lax.scan(
        step, s0, (total_decay.transpose(1, 0, 2), chunk_states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)              # (B,c,H,P,N)

    # inter-chunk contribution
    state_decay = jnp.exp(a_cum)                                    # (B,c,T,H)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", C_, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)[:, :S_orig]
    return y, final_state


def mamba_forward(p: dict, cfg: ModelConfig, x: jax.Array):
    """Full-sequence forward (train/prefill). Returns (out, (conv_tail, state))."""
    B, S, D = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt_ = x.dtype
    hin = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = hin @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_tail = xbc[:, -(cfg.conv_width - 1):, :]                   # decode cache
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin = xbc[..., :di].reshape(B, S, h, cfg.ssm_headdim)
    Bm = xbc[..., di: di + n]
    Cm = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = _ssd_chunked(xin, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xin.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(dt_), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_), (conv_tail, state.astype(jnp.float32))


def mamba_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    return {
        "conv": Spec((batch, cfg.conv_width - 1, conv_dim), ("batch", None, "ff"), "zeros"),
        "state": Spec((batch, h, cfg.ssm_headdim, n), ("batch", None, None, None), "zeros", dtype="float32"),
    }


def mamba_decode_step(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict):
    """Single-token recurrence.  x: (B,1,D); cache: {conv (B,W-1,C), state}."""
    B = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt_ = x.dtype
    hin = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = hin @ p["in_proj"].astype(dt_)
    z, xbc_t, dt_raw = _split_proj(cfg, zxbcdt)                     # (B,1,*)
    window = jnp.concatenate([cache["conv"], xbc_t], axis=1)        # (B,W,C)
    conv_out = (window.astype(jnp.float32) * p["conv_w"].astype(jnp.float32)[None]
                ).sum(axis=1, keepdims=True) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out).astype(dt_)                         # (B,1,C)
    xin = xbc[..., :di].reshape(B, h, cfg.ssm_headdim)
    Bm = xbc[:, 0, di: di + n]                                      # (B,N)
    Cm = xbc[:, 0, di + n:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))        # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                                # (B,H)
    s = cache["state"]                                              # (B,H,P,N)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xin.astype(jnp.float32), Bm.astype(jnp.float32))
    s = s * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), s)
    y = y + xin.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(dt_), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    new_cache = {"conv": window[:, 1:, :], "state": s}
    return out, new_cache
