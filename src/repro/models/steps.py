"""Train / serve step builders.

Loss is computed in sequence chunks against the (possibly vocab-sharded)
head so (B, S, V) logits are never resident: at 1M tokens x 256K vocab
that's the difference between 1 TB of fp32 logits and a bounded scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .nn import softmax_xent
from .transformer import cache_specs, decode_step, forward, prefill
from ..optim import AdamWConfig, adamw_update, compress_decompress, \
    init_error_state, init_opt_state
from ..pshard import constrain, constrain_tree

__all__ = ["head_weights", "chunked_xent", "make_loss_fn", "make_train_step",
           "make_prefill_step", "make_decode_step"]


def head_weights(params: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T            # (D, V)
    return params["embed"]["head"]


def chunked_xent(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None, chunk: int = 512) -> jax.Array:
    """Mean next-token xent over (B,S) in S-chunks.  hidden (B,S,D)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # irregular small sequences: single chunk
    n = S // chunk
    hc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = (mask.reshape(B, n, chunk).transpose(1, 0, 2)
          if mask is not None else jnp.ones_like(lc, jnp.float32))

    @jax.checkpoint
    def chunk_nll(h, l, m):
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return ((lse - ll) * m).sum()

    def body(carry, xs):
        h, l, m = xs
        return (carry[0] + chunk_nll(h, l, m), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        hidden, aux = forward(params, cfg, batch)
        labels = batch["tokens"][:, 1:]
        h = hidden[:, :-1, :]
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else None
        loss = chunked_xent(h, head_weights(params, cfg), labels, mask)
        total = loss + cfg.router_aux_weight * aux
        return total, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_compression: bool = False, microbatches: int = 1,
                    param_pspecs=None, grad_dtype=jnp.float32):
    """train_step(state, batch) -> (state, metrics).

    state = {params, opt: {m, v, count}, [err]}.

    microbatches > 1 enables gradient accumulation: the global batch is
    scanned in K slices so per-layer activation residuals scale with B/K —
    this is what fits 95-layer x 1M-token steps in 16 GB/chip HBM.
    param_pspecs (PartitionSpec tree) pins the fp32 gradient accumulator to
    the parameter shardings — without it XLA materializes the accumulator
    with whatever sharding propagation picks (often dropping the FSDP axis,
    a 16x memory regression)."""
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        if microbatches == 1:
            (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            return grads, total, metrics
        K = microbatches

        def resplit(x):
            B = x.shape[0]
            assert B % K == 0, (B, K)
            x = x.reshape((K, B // K) + x.shape[1:])
            return constrain(x, None, "batch", *([None] * (x.ndim - 2)))

        mb = jax.tree.map(resplit, batch)

        def micro(carry, b):
            gsum, lsum, asum = carry
            (total, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, b)
            gsum = jax.tree.map(lambda a, x: (a.astype(jnp.float32)
                                              + x.astype(jnp.float32)).astype(a.dtype),
                                gsum, g)
            gsum = constrain_tree(gsum, param_pspecs)
            return (gsum, lsum + total, asum + metrics["aux"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
        g0 = constrain_tree(g0, param_pspecs)
        (gsum, lsum, asum), _ = jax.lax.scan(
            micro, (g0, jnp.zeros(()), jnp.zeros(())), mb)
        grads = jax.tree.map(lambda g: g / K, gsum)
        return grads, lsum / K, {"loss": lsum / K, "aux": asum / K}

    def train_step(state, batch):
        grads, total, metrics = grads_of(state["params"], batch)
        if grad_compression:
            grads, err = compress_decompress(grads, state["err"])
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        new_state = {"params": new_params, "opt": new_opt}
        if grad_compression:
            new_state["err"] = err
        metrics = {"total": total, **metrics, **opt_metrics}
        return new_state, metrics

    return train_step


def init_train_state(params, grad_compression: bool = False) -> dict:
    state = {"params": params, "opt": init_opt_state(params)}
    if grad_compression:
        state["err"] = init_error_state(params)
    return state


def _logits_last(params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    logits = (hidden @ head_weights(params, cfg).astype(hidden.dtype))
    return constrain(logits.astype(jnp.float32), "batch", None, "vocab")


def make_prefill_step(cfg: ModelConfig, cache_len: Optional[int] = None):
    """prefill_step(params, batch) -> (next_token (B,1), logits, cache)."""

    def prefill_step(params, batch):
        h_last, cache = prefill(params, cfg, batch, cache_len)
        logits = _logits_last(params, cfg, h_last)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """decode_fn(params, token (B,1), cache) -> (next_token, logits, cache)."""

    def decode_fn(params, token, cache):
        h, cache = decode_step(params, cfg, token, cache)
        logits = _logits_last(params, cfg, h)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache

    return decode_fn
