"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses jax.lax.associative_scan over the sequence (log-depth);
decode is a single-step update.  The temporal block is
conv1d(width 4) -> RG-LRU, gated by a GeLU branch, as in Griffin.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .nn import rms_norm
from .params import Spec

__all__ = ["rglru_specs", "rglru_forward", "rglru_decode_step", "rglru_cache_specs"]

_C = 8.0


def _blocks(cfg: ModelConfig) -> int:
    w = cfg.lru_width or cfg.d_model
    nb = cfg.lru_blocks
    while w % nb:
        nb //= 2
    return max(nb, 1)


def rglru_specs(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    nb = _blocks(cfg)
    bw = w // nb
    return {
        "ln": Spec((d,), ("model_dim",), "zeros"),
        "w_x": Spec((d, w), ("model_dim", "ff"), "scaled"),       # x branch
        "w_g": Spec((d, w), ("model_dim", "ff"), "scaled"),       # gate branch
        "conv_w": Spec((cfg.conv_width, w), (None, "ff"), "scaled"),
        "conv_b": Spec((w,), ("ff",), "zeros"),
        # Griffin: block-diagonal recurrence/input gates — with the block dim
        # on the TP axis the gate matmuls never leave the shard (a dense
        # (W,W) gate costs an fp32 all-reduce of (B,S,W) per layer:
        # measured 11.9 GiB/dev of all-reduce on prefill_32k)
        "wa": Spec((nb, bw, bw), ("ff", None, None), "scaled"),
        "wi": Spec((nb, bw, bw), ("ff", None, None), "scaled"),
        "lam": Spec((w,), (None,), "ones"),                       # Lambda
        "w_out": Spec((w, d), ("ff", "model_dim"), "scaled"),
    }


def _gates(p, xc: jax.Array, cfg: ModelConfig):
    """log_a and gated input for the recurrence; fp32, block-diagonal gates."""
    nb, bw = p["wa"].shape[0], p["wa"].shape[1]
    shape = xc.shape
    xb = xc.astype(jnp.float32).reshape(shape[:-1] + (nb, bw))
    r = jax.nn.sigmoid(jnp.einsum("...kb,kbc->...kc", xb,
                                  p["wa"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...kb,kbc->...kc", xb,
                                  p["wi"].astype(jnp.float32)))
    r = r.reshape(shape)
    i = i.reshape(shape)
    x32 = xc.astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x32)
    return a, b


def rglru_forward(p: dict, cfg: ModelConfig, x: jax.Array):
    """Full-sequence forward. x: (B,S,D) -> (out, (conv_tail, h_last))."""
    B, S, D = x.shape
    dt = x.dtype
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = h @ p["w_x"].astype(dt)                                  # (B,S,W)
    gb = h @ p["w_g"].astype(dt)
    conv_tail = xb[:, -(cfg.conv_width - 1):, :]
    # causal depthwise conv
    W = cfg.conv_width
    pad = jnp.pad(xb, ((0, 0), (W - 1, 0), (0, 0)))
    xc = jnp.zeros(xb.shape, jnp.float32)
    for t in range(W):
        xc = xc + pad[:, t: t + S, :].astype(jnp.float32) * p["conv_w"][t].astype(jnp.float32)
    xc = (xc + p["conv_b"].astype(jnp.float32)).astype(dt)

    a, b = _gates(p, xc, cfg)                                     # (B,S,W) fp32
    # linear recurrence h_t = a_t h_{t-1} + b_t via associative scan
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = hs * jax.nn.gelu(gb.astype(jnp.float32))
    out = y.astype(dt) @ p["w_out"].astype(dt)
    return out, (conv_tail, hs[:, -1, :])


def rglru_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": Spec((batch, cfg.conv_width - 1, w), ("batch", None, "ff"), "zeros"),
        "h": Spec((batch, w), ("batch", "ff"), "zeros", dtype="float32"),
    }


def rglru_decode_step(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict):
    """x: (B,1,D); cache {conv (B,W-1,Wd), h (B,Wd)}."""
    B = x.shape[0]
    dt = x.dtype
    hin = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = hin @ p["w_x"].astype(dt)                                # (B,1,W)
    gb = hin @ p["w_g"].astype(dt)
    window = jnp.concatenate([cache["conv"], xb], axis=1)         # (B,W,Wd)
    xc = (window.astype(jnp.float32) * p["conv_w"].astype(jnp.float32)[None]).sum(1) \
        + p["conv_b"].astype(jnp.float32)                          # (B,Wd)
    a, b = _gates(p, xc.astype(dt), cfg)
    h_new = a * cache["h"] + b                                    # (B,Wd) fp32
    y = h_new * jax.nn.gelu(gb[:, 0].astype(jnp.float32))
    out = (y.astype(dt) @ p["w_out"].astype(dt))[:, None, :]
    return out, {"conv": window[:, 1:, :], "h": h_new}
