"""Mixture-of-Experts layer: token-choice top-k routing with sort-based
capacity dispatch, organized per data-parallel *group*.

GSPMD cannot shard a scatter whose indices permute tokens globally (it
replicates the buffers — measured +25 GiB/device on llama4 prefill_32k).
Instead tokens are reshaped to (G, T/G, D) where G = the DP shard count:
every sort/scatter/gather is then *local to a group* (batched over the
sharded leading dim), and the only cross-device movement is the
(G, E, C, D) dispatch buffer resharding from data-sharded groups to
model-sharded experts — i.e. exactly the all-to-all a hand-written
expert-parallel implementation performs.

Supports llama4-style (128 experts, top-1, + shared expert, interleaved)
and phi3.5-moe-style (16 experts, top-2) from the same code path.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .nn import mlp_specs, mlp_apply
from .params import Spec
from ..pshard import ambient_mesh, ambient_rules, constrain

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_dff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    specs = {
        "router": Spec((d, e), ("model_dim", None), "scaled"),
        "w_up": Spec((e, d, 2 * f if gated else f), ("expert", "model_dim", "ff"), "scaled"),
        "w_down": Spec((e, f, d), ("expert", "ff", "model_dim"), "scaled"),
    }
    if cfg.moe_shared_expert:
        specs["shared"] = mlp_specs(cfg)
    return specs


def _dp_groups(n_tokens: int) -> int:
    """Number of DP shards the token dim is split over (1 without a mesh)."""
    mesh = ambient_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ambient_rules().axes_for("batch"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    while g > 1 and n_tokens % g:
        g //= 2
    return max(g, 1)


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * cfg.moe_topk * tokens_per_group
                      / cfg.moe_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (output (B,S,D), aux load-balance loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_topk
    f = cfg.moe_dff or cfg.d_ff
    T = B * S
    dt = x.dtype
    G = _dp_groups(T)
    Tl = T // G
    C = _capacity(cfg, Tl)
    xg = x.reshape(G, Tl, D)
    xg = constrain(xg, "batch", None, None)

    # --- routing (fp32) ------------------------------------------------------
    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (G,Tl,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                    # (G,Tl,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style, computed globally)
    me = probs.mean(axis=(0, 1))                                       # (E,)
    ce = (jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
          .sum(axis=(0, 1, 2))) / (T * K)
    aux = E * jnp.sum(me * ce)

    # --- per-group sort-based capacity dispatch -------------------------------
    flat_e = expert_idx.reshape(G, Tl * K)                             # token-major
    flat_g = gate_vals.reshape(G, Tl * K)
    order = jnp.argsort(flat_e, axis=1, stable=True)                   # (G,TlK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sorted_tok = order // K
    # exclusive-cumsum expert counts -> start offsets per group
    cnt = jax.nn.one_hot(flat_e, E, dtype=jnp.int32).sum(axis=1)       # (G,E)
    starts = jnp.cumsum(cnt, axis=1) - cnt                             # (G,E)
    pos = jnp.arange(Tl * K)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=1)
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)                  # pad row

    src = jnp.take_along_axis(xg, sorted_tok[..., None], axis=1).astype(dt)

    def scatter_rows(buf, idx, vals):
        return buf.at[idx].set(vals, mode="drop")

    buf = jnp.zeros((G, E * C + 1, D), dt)
    buf = jax.vmap(scatter_rows)(buf, dest, src)
    expert_in = buf[:, : E * C].reshape(G, E, C, D)
    expert_in = constrain(expert_in, "batch", "expert", None, None)

    # --- expert FFN (experts sharded over "model": the all-to-all happens
    # in the resharding right above) -------------------------------------------
    h = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"].astype(dt))
    if cfg.act in ("swiglu", "geglu"):
        u, g_ = h[..., :f], h[..., f:]
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = u * act(g_)
    else:
        h = jax.nn.relu(h) ** 2 if cfg.act == "relu2" else jax.nn.silu(h)
    h = constrain(h, "batch", "expert", None, "ff")
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    expert_out = constrain(expert_out, "batch", "expert", None, None)

    # --- combine (back to data-sharded groups) ---------------------------------
    # combine in the compute dtype: fp32 cotangents here force fp32 grad
    # dots + fp32 FSDP all-gathers in the backward (measured +7.5 GiB/dev on
    # llama4 train_4k); each token sums only top-k contributions so bf16
    # accumulation is safe.
    rows = expert_out.reshape(G, E * C, D)
    safe = jnp.where(keep, dest, 0)
    gathered = jnp.take_along_axis(rows, safe[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0).astype(dt)
    wsorted = jnp.take_along_axis(flat_g, order, axis=1)
    contrib = gathered * wsorted[..., None].astype(dt)

    def combine_rows(tok, vals):
        return jnp.zeros((Tl, D), dt).at[tok].add(vals)

    y = jax.vmap(combine_rows)(sorted_tok, contrib)                    # (G,Tl,D)
    y = constrain(y, "batch", None, None)

    if cfg.moe_shared_expert:
        y = y + mlp_apply(p["shared"], cfg, xg)
    return y.reshape(B, S, D), aux
