"""Model configuration for the architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    act: str = "swiglu"         # swiglu | geglu | relu2 | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True

    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_every: int = 1          # 1 = every layer MoE; 2 = interleaved (Llama-4)
    moe_dff: int = 0            # per-expert FFN width (d_ff used for shared/dense)
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (RecurrentGemma: RG-LRU + local attention) ---
    layer_pattern: Tuple[str, ...] = ()   # e.g. ("R","R","A") tiled over n_layers
    local_window: int = 0                 # sliding window for local attention
    lru_width: int = 0
    # Griffin's RG-LRU gates are block-diagonal; with blocks == the TP degree
    # the gate matmuls are shard-local (no collectives in the recurrence)
    lru_blocks: int = 16

    # --- VLM (cross-attention image layers) ---
    cross_attn_every: int = 0   # one cross-attn layer per this many layers
    vis_tokens: int = 0         # stubbed frontend: precomputed patch embeddings
    vis_dim: int = 0

    # --- encoder-decoder (audio: stubbed frame-embedding frontend) ---
    enc_layers: int = 0
    audio_frontend: bool = False

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- attention blocking (jnp online-softmax path; Pallas kernel on TPU) ---
    q_block: int = 512
    kv_block: int = 1024
    attention_impl: str = "blocked"   # blocked | naive | pallas

    #: embedding tables are padded to this multiple so the vocab dim always
    #: divides the model axis (e.g. seamless 256206, mamba2 50280); labels
    #: never reference pad ids, logits over pads train down like any rare id
    pad_vocab_to: int = 128

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.pad_vocab_to) * self.pad_vocab_to

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:         # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv=min(max(self.n_kv * 4 // max(self.n_heads, 1), 1), 4),
            d_ff=256,
            vocab=512,
            q_block=16,
            kv_block=16,
        )
        if self.family == "moe":
            kw.update(moe_experts=4, moe_topk=min(self.moe_topk, 2), moe_dff=128)
        if self.family == "ssm":
            kw.update(ssm_state=16, ssm_headdim=32, ssm_chunk=16, d_model=64,
                      n_heads=1, n_kv=1, d_ff=0)
        if self.family == "hybrid":
            kw.update(layer_pattern=self.layer_pattern, local_window=32,
                      lru_width=128, n_layers=5, n_kv=1, ssm_chunk=16)
        if self.family == "vlm":
            kw.update(cross_attn_every=self.cross_attn_every, vis_tokens=16,
                      vis_dim=128, n_layers=min(self.n_layers, self.cross_attn_every * 2))
        if self.family == "encdec":
            kw.update(enc_layers=2, n_layers=2)
        return self.replace(**kw)
