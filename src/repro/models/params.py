"""Declarative parameter specs.

Modules declare parameters as `Spec(shape, logical_axes, init)` trees; the
same tree materializes real arrays (smoke tests / training), abstract
ShapeDtypeStructs with NamedShardings (multi-pod dry-run), or PartitionSpec
trees (jit in_shardings) — one source of truth for shape + sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..pshard import ShardingRules, ambient_rules, spec_for

__all__ = ["Spec", "materialize", "abstractify", "partition_specs", "count_params"]


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: Optional[str] = None           # None -> caller's default dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def resolved_dtype(self, default):
        return jnp.dtype(self.dtype) if self.dtype is not None else jnp.dtype(default)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def materialize(key: jax.Array, tree: Any, dtype=jnp.float32) -> Any:
    """Create real parameter arrays from a Spec tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        dt = s.resolved_dtype(dtype)
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        elif s.init == "scaled":
            fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[0], 1)
            out.append((jax.random.normal(k, s.shape) / jnp.sqrt(fan_in)).astype(dt))
        else:
            out.append((s.scale * jax.random.normal(k, s.shape)).astype(dt))
    return treedef.unflatten(out)


def abstractify(tree: Any, mesh, dtype=jnp.float32,
                rules: Optional[ShardingRules] = None) -> Any:
    """ShapeDtypeStruct tree with NamedShardings (no allocation; dry-run)."""
    from jax.sharding import NamedSharding

    def conv(s: Spec):
        spec = spec_for(s.shape, s.axes, mesh, rules)
        return jax.ShapeDtypeStruct(s.shape, s.resolved_dtype(dtype),
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(conv, tree, is_leaf=_is_spec)


def partition_specs(tree: Any, mesh, rules: Optional[ShardingRules] = None) -> Any:
    return jax.tree.map(lambda s: spec_for(s.shape, s.axes, mesh, rules),
                        tree, is_leaf=_is_spec)


def count_params(tree: Any) -> int:
    tot = 0
    for s in jax.tree.leaves(tree, is_leaf=_is_spec):
        n = 1
        for d in s.shape:
            n *= d
        tot += n
    return tot
