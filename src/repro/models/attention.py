"""Attention: GQA/MQA self-attention with RoPE, blocked online-softmax
(flash-style) training path, sliding-window (local) variant, cross-attention,
and single-token KV-cache decode.

The blocked path is the compile/dry-run implementation (memory-bounded,
cond-skips fully-masked blocks so causal FLOPs stay ~triangular); the Pallas
kernel in repro.kernels.flash_attention implements the same contract for
real TPUs and is validated against `naive_attention`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .nn import rms_norm, rope
from .params import Spec
from ..pshard import constrain

__all__ = ["attn_specs", "cross_attn_specs", "self_attention", "cross_attention",
           "decode_self_attention", "blocked_attention", "naive_attention"]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# core attention math
# --------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Oracle: full score matrix. q (B,Sq,H,hd); k,v (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _block_mask(q_start, k_start, q_block, kv_block, causal, window):
    qpos = q_start + jnp.arange(q_block)[:, None]
    kpos = k_start + jnp.arange(kv_block)[None, :]
    mask = jnp.ones((q_block, kv_block), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    return mask


def _block_pred(q_start, k_start, q_block, kv_block, causal, window):
    """Scalar predicate: does this (q, kv) block pair have any unmasked
    entry?  lax.cond on it skips fully-masked blocks so causal work stays
    ~triangular and sliding-window work stays O(S*W)."""
    pred = jnp.array(True)
    if causal:
        pred &= k_start < q_start + q_block
    if window:
        pred &= (k_start + kv_block) > (q_start - window + 1)
    return pred


def _flash_fwd_impl(q, k, v, causal, window, q_offset, q_block, kv_block):
    """Online-softmax blocked attention forward.

    Returns out (B,Sq,H,hd) and lse (B,KV,G,Sq) fp32 for the backward."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // q_block, Sk // kv_block
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, nq, q_block, KV, G, hd)
    kb = k.reshape(B, nk, kv_block, KV, hd)
    vb = v.reshape(B, nk, kv_block, KV, hd)

    def q_step(_, iq):
        qi = qg[:, iq].astype(jnp.float32) * scale        # (B,qb,KV,G,hd)
        q_start = q_offset + iq * q_block

        def kv_step(carry, ik):
            k_start = ik * kv_block

            def compute(operands):
                (m, l, acc), ik = operands
                ki = kb[:, ik].astype(jnp.float32)         # (B,kb,KV,hd)
                vi = vb[:, ik].astype(jnp.float32)
                s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki)  # (B,KV,G,qb,kb)
                s = jnp.where(_block_mask(q_start, k_start, q_block, kv_block,
                                          causal, window), s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bkgqs,bskd->bkgqd", p, vi)
                return m_new, l_new, acc_new

            pred = _block_pred(q_start, k_start, q_block, kv_block, causal, window)
            return jax.lax.cond(pred, compute, lambda o: o[0],
                                (carry, ik)), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,KV,G,qb,hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,KV,G,qb)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd).astype(q.dtype)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def blocked_attention_core(q, k, v, causal, window, q_offset, q_block, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_block, kv_block)
    return out


def _flash_vjp_fwd(q, k, v, causal, window, q_offset, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, q_offset, q_block, kv_block, res, dout):
    """Flash-style backward: recompute p per block from (q,k,lse); never
    store the (Sq, Sk) probability matrix.  This is what keeps the 32K-token
    train/prefill cells inside HBM."""
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // q_block, Sk // kv_block
    scale = 1.0 / (hd ** 0.5)
    f32 = jnp.float32

    qg = q.reshape(B, nq, q_block, KV, G, hd)
    kb = k.reshape(B, nk, kv_block, KV, hd)
    vb = v.reshape(B, nk, kv_block, KV, hd)
    dog = dout.reshape(B, nq, q_block, KV, G, hd)
    lse_q = lse.reshape(B, KV, G, nq, q_block)
    # delta[b,kv,g,s] = sum_d dout * out
    delta = (dout.astype(f32) * out.astype(f32)).sum(-1)       # (B,Sq,H)
    delta = delta.reshape(B, nq, q_block, KV, G).transpose(0, 3, 4, 1, 2)

    def q_step(carry, iq):
        dk_acc, dv_acc = carry                                  # (B,Sk,KV,hd) f32
        qi = qg[:, iq].astype(f32) * scale
        doi = dog[:, iq].astype(f32)
        lse_i = lse_q[:, :, :, iq]                              # (B,KV,G,qb)
        delta_i = delta[:, :, :, iq]                            # (B,KV,G,qb)
        q_start = q_offset + iq * q_block

        def kv_step(carry, ik):
            def compute(operands):
                (dq_b, dk_acc, dv_acc), ik = operands
                k_start = ik * kv_block
                ki = jax.lax.dynamic_slice_in_dim(kb, ik, 1, 1)[:, 0].astype(f32)
                vi = jax.lax.dynamic_slice_in_dim(vb, ik, 1, 1)[:, 0].astype(f32)
                s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki)
                s = jnp.where(_block_mask(q_start, k_start, q_block, kv_block,
                                          causal, window), s, NEG_INF)
                p = jnp.exp(s - lse_i[..., None])               # (B,KV,G,qb,kb)
                dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p, doi)
                dp = jnp.einsum("bqkgd,bskd->bkgqs", doi, vi)
                ds = p * (dp - delta_i[..., None])
                dq_b = dq_b + jnp.einsum("bkgqs,bskd->bqkgd", ds, ki) * scale
                dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qi)
                start = ik * kv_block
                upd_k = jax.lax.dynamic_slice_in_dim(dk_acc, start, kv_block, 1)
                dk_acc = jax.lax.dynamic_update_slice_in_dim(
                    dk_acc, upd_k + dk_blk, start, 1)
                upd_v = jax.lax.dynamic_slice_in_dim(dv_acc, start, kv_block, 1)
                dv_acc = jax.lax.dynamic_update_slice_in_dim(
                    dv_acc, upd_v + dv_blk, start, 1)
                return dq_b, dk_acc, dv_acc

            pred = _block_pred(q_start, ik * kv_block, q_block, kv_block,
                               causal, window)
            return jax.lax.cond(pred, compute, lambda o: o[0],
                                (carry, ik)), None

        dq0 = jnp.zeros((B, q_block, KV, G, hd), f32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((B, Sk, KV, hd), f32)
    dv0 = jnp.zeros((B, Sk, KV, hd), f32)
    (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


blocked_attention_core.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def blocked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      q_block=512, kv_block=1024):
    """Flash-style blocked attention with a recompute (custom-VJP) backward.
    Fully-masked blocks are lax.cond-skipped in both directions."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]

    def fit(block, S):
        block = min(block, S)
        while S % block:            # e.g. 1600 image tokens with block 1024
            block //= 2
        return max(block, 1)

    q_block = fit(q_block, Sq)
    kv_block = fit(kv_block, Sk)
    return blocked_attention_core(q, k, v, causal, window, q_offset,
                                  q_block, kv_block)


def attention(q, k, v, cfg: ModelConfig, *, causal=True, window=0, q_offset=0):
    if cfg.attention_impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if cfg.attention_impl == "pallas":
        from ..kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset,
                                      q_block=cfg.q_block, kv_block=cfg.kv_block)
    return blocked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, q_block=cfg.q_block,
                             kv_block=cfg.kv_block)


# --------------------------------------------------------------------------
# modules
# --------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    specs = {
        "ln": Spec((d,), ("model_dim",), "zeros"),
        "wq": Spec((d, H * hd), ("model_dim", "heads"), "scaled"),
        "wkv": Spec((d, 2 * KV * hd), ("model_dim", "kv_heads"), "scaled"),
        "wo": Spec((H * hd, d), ("heads", "model_dim"), "scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = Spec((H * hd,), ("heads",), "zeros")
        specs["bkv"] = Spec((2 * KV * hd,), ("kv_heads",), "zeros")
    return specs


def _project_qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    kv = x @ p["wkv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        kv = kv + p["bkv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = kv[..., : KV * hd].reshape(B, S, KV, hd)
    v = kv[..., KV * hd:].reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    return q, k, v


def self_attention(p, cfg: ModelConfig, x, *, causal=True, window=0):
    """Training/prefill self-attention block body (pre-norm, pre-residual).

    Returns (output, (k, v)) so prefill can build a cache."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, h, positions)
    o = attention(q, k, v, cfg, causal=causal, window=window)
    o = constrain(o, "batch", None, "heads", None)
    out = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    return out, (k, v)


def decode_self_attention(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                          window=0):
    """Single-token decode. x: (B,1,D); cache_k/v: (B,S,KV,hd); pos: scalar
    int32 — number of tokens already in the cache (== index to write) — or
    a (B,) int32 vector of per-row positions for continuous batching,
    where each batch slot decodes at its own depth.

    With a sliding window the cache is a ring buffer of size window (the
    scalar-pos path only; per-row positions are linear-cache only)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    B = h.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    G = H // KV
    S = cache_k.shape[1]
    per_row = getattr(pos, "ndim", 0) == 1
    if per_row:
        if window:
            raise NotImplementedError(
                "per-row decode positions do not support sliding-window "
                "ring caches (continuous batching is linear-cache only)")
        positions = pos[:, None].astype(jnp.int32)          # (B, 1)
    else:
        positions = jnp.full((1, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, h, positions)
    if per_row:
        upd = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
            c, u, (s, 0, 0)))
        cache_k = upd(cache_k, k.astype(cache_k.dtype), pos)
        cache_v = upd(cache_v, v.astype(cache_v.dtype), pos)
        # per-row validity; slots beyond a row's position may alias shared
        # scratch pages of a paged pool, so zero their K/V contributions
        # outright — exp-underflow alone would still propagate NaN/Inf
        # garbage through 0 * NaN in the value einsum.
        valid = jnp.arange(S)[None, :] <= pos[:, None]      # (B, S)
        kc = jnp.where(valid[:, :, None, None], cache_k, 0)
        vc = jnp.where(valid[:, :, None, None], cache_v, 0)
        vmask = valid[:, None, None, None, :]
    else:
        slot = pos % S if window else pos
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
        # ring buffer: entries older than the window are overwritten, so
        # slot validity is simply idx <= pos in both linear and ring cases.
        valid = jnp.arange(S) <= pos
        kc, vc = cache_k, cache_v
        vmask = valid[None, None, None, None, :]
    qg = q.reshape(B, 1, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        kc.astype(jnp.float32)) / (hd ** 0.5)
    scores = jnp.where(vmask, scores, NEG_INF)
    pmax = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - pmax)
    probs = e / e.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, vc.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    out = o @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


def cross_attn_specs(cfg: ModelConfig, mem_dim: Optional[int] = None) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    md = mem_dim or cfg.d_model
    return {
        "ln": Spec((d,), ("model_dim",), "zeros"),
        "wq": Spec((d, H * hd), ("model_dim", "heads"), "scaled"),
        "wkv": Spec((md, 2 * KV * hd), ("model_dim", "kv_heads"), "scaled"),
        "wo": Spec((H * hd, d), ("heads", "model_dim"), "scaled"),
        "gate": Spec((), (), "zeros"),
    }


def cross_attention(p, cfg: ModelConfig, x, memory):
    """Cross-attention to a (B, M, mem_dim) memory (vision patches / encoder
    states).  Gated (tanh) as in Llama-3.2 vision cross-attn layers."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    B, S, _ = h.shape
    M = memory.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = x.dtype
    q = (h @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    kv = memory.astype(dt) @ p["wkv"].astype(dt)
    k = kv[..., : KV * hd].reshape(B, M, KV, hd)
    v = kv[..., KV * hd:].reshape(B, M, KV, hd)
    o = attention(q, k, v, cfg, causal=False)
    out = o.reshape(B, S, -1) @ p["wo"].astype(dt)
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(dt) * out
