"""Shared neural-net primitives: norms, activations, MLPs, RoPE, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import Spec
from ..pshard import constrain

__all__ = ["rms_norm", "mlp_specs", "mlp_apply", "rope", "act_fn",
           "embed_specs", "softmax_xent", "layer_norm"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    # NOTE: deliberately avoids `x.astype(f32)` on the raw input.  Under
    # scan+remat, XLA hoists a loop-invariant convert of the *entire saved
    # residual stack* to fp32 (2x the dominant training buffer — measured
    # +11.9 GiB/device on deepseek-67b train_4k).  Converting after the
    # elementwise square keeps the reduction in fp32 without a hoistable
    # convert(x) in the backward graph.  See EXPERIMENTS.md §Perf.
    dt = x.dtype
    ms = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    return x * inv.astype(dt) * (1.0 + scale).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def act_fn(name: str, x: jax.Array) -> jax.Array:
    if name == "relu2":                    # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    if name == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)                  # swiglu/geglu gate handled by caller


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    specs = {
        "w_up": Spec((d, 2 * f if gated else f), ("model_dim", "ff")),
        "w_down": Spec((f, d), ("ff", "model_dim")),
    }
    return specs


def mlp_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              d_ff: Optional[int] = None) -> jax.Array:
    f = d_ff or cfg.d_ff
    dt = x.dtype
    h = x @ p["w_up"].astype(dt)
    if cfg.act in ("swiglu", "geglu"):
        u, g = h[..., :f], h[..., f:]
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = u * act(g)
    else:
        h = act_fn(cfg.act, h)
    h = constrain(h, "batch", None, "ff")
    return h @ p["w_down"].astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_specs(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab
    # the INPUT table uses its own logical axis: sharding the gather's vocab
    # dim costs an all-reduce of (B,S,D) per step (measured 2 GiB/dev f32 on
    # deepseek prefill); the default rule leaves vocab_in unsharded and
    # FSDP-shards d_model instead, making the gather collective-free.
    specs = {"tok": Spec((v, cfg.d_model), ("vocab_in", "model_dim"), "normal", 0.02)}
    if not cfg.tie_embeddings:
        specs["head"] = Spec((cfg.d_model, v), ("model_dim", "vocab"))
    return specs


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy; logits (..., V) may be vocab-sharded
    (GSPMD partitions the log-sum-exp reductions)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
