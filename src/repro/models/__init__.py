from .config import ModelConfig
from . import attention, moe, nn, params, rglru, ssm, steps, transformer

__all__ = ["ModelConfig", "attention", "moe", "nn", "params", "rglru", "ssm",
           "steps", "transformer"]
