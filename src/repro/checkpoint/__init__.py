from .checkpointer import Checkpointer, restore_resharded

__all__ = ["Checkpointer", "restore_resharded"]
