"""Fault-tolerant checkpointing.

Properties needed at 1000+ nodes, all implemented here at single-process
scale with the same code shape:

* **atomic** — write to `step_XXXX.tmp/`, fsync, rename; a preempted writer
  never corrupts the latest checkpoint;
* **async** — serialization happens on a background thread so the train loop
  keeps stepping (device->host copy is the only sync part);
* **windowed** — keep the most recent K checkpoints, delete older;
* **elastic restore** — checkpoints are stored as plain host arrays with a
  pytree manifest, so they can be restored onto a *different* mesh shape
  (restore_resharded places each leaf with the new sharding).

ECC integration (the paper's mechanism as framework feature): `save` can
attach the ReliableStore parity tree so a restore re-verifies weight
integrity end-to-end.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

try:                              # bfloat16 leaves round-trip as uint16 views
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:               # pragma: no cover - ml_dtypes ships with jax
    _BF16 = None

__all__ = ["Checkpointer", "restore_resharded"]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        # device -> host happens synchronously (consistent snapshot) ...
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()

        def work():
            self._write(step, host_state)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Any) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_state)
        # npz cannot represent bfloat16 (it degrades to a raw V2 void
        # dtype); store those leaves as uint16 bit views and record their
        # indices so restore can view them back losslessly
        bf16 = [i for i, l in enumerate(leaves)
                if _BF16 is not None and l.dtype == _BF16]
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": (l.view(np.uint16) if i in bf16 else l)
                    for i, l in enumerate(leaves)})
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "bf16_leaves": bf16, "time": time.time()}, f)
        old = final + ".old"
        if os.path.isdir(final):
            # re-save of the same step (e.g. after an ECC-triggered restore
            # rolled the loop back): rename over a non-empty dir fails on
            # POSIX.  Move the published snapshot aside rather than deleting
            # it, so a crash between the two renames still leaves a restorable
            # snapshot (.old is invisible to all_steps) — never a window with
            # no published data
            if os.path.isdir(old):
                shutil.rmtree(old)
            os.replace(final, old)
        os.replace(tmp, final)  # atomic publish
        shutil.rmtree(old, ignore_errors=True)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}.old"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def _snapshots(self) -> Dict[int, str]:
        """step -> dir name of every restorable snapshot.  A `.old` aside
        (left if a re-save crashed between its two renames) counts only when
        the published dir for that step is gone — it holds the complete
        pre-crash snapshot.  Recovery never mutates the dir; callers racing
        an in-flight async save should wait() first (TrainLoop.restore
        does), since _write renames the dir being re-saved."""
        finals, olds = {}, {}
        for name in os.listdir(self.dir):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if name.endswith(".old"):
                olds[int(name[:-4].split("_")[1])] = name
            else:
                finals[int(name.split("_")[1])] = name
        for step, name in olds.items():
            finals.setdefault(step, name)
        return finals

    def all_steps(self) -> List[int]:
        return sorted(self._snapshots())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Any:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        name = self._snapshots().get(step)
        if name is None:
            raise FileNotFoundError(f"no checkpoint for step {step} in {self.dir}")
        path = os.path.join(self.dir, name)
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        bf16 = set(manifest.get("bf16_leaves", ()))   # absent pre-upgrade
        z = np.load(os.path.join(path, "arrays.npz"))
        leaves = [z[f"leaf_{i}"].view(_BF16) if i in bf16 else z[f"leaf_{i}"]
                  for i in range(len(z.files))]
        return treedef.unflatten(leaves)


def restore_resharded(ckpt: Checkpointer, shardings: Any,
                      step: Optional[int] = None) -> Any:
    """Elastic restore: place host arrays with *new* shardings (possibly a
    different mesh shape than the one that saved them)."""
    host = ckpt.restore(step)
    flat_h, td = jax.tree.flatten(host)
    flat_s = td.flatten_up_to(shardings)
    return td.unflatten([jax.device_put(h, s) if s is not None else jax.device_put(h)
                         for h, s in zip(flat_h, flat_s)])
