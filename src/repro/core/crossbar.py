"""Crossbar array simulation: in-row / in-column vectored stateful logic.

A crossbar is an (n x n) boolean resistance matrix.  Stateful logic applies
the same gate across *all rows* (columns) in one cycle by driving bitlines
(wordlines).  Partitions split a row (column) into independent segments so
multiple in-row gates execute concurrently (FELIX partitions).

Two error processes (paper §II-B):

* direct   — a gate writes the wrong value (p_gate), injected inside the gate
             primitives (stateful_logic.maybe_flip);
* indirect — accessing (reading or using as gate input) a memristor corrupts
             it with probability p_input (state drift / read disturb);
             time-based retention drift is modeled by `drift(key, p, dt)`.

Error processes are drawn from the unified fault taxonomy
(repro.faults.models): `ErrorModel` either wraps raw probabilities into the
default transient/drift models (back-compat) or takes explicit FaultModel
instances per channel, so the same campaign scenarios (stuck-at defects,
composite drift+transient, ...) drive the crossbar simulation and the
arena-level experiments.

The simulator is functional: every op returns a new state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from . import stateful_logic as sl
from ..faults.models import FaultModel, RetentionDrift, TransientBitFlips

__all__ = ["Crossbar", "ErrorModel"]


@dataclasses.dataclass(frozen=True)
class ErrorModel:
    """Error processes for the crossbar simulation.

    Back-compat surface: raw per-event probabilities (p_gate, p_input,
    p_retention), wrapped on demand into the default FaultModels.  Scenario
    surface: pass any faults.FaultModel per channel (`gate`, `input`,
    `retention`) to override the default process — e.g.
    ErrorModel(input=StuckAtFaults(1e-4, 1e-4)) pins defective cells
    instead of drawing i.i.d. transient flips.
    """

    p_gate: float = 0.0     # direct: incorrect stateful gate output
    p_input: float = 0.0    # indirect: corruption of accessed (input) bits
    p_retention: float = 0.0  # indirect: per-bit drift per time unit
    gate: Optional[FaultModel] = None       # overrides p_gate
    input: Optional[FaultModel] = None      # overrides p_input
    retention: Optional[FaultModel] = None  # overrides p_retention

    def gate_param(self):
        """What the gate primitives receive: a float (fast path, exact
        historic draws) or the overriding FaultModel."""
        return self.gate if self.gate is not None else self.p_gate

    def input_model(self) -> FaultModel:
        return self.input if self.input is not None \
            else TransientBitFlips(self.p_input)

    def retention_model(self) -> FaultModel:
        return self.retention if self.retention is not None \
            else RetentionDrift(self.p_retention)

    @property
    def has_input_noise(self) -> bool:
        return self.input is not None or self.p_input > 0.0


@dataclasses.dataclass
class Crossbar:
    """An n_rows x n_cols crossbar of boolean resistive states."""

    state: jax.Array                      # bool (n_rows, n_cols)
    errors: ErrorModel = dataclasses.field(default_factory=ErrorModel)
    counter: sl.CycleCounter = dataclasses.field(default_factory=sl.CycleCounter)

    # -- construction --------------------------------------------------------
    @staticmethod
    def zeros(n_rows: int, n_cols: int, errors: ErrorModel = ErrorModel()) -> "Crossbar":
        return Crossbar(jnp.zeros((n_rows, n_cols), jnp.bool_), errors)

    @staticmethod
    def from_array(a, errors: ErrorModel = ErrorModel()) -> "Crossbar":
        return Crossbar(jnp.asarray(a, jnp.bool_), errors)

    @property
    def shape(self):
        return self.state.shape

    def _with(self, state) -> "Crossbar":
        return Crossbar(state, self.errors, self.counter)

    # -- input access corruption (indirect) ----------------------------------
    def _read_cols(self, cols: Sequence[int], key: Optional[jax.Array]):
        """Read input columns; optionally corrupt the *stored* inputs."""
        vals = [self.state[:, c] for c in cols]
        if key is None or not self.errors.has_input_noise:
            return vals, self.state
        model = self.errors.input_model()
        new_state = self.state
        keys = jax.random.split(key, len(cols))
        out_vals = []
        for c, k, v in zip(cols, keys, vals):
            corrupted = model.corrupt_bits(v, k)
            new_state = new_state.at[:, c].set(corrupted)
            out_vals.append(corrupted)
        return out_vals, new_state

    def _read_rows(self, rows: Sequence[int], key: Optional[jax.Array]):
        vals = [self.state[r, :] for r in rows]
        if key is None or not self.errors.has_input_noise:
            return vals, self.state
        model = self.errors.input_model()
        new_state = self.state
        keys = jax.random.split(key, len(rows))
        out_vals = []
        for r, k, v in zip(rows, keys, vals):
            corrupted = model.corrupt_bits(v, k)
            new_state = new_state.at[r, :].set(corrupted)
            out_vals.append(corrupted)
        return out_vals, new_state

    # -- vectored in-row gate: all rows in one cycle --------------------------
    def row_gate(self, gate: str, in_cols: Sequence[int], out_col: int,
                 key: Optional[jax.Array] = None) -> "Crossbar":
        """Apply `gate` with inputs at `in_cols`, output at `out_col`,
        simultaneously in every row (paper Fig. 1(a))."""
        k_in = k_g = None
        if key is not None:
            k_in, k_g = jax.random.split(key)
        ins, state = self._read_cols(in_cols, k_in)
        out = _apply(gate, ins, k_g, self.errors.gate_param())
        new = state.at[:, out_col].set(out)
        self.counter.tick(n_parallel=self.shape[0], cycles=sl.GATE_COSTS[gate])
        return self._with(new)

    # -- vectored in-column gate: all columns in one cycle ---------------------
    def col_gate(self, gate: str, in_rows: Sequence[int], out_row: int,
                 key: Optional[jax.Array] = None) -> "Crossbar":
        """Apply `gate` with inputs at `in_rows`, output at `out_row`,
        simultaneously in every column (paper Fig. 1(b))."""
        k_in = k_g = None
        if key is not None:
            k_in, k_g = jax.random.split(key)
        ins, state = self._read_rows(in_rows, k_in)
        out = _apply(gate, ins, k_g, self.errors.gate_param())
        new = state.at[out_row, :].set(out)
        self.counter.tick(n_parallel=self.shape[1], cycles=sl.GATE_COSTS[gate])
        return self._with(new)

    # -- partitioned in-row gates (FELIX partitions, paper Fig. 1(c)) ---------
    def partitioned_row_gate(self, gate: str, part_width: int,
                             in_offsets: Sequence[int], out_offset: int,
                             key: Optional[jax.Array] = None) -> "Crossbar":
        """Divide every row into partitions of `part_width` columns and apply
        the gate within each partition concurrently: inputs/outputs are given
        as offsets *within* the partition.  One cycle for all rows x all
        partitions."""
        n_rows, n_cols = self.shape
        assert n_cols % part_width == 0
        n_parts = n_cols // part_width
        view = self.state.reshape(n_rows, n_parts, part_width)
        k_in = k_g = None
        if key is not None:
            k_in, k_g = jax.random.split(key)
        ins = [view[:, :, o] for o in in_offsets]
        if k_in is not None and self.errors.has_input_noise:
            model = self.errors.input_model()
            keys = jax.random.split(k_in, len(ins))
            new_view = view
            tmp = []
            for o, k, v in zip(in_offsets, keys, ins):
                cv = model.corrupt_bits(v, k)
                new_view = new_view.at[:, :, o].set(cv)
                tmp.append(cv)
            ins, view = tmp, new_view
        out = _apply(gate, ins, k_g, self.errors.gate_param())
        new = view.at[:, :, out_offset].set(out).reshape(n_rows, n_cols)
        self.counter.tick(n_parallel=n_rows * n_parts, cycles=sl.GATE_COSTS[gate])
        return self._with(new)

    # -- write / drift ---------------------------------------------------------
    def write_col(self, col: int, values, key: Optional[jax.Array] = None,
                  p_write: float = 0.0) -> "Crossbar":
        vals = jnp.asarray(values, jnp.bool_)
        if key is not None and p_write > 0.0:
            vals = jnp.logical_xor(vals, jax.random.bernoulli(key, p_write, vals.shape))
        self.counter.tick(n_parallel=self.shape[0])
        return self._with(self.state.at[:, col].set(vals))

    def write_row(self, row: int, values, key: Optional[jax.Array] = None,
                  p_write: float = 0.0) -> "Crossbar":
        vals = jnp.asarray(values, jnp.bool_)
        if key is not None and p_write > 0.0:
            vals = jnp.logical_xor(vals, jax.random.bernoulli(key, p_write, vals.shape))
        self.counter.tick(n_parallel=self.shape[1])
        return self._with(self.state.at[row, :].set(vals))

    def drift(self, key: jax.Array, dt: float = 1.0) -> "Crossbar":
        """Retention/state-drift + abrupt events over a time interval dt,
        drawn from the retention FaultModel (RetentionDrift by default)."""
        model = self.errors.retention_model()
        return self._with(model.corrupt_bits(self.state, key, dt))


def _apply(gate: str, ins, key, p_gate):
    fns: dict = {
        "not": lambda i, k: sl.g_not(i[0], k, p_gate),
        "nor": lambda i, k: sl.g_nor(i[0], i[1], k, p_gate),
        "or": lambda i, k: sl.g_or(i[0], i[1], k, p_gate),
        "nand": lambda i, k: sl.g_nand(i[0], i[1], k, p_gate),
        "and": lambda i, k: sl.g_and(i[0], i[1], k, p_gate),
        "min3": lambda i, k: sl.g_min3(i[0], i[1], i[2], k, p_gate),
        "maj3": lambda i, k: sl.g_maj3(i[0], i[1], i[2], k, p_gate),
        "xor": lambda i, k: sl.g_xor(i[0], i[1], k, p_gate),
    }
    if gate not in fns:
        raise ValueError(f"unknown gate {gate!r}")
    if key is None or (not isinstance(p_gate, FaultModel) and p_gate == 0.0):
        key = None
    return fns[gate](ins, key)
