"""Stateful-logic gate primitives (MAGIC / FELIX / IMPLY families).

A memristive stateful gate computes a Boolean function of the resistive
states of its input memristors and writes it into an output memristor, in a
single cycle, *in parallel across all rows (columns)* of a crossbar.  We
simulate gates as vectorized boolean ops; the vectorized axis IS the
row/column parallelism.

Error model (paper §II-B, "direct" soft errors): each gate evaluation
produces the wrong output with probability ``p_gate`` (independently per row,
per gate).  Injection is explicit — every primitive takes an optional
``(key, p_gate)`` pair so that reliability experiments control the fault
stream deterministically.  ``p_gate`` may also be any
``repro.faults.FaultModel`` (the unified taxonomy), whose bit-level sampler
then supplies the corruption — e.g. ``StuckAtFaults`` for permanently
defective output cells instead of i.i.d. transient flips.

Cycle accounting: each stateful gate is one crossbar cycle regardless of how
many rows it spans (that is the whole point of the paper).  ``CycleCounter``
tracks latency (cycles) and gate-evaluations (throughput/energy proxy).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..faults.models import FaultModel

__all__ = [
    "CycleCounter",
    "maybe_flip",
    "g_not",
    "g_nor",
    "g_or",
    "g_nand",
    "g_and",
    "g_min3",
    "g_maj3",
    "g_xor",
    "GATE_COSTS",
]


@dataclasses.dataclass
class CycleCounter:
    """Latency/energy accounting for stateful-logic sequences.

    cycles:  crossbar cycles (latency) — one per gate *issue*, independent of
             how many rows execute it in parallel.
    gate_evals: total gate evaluations (cycles x parallel rows) — an
             energy/throughput proxy.
    """

    cycles: int = 0
    gate_evals: int = 0

    def tick(self, n_parallel: int = 1, cycles: int = 1) -> None:
        self.cycles += cycles
        self.gate_evals += cycles * n_parallel

    def __add__(self, other: "CycleCounter") -> "CycleCounter":
        return CycleCounter(self.cycles + other.cycles, self.gate_evals + other.gate_evals)


def maybe_flip(out: jax.Array, key: Optional[jax.Array], p_gate) -> jax.Array:
    """Corrupt gate output: p_gate is a float flip probability (each output
    bit flips independently) or a faults.FaultModel applied to the output."""
    if key is None:
        return out
    if isinstance(p_gate, FaultModel):
        return p_gate.corrupt_bits(out, key)
    flips = jax.random.bernoulli(key, p_gate, shape=out.shape)
    return jnp.logical_xor(out, flips)


# --- single-cycle stateful gates -------------------------------------------
# MAGIC natively provides NOR/NOT; FELIX adds OR, NAND and Minority3 in one
# cycle.  AND/XOR/MAJ are multi-cycle compositions; their cycle costs are in
# GATE_COSTS and used by the crossbar-level cost accounting.

def g_not(a, key=None, p_gate=0.0):
    return maybe_flip(jnp.logical_not(a), key, p_gate)


def g_nor(a, b, key=None, p_gate=0.0):
    return maybe_flip(jnp.logical_not(jnp.logical_or(a, b)), key, p_gate)


def g_or(a, b, key=None, p_gate=0.0):  # FELIX single cycle
    return maybe_flip(jnp.logical_or(a, b), key, p_gate)


def g_nand(a, b, key=None, p_gate=0.0):  # FELIX single cycle
    return maybe_flip(jnp.logical_not(jnp.logical_and(a, b)), key, p_gate)


def g_and(a, b, key=None, p_gate=0.0):
    """AND = NOT(NAND): 2 cycles."""
    if key is None:
        return jnp.logical_and(a, b)
    k1, k2 = jax.random.split(key)
    return g_not(g_nand(a, b, k1, p_gate), k2, p_gate)


def g_min3(a, b, c, key=None, p_gate=0.0):
    """Minority3 (FELIX, single cycle): NOT(majority(a,b,c)).

    This is the paper's voting gate.
    """
    maj = (a & b) | (b & c) | (a & c)
    return maybe_flip(jnp.logical_not(maj), key, p_gate)


def g_maj3(a, b, c, key=None, p_gate=0.0):
    """Majority = NOT(Minority3): 2 cycles (Min3 then NOT)."""
    if key is None:
        return (a & b) | (b & c) | (a & c)
    k1, k2 = jax.random.split(key)
    return g_not(g_min3(a, b, c, k1, p_gate), k2, p_gate)


def g_xor(a, b, key=None, p_gate=0.0):
    """XOR via 5 NOR gates (NOR-only decomposition):

      x1 = NOR(a, b); x2 = NOR(a, x1); x3 = NOR(b, x1);
      x4 = NOR(x2, x3) = XNOR; out = NOT(x4).
    """
    if key is None:
        return jnp.logical_xor(a, b)
    ks = jax.random.split(key, 5)
    x1 = g_nor(a, b, ks[0], p_gate)
    x2 = g_nor(a, x1, ks[1], p_gate)
    x3 = g_nor(b, x1, ks[2], p_gate)
    x4 = g_nor(x2, x3, ks[3], p_gate)
    return g_not(x4, ks[4], p_gate)


#: crossbar cycles per logical op (FELIX gate set)
GATE_COSTS = {
    "not": 1,
    "nor": 1,
    "or": 1,
    "nand": 1,
    "min3": 1,
    "and": 2,
    "maj3": 2,
    "xor": 5,
}
