"""Triple modular redundancy with per-bit Minority3 voting (paper §V).

Three execution disciplines, identical output semantics, different cost:

* serial        — 3x latency, ~1x area (inputs/intermediates reused)
* parallel      — 1x latency, 3x area (memristive partitions; on TPU: 3
                  replicas across a mesh axis / vmap)
* semi-parallel — 1x latency, 1x area, 1/3 throughput (repeat across rows)

Voting is **per-bit** with the Minority3 stateful gate: majority = NOT(Min3),
2 crossbar cycles per bit-plane, itself vulnerable to soft errors
("non-ideal voting") — the paper shows this becomes the reliability
bottleneck near p_gate = 1e-9 (Fig. 4, dashed line).

Per-bit voting strictly dominates per-element voting: they differ only where
per-element voting is undefined (no two copies agree on the whole word).

NOTE (DESIGN.md §12): the public protection API is `repro.reliability.Tmr`,
which exposes all three disciplines (including semi-parallel) end-to-end
behind the composable `Scheme` protocol; the voters and cost table here
are the building blocks it dispatches to.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import stateful_logic as sl
from .bitops import float_view_u32, u32_view_float

__all__ = ["TmrCost", "vote_bits", "vote_words", "vote_array",
           "tmr", "TMR_COSTS"]


@dataclasses.dataclass(frozen=True)
class TmrCost:
    latency_x: float
    area_x: float
    throughput_x: float


#: paper §V trade-off surface, relative to the unreliable baseline
TMR_COSTS = {
    "serial": TmrCost(latency_x=3.0, area_x=1.0, throughput_x=1.0),
    "parallel": TmrCost(latency_x=1.0, area_x=3.0, throughput_x=1.0),
    "semi_parallel": TmrCost(latency_x=1.0, area_x=1.0, throughput_x=1.0 / 3.0),
}


def vote_bits(a: jax.Array, b: jax.Array, c: jax.Array,
              key: Optional[jax.Array] = None, p_gate: float = 0.0) -> jax.Array:
    """Per-bit majority of three boolean bit-planes via Minority3 + NOT.

    With (key, p_gate) the two voting gates are themselves fault-injected
    (non-ideal voting, as evaluated in the paper's Fig. 4).
    """
    if key is None:
        return sl.g_maj3(a, b, c)
    return sl.g_maj3(a, b, c, key, p_gate)


def vote_words(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """Per-bit majority on packed integer words (uint/int arrays)."""
    return (a & b) | (b & c) | (a & c)


def vote_array(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """Per-bit majority on arbitrary arrays (floats voted on raw IEEE bits).

    This is the TPU-facing voter used by the reliable serving path: bitcast
    to words, vote bitwise, bitcast back.  Any single corrupted copy is
    corrected exactly, including NaN-producing bit flips.
    """
    if jnp.issubdtype(a.dtype, jnp.floating):
        av, bv, cv = float_view_u32(a), float_view_u32(b), float_view_u32(c)
        return u32_view_float(vote_words(av, bv, cv), a.dtype)
    if a.dtype == jnp.bool_:
        return vote_bits(a, b, c)
    return vote_words(a, b, c)


def tmr(fn: Callable[..., jax.Array], mode: str = "serial",
        voter: Callable = vote_array):
    """Wrap `fn(key, *args) -> pytree` with triple-modular redundancy.

    `fn` must accept a PRNG key as its first argument (the per-copy fault
    stream); the wrapper runs three copies with independent keys and votes
    per-bit on every leaf.

    mode='serial'   : three sequential evaluations (3x latency, reuse).
    mode='parallel' : vmap over a stacked replica axis (1x latency, 3x area;
                      on a real mesh the replica axis is sharded).
    mode='semi_parallel': batched side-by-side within the same call (the
                      crossbar-rows analogue) — implemented like 'parallel'
                      but accounted at 1/3 throughput.
    """
    if mode not in TMR_COSTS:
        raise ValueError(f"mode must be one of {sorted(TMR_COSTS)}")

    def wrapped(key: jax.Array, *args):
        keys = jax.random.split(key, 3)
        if mode == "serial":
            outs = [fn(k, *args) for k in keys]
        else:
            outs = jax.vmap(lambda k: fn(k, *args))(keys)
            outs = [jax.tree.map(lambda x, i=i: x[i], outs) for i in range(3)]
        return jax.tree.map(lambda a, b, c: voter(a, b, c), *outs)

    wrapped.cost = TMR_COSTS[mode]
    return wrapped
