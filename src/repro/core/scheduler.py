"""Levelized netlist schedules: O(depth) wide steps instead of O(G) gates.

The lax.scan executor in core/netlist.py walks the gate list one Min3 at a
time — serial in the gate dimension, the opposite of the crossbar row
parallelism the mMPU exploits.  But a Min3 netlist is a DAG: every gate
whose inputs are already computed can fire in the same cycle (HIPE-MAGIC's
level scheduling, PAPERS.md).  This module compiles a `Netlist` into a
dense, padded ``(L, W, 4)`` schedule of dependency levels and executes it
as L wide vector steps over *trial-packed* words (32 trials per uint32
lane, core/bitops.pack_trials), so each level is a handful of bitwise ops.

Two compilation decisions carry the speedup:

* **capacity-capped levels** — raw ASAP levelization of the multiplier is
  two 1024-wide partial-product levels followed by hundreds of ~45-wide
  adder levels; padding every level to the global maximum would waste ~20x
  the work.  Capacity-constrained list scheduling (default width: a power
  of two near 2·G/depth) spills wide levels into their successors' slack;
  every gate still executes strictly after its producers.
* **schedule-order wire renumbering** — wires are renamed so that level
  l's outputs occupy one contiguous row block of the packed state
  ``[base + l·W, base + (l+1)·W)``.  A level then commits with one
  dynamic_update_slice instead of a scattered column write (~5x on CPU;
  on TPU a lane-contiguous store instead of a scatter), while reads stay
  gathers over earlier rows.  Padding slots read row 0 (const ZERO) and
  own their slot's row, so no trash-wire aliasing exists anywhere.

Fault injection matches the scan reference bit-for-bit: gate ``gid`` is
corrupted under ``fold_in(key, gid)`` via the faults.FaultModel
packed-trial samplers (``gate_lane_masks``), and single-fault planes
(`fault_gate`) XOR the same positions.  The Pallas kernel in
kernels/netlist_exec consumes the same schedule and the same mask tensors,
which makes kernel ≡ levelized ≡ scan an exact identity, fault streams
included (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..faults.models import FaultModel, TransientGateFaults
from .bitops import PACK, pack_trials, unpack_trials
from .netlist import Netlist

__all__ = ["Schedule", "levelize", "schedule", "schedule_fault_masks",
           "min3_level", "packed_initial_state", "execute_levelized"]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Dense levelized form of a Netlist.

    sched:     (L, W, 4) int32 — Min3 rows (in1, in2, in3, out) grouped by
               level, in *original wire ids* (padding slots read wire 0 and
               carry out = n_wires).
    sched_gid: (L, W) int32 — original gate id per slot, -1 for padding
               (the key into gate-indexed fault-mask tensors).
    widths:    (L,) int32 — real gates per level.
    depth:     critical-path depth of the DAG (ASAP level count); L >= depth
               when the width cap forces spilling.
    remap:     (n_wires,) int32 — wire id -> packed state row: row 0 ZERO,
               row 1 ONE, rows [2, base) the primary inputs in netlist
               order, then slot (l, s) owns row base + l*W + s.
    rows_in:   (L, W, 3) int32 — sched input wires through remap (padding
               slots read row 0); level l's outputs are exactly rows
               [base + l*W, base + (l+1)*W) of the packed state.
    """

    n_wires: int
    n_gates: int
    depth: int
    sched: np.ndarray
    sched_gid: np.ndarray
    widths: np.ndarray
    base: int
    remap: np.ndarray
    rows_in: np.ndarray

    @property
    def n_levels(self) -> int:
        return int(self.sched.shape[0])

    @property
    def max_width(self) -> int:
        return int(self.sched.shape[1])

    @property
    def n_slots(self) -> int:
        return int(self.sched.shape[0] * self.sched.shape[1])

    @property
    def n_rows(self) -> int:
        return self.base + self.n_slots

    def issue_counts(self, row_cap: int) -> np.ndarray:
        """Row-parallel issues per level under a crossbar row budget:
        level l's ``widths[l]`` gates fire in ``ceil(widths[l]/row_cap)``
        sequential issues (the mMPU cost model's latency unit —
        costmodel.compile.lower_schedule)."""
        if row_cap < 1:
            raise ValueError(f"row_cap must be >= 1, got {row_cap}")
        return -(-self.widths.astype(np.int64) // int(row_cap))


def _next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1)).bit_length()


def _asap_levels(nl: Netlist) -> np.ndarray:
    """ASAP level per gate (1-based; constants/inputs sit at level 0)."""
    wire_level = np.zeros(nl.n_wires, np.int64)
    gate_level = np.zeros(nl.n_gates, np.int64)
    for g in range(nl.n_gates):
        i1, i2, i3, out = nl.gates[g]
        lvl = 1 + max(wire_level[i1], wire_level[i2], wire_level[i3])
        gate_level[g] = lvl
        wire_level[out] = lvl
    return gate_level


def levelize(nl: Netlist, max_width: Optional[int] = None) -> Schedule:
    """Compile a netlist into a capacity-capped levelized schedule.

    Capacity-constrained list scheduling: at each step, fire up to
    ``max_width`` ready gates (all producers in strictly earlier steps),
    lowest gate id first — deterministic, and id order is the builder's
    emission order so locality of the wire state is preserved.
    ``max_width=None`` picks a power of two near 2·G/depth (clamped to
    [32, ASAP max width]) — wide enough that spilling adds few levels,
    narrow enough that padding stays O(G).
    """
    G = nl.n_gates
    n_in = len(nl.inputs)
    base = 2 + n_in
    remap = np.zeros(nl.n_wires, np.int64)
    remap[1] = 1
    remap[nl.inputs] = 2 + np.arange(n_in)
    if G == 0:
        return Schedule(nl.n_wires, 0, 0, np.zeros((0, 1, 4), np.int32),
                        np.full((0, 1), -1, np.int32), np.zeros(0, np.int32),
                        base, remap.astype(np.int32),
                        np.zeros((0, 1, 3), np.int32))

    asap = _asap_levels(nl)
    depth = int(asap.max())
    if max_width is None:
        _, counts = np.unique(asap, return_counts=True)
        width_asap = int(counts.max())
        max_width = min(width_asap, max(32, _next_pow2(-(-2 * G // depth))))
    max_width = max(1, int(max_width))

    # producer gate of each wire (-1 for constants and primary inputs)
    producer = np.full(nl.n_wires, -1, np.int64)
    producer[nl.gates[:, 3]] = np.arange(G)
    pred = producer[nl.gates[:, :3]]                    # (G, 3), -1 = source
    indeg = (pred >= 0).sum(axis=1)
    # consumers adjacency (flat CSR to keep the python loop cheap)
    src = pred[pred >= 0]
    dst = np.repeat(np.arange(G), 3)[(pred >= 0).reshape(-1)]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    starts = np.searchsorted(src, np.arange(G + 1))

    future: list = [(1, g) for g in range(G) if indeg[g] == 0]
    heapq.heapify(future)
    ready: list = []
    levels: list = []
    scheduled = 0
    step = 0
    while scheduled < G:
        step += 1
        if not ready and future and future[0][0] > step:
            step = future[0][0]
        while future and future[0][0] <= step:
            heapq.heappush(ready, heapq.heappop(future)[1])
        level = []
        while ready and len(level) < max_width:
            level.append(heapq.heappop(ready))
        for g in level:
            for consumer in dst[starts[g]:starts[g + 1]]:
                indeg[consumer] -= 1
                if indeg[consumer] == 0:
                    heapq.heappush(future, (step + 1, consumer))
        scheduled += len(level)
        levels.append(level)

    L, W = len(levels), max_width
    sched = np.zeros((L, W, 4), np.int32)
    sched[:, :, 3] = nl.n_wires
    sched_gid = np.full((L, W), -1, np.int32)
    widths = np.zeros(L, np.int32)
    for l, level in enumerate(levels):
        widths[l] = len(level)
        sched[l, :len(level)] = nl.gates[level]
        sched_gid[l, :len(level)] = level

    valid = sched_gid >= 0
    slot_row = base + np.arange(L * W).reshape(L, W)
    remap[nl.gates[sched_gid[valid], 3]] = slot_row[valid]
    rows_in = np.where(valid[..., None], remap[sched[:, :, :3]], 0)
    return Schedule(nl.n_wires, G, depth, sched, sched_gid, widths,
                    base, remap.astype(np.int32), rows_in.astype(np.int32))


_schedule_cache: Dict[tuple, Schedule] = {}


def schedule(nl: Netlist, max_width: Optional[int] = None) -> Schedule:
    """Cached levelize — netlists are built once and executed many times.

    Keyed on the netlist's exact bytes (a handful of netlists per process,
    ~200 KB each — collisions would silently execute the wrong schedule,
    so no hashing shortcut)."""
    key = (nl.n_wires, np.ascontiguousarray(nl.gates).tobytes(),
           np.ascontiguousarray(nl.inputs).tobytes(),
           np.ascontiguousarray(nl.outputs).tobytes(), max_width)
    sch = _schedule_cache.get(key)
    if sch is None:
        sch = _schedule_cache[key] = levelize(nl, max_width)
    return sch


def schedule_fault_masks(sch: Schedule, trials: int,
                         key: Optional[jax.Array] = None, p_gate=0.0,
                         fault_gate: Optional[jax.Array] = None,
                         ) -> Optional[Tuple[Optional[jax.Array], jax.Array]]:
    """Build schedule-ordered corruption masks, or None when fault-free.

    Returns (keep, flip) with flip uint32 (L, W, tw), tw = ceil(trials/32):
    slot (l, s)'s freshly computed packed column corrupts as
    ``(val & keep[l, s]) ^ flip[l, s]`` — identity on padding slots.  keep
    is None when no iid model is active (single-fault only): the
    corruption is then a pure XOR and the engines skip the AND — the
    exhaustive alpha path never materializes G x tw words of constant
    ones.  Gate gid samples under fold_in(key, gid) exactly like the scan
    reference; a float p_gate means TransientGateFaults(p_gate); the iid
    model is applied before the single-fault XOR (scan order), which in
    affine form is just flip ^= single_fault_plane.
    """
    G, tw = sch.n_gates, -(-trials // PACK)
    model = p_gate if isinstance(p_gate, FaultModel) else (
        TransientGateFaults(p_gate) if p_gate > 0.0 else None)
    use_iid = key is not None and model is not None
    if not use_iid and fault_gate is None:
        return None

    if use_iid:
        gids = jnp.arange(G, dtype=jnp.int32)
        keys = jax.vmap(lambda g: jax.random.fold_in(key, g))(gids)
        keep_g, flip_g = jax.vmap(
            lambda k: model.gate_lane_masks(k, trials))(keys)      # (G, tw)
    else:
        keep_g = None
        flip_g = jnp.zeros((G, tw), jnp.uint32)

    if fault_gate is not None:
        # trial t flips gate fault_gate[t]: scatter bit t%32 into word t//32
        # of that gate's row (distinct bits per trial — adds never collide);
        # negative fault_gate disables by landing in the spare row G
        t = jnp.arange(trials, dtype=jnp.uint32)
        fg = jnp.where(fault_gate < 0, G, fault_gate).astype(jnp.int32)
        single = jnp.zeros((G + 1, tw), jnp.uint32)
        single = single.at[fg, (t // PACK).astype(jnp.int32)].add(
            jnp.uint32(1) << (t % PACK), mode="drop")
        flip_g = flip_g ^ single[:G]

    gid = jnp.asarray(sch.sched_gid)                               # (L, W)
    pad = (gid < 0)[..., None]
    safe = jnp.maximum(gid, 0)
    flip = jnp.where(pad, jnp.uint32(0), flip_g[safe])
    if keep_g is None:
        return None, flip
    keep = jnp.where(pad, jnp.uint32(0xFFFFFFFF), keep_g[safe])
    return keep, flip


def min3_level(state: jax.Array, rows: jax.Array) -> jax.Array:
    """Evaluate one schedule level: (n_rows, tw) packed state + (W, 3) input
    rows -> (W, tw) Minority3 outputs.  One fused gather per level — a
    (W, 3, tw) single XLA gather is ~4x a triple of (W, tw) gathers on CPU.
    Shared by execute_levelized and the netlist_exec kernel body, so the
    kernel == level bit-identity rests on literally the same expression."""
    abc = state[rows]
    a, b, c = abc[:, 0], abc[:, 1], abc[:, 2]
    return ~((a & b) | (b & c) | (a & c))


def packed_initial_state(sch: Schedule, inputs: jax.Array) -> jax.Array:
    """(trials, n_in) bool -> (n_rows, tw) uint32 packed wire state in the
    schedule's renumbered row layout (constants + inputs loaded in netlist
    input order — rows [2, base) — every level's output block zeroed)."""
    tw = -(-inputs.shape[0] // PACK)
    state = jnp.zeros((sch.n_rows, tw), jnp.uint32)
    state = state.at[1].set(jnp.uint32(0xFFFFFFFF))
    return state.at[2:sch.base].set(pack_trials(inputs).T)


def execute_levelized(nl: Netlist, inputs: jax.Array,
                      key: Optional[jax.Array] = None, p_gate=0.0,
                      fault_gate: Optional[jax.Array] = None,
                      max_width: Optional[int] = None,
                      unroll: int = 4) -> jax.Array:
    """Levelized bit-packed executor — same contract as netlist.execute,
    bit-exact against it (fault streams included), O(L) steps instead of
    O(G).  This is also the jnp oracle for kernels/netlist_exec.
    """
    sch = schedule(nl, max_width)
    trials = inputs.shape[0]
    state = packed_initial_state(sch, inputs)
    masks = schedule_fault_masks(sch, trials, key, p_gate, fault_gate)
    rows_in = jnp.asarray(sch.rows_in)
    offsets = sch.base + sch.max_width * jnp.arange(max(sch.n_levels, 1),
                                                    dtype=jnp.int32)
    offsets = offsets[:sch.n_levels]
    zero = jnp.int32(0)

    if masks is None:
        def body(state, xs):
            rows, off = xs
            val = min3_level(state, rows)
            return jax.lax.dynamic_update_slice(state, val, (off, zero)), None

        state, _ = jax.lax.scan(body, state, (rows_in, offsets), unroll=unroll)
    elif masks[0] is None:                           # single-fault: pure XOR
        def body(state, xs):
            rows, off, flip = xs
            val = min3_level(state, rows) ^ flip
            return jax.lax.dynamic_update_slice(state, val, (off, zero)), None

        state, _ = jax.lax.scan(body, state, (rows_in, offsets, masks[1]),
                                unroll=unroll)
    else:
        def body(state, xs):
            rows, off, keep, flip = xs
            val = (min3_level(state, rows) & keep) ^ flip
            return jax.lax.dynamic_update_slice(state, val, (off, zero)), None

        state, _ = jax.lax.scan(body, state, (rows_in, offsets) + masks,
                                unroll=unroll)
    out = state[jnp.asarray(sch.remap[np.asarray(nl.outputs)])]
    return unpack_trials(out.T, trials)
