"""Packed parameter arena: one contiguous uint32 buffer per pytree.

The reliability layer's throughput problem (DESIGN.md §9) is dispatch
granularity: protecting a model with N leaves as N independent buffers costs
N kernel launches per protect/scrub/refresh, and the small leaves (biases,
norm scales) dominate launch overhead rather than bandwidth.  The arena
flattens the whole pytree into ONE flat uint32 buffer:

    [ leaf0 words | pad | leaf1 words | pad | ... ]

Every leaf starts on a 32-word (ECC block) boundary, so a block never
straddles two leaves, pad words are identically zero (their parity
contribution is zero and a syndrome over padding is clean), and an
uncorrectable block is attributable to exactly one leaf.

All metadata (offsets, pad, dtype, shape) is host-side and static — packing
and unpacking are pure bitcast/concatenate/slice programs, so they trace
and fuse under jit, and protect/scrub/refresh over the arena become a single
fused kernel launch regardless of the number of leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["BLOCK", "LeafSpec", "ArenaSpec", "leaf_to_words", "words_to_leaf",
           "pack", "unpack", "arena_spec", "canonical_parts", "words_for"]

BLOCK = 32  # words per ECC block == bits per word


def _n_elems(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def words_for(shape, dtype) -> int:
    """Payload words `leaf_to_words` would produce for a leaf of this
    shape/dtype (bfloat16 packs two 16-bit halves per word) — the
    host-side sizing primitive for arena consumers that lay out
    fixed-granularity regions, e.g. the paged KV pool checking that one
    KV page spans a whole number of ECC blocks."""
    n = _n_elems(shape)
    if jnp.dtype(dtype) == jnp.bfloat16:
        return (n + 1) // 2
    return n


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Placement of one leaf inside the arena (all host-side constants)."""
    offset: int          # word offset of the leaf start (block-aligned)
    n_words: int         # payload words (bf16 halves packed two per word)
    pad_words: int       # zero words up to the next block boundary
    dtype: Any           # jnp dtype of the original leaf
    shape: Tuple[int, ...]

    @property
    def n_blocks(self) -> int:
        return (self.n_words + self.pad_words) // BLOCK


@dataclasses.dataclass(frozen=True)
class ArenaSpec:
    leaves: Tuple[LeafSpec, ...]
    treedef: Any
    n_words: int         # total arena length in words (multiple of BLOCK)

    @property
    def n_blocks(self) -> int:
        return self.n_words // BLOCK

    def leaf_of_block(self, block: int) -> int:
        """Index of the leaf that owns ECC block `block` (host-side)."""
        for i, l in enumerate(self.leaves):
            first = l.offset // BLOCK
            if first <= block < first + l.n_blocks:
                return i
        raise IndexError(block)


def _words_per_leaf(x: jax.Array) -> int:
    if x.dtype == jnp.bfloat16:
        return (_n_elems(x.shape) + 1) // 2
    return _n_elems(x.shape)


def leaf_to_words(x: jax.Array) -> jax.Array:
    """Bitcast one leaf to its flat uint32 payload (no block padding).

    bfloat16 leaves pack two 16-bit halves per word, LSB-half first; an
    odd-length leaf carries one zero half-word in its last word.
    """
    if x.dtype == jnp.bfloat16:
        u16 = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint16)
        if u16.shape[0] % 2:
            u16 = jnp.pad(u16, (0, 1))
        return u16[0::2].astype(jnp.uint32) | (u16[1::2].astype(jnp.uint32) << 16)
    if x.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint32)
    if x.dtype in (jnp.int32, jnp.uint32):
        return x.reshape(-1).astype(jnp.uint32)
    raise TypeError(f"arena: unsupported dtype {x.dtype}")


def words_to_leaf(words: jax.Array, spec: LeafSpec) -> jax.Array:
    """Inverse of `leaf_to_words` given the leaf's exact payload words."""
    n = _n_elems(spec.shape)
    if spec.dtype == jnp.bfloat16:
        u16 = jnp.stack([(words & 0xFFFF).astype(jnp.uint16),
                         (words >> 16).astype(jnp.uint16)], -1).reshape(-1)[:n]
        return jax.lax.bitcast_convert_type(u16, jnp.bfloat16).reshape(spec.shape)
    if spec.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(words, jnp.float32).reshape(spec.shape)
    return words.astype(spec.dtype).reshape(spec.shape)


def arena_spec(params: Any) -> ArenaSpec:
    """Layout (without building the buffer): abstract shapes suffice."""
    leaves, treedef = jax.tree.flatten(params)
    specs, offset = [], 0
    for x in leaves:
        n_words = _words_per_leaf(x)
        pad = (-n_words) % BLOCK
        specs.append(LeafSpec(offset=offset, n_words=n_words, pad_words=pad,
                              dtype=x.dtype, shape=tuple(x.shape)))
        offset += n_words + pad
    return ArenaSpec(leaves=tuple(specs), treedef=treedef, n_words=offset)


def canonical_parts(parts):
    """Make a list of arrays safe to `jnp.concatenate` on a multi-device
    mesh: concatenating eager arrays with MIXED shardings miscompiles on
    multi-device backends (an unreduced cross-replica sum lands in the
    output — every value doubles per replicated mesh axis; observed on
    jax 0.4.37 CPU both eagerly and under jit), while same-sharding
    concatenation is correct.  Canonicalize every part onto one
    replicated sharding first; no-op under tracing or when all parts
    already share a sharding."""
    if any(isinstance(p, jax.core.Tracer) for p in parts):
        return parts
    shardings = {p.sharding for p in parts}
    if len(shardings) <= 1:
        return parts
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = next((s.mesh for s in shardings
                 if isinstance(s, NamedSharding)), None)
    common = parts[0].sharding if mesh is None \
        else NamedSharding(mesh, PartitionSpec())
    return [jax.device_put(p, common) for p in parts]


def pack(params: Any) -> Tuple[jax.Array, ArenaSpec]:
    """Flatten a pytree into (arena_u32, spec); one concatenate, jit-safe."""
    spec = arena_spec(params)
    leaves = jax.tree.leaves(params)
    parts = []
    for x, l in zip(leaves, spec.leaves):
        w = leaf_to_words(x)
        if l.pad_words:
            w = jnp.pad(w, (0, l.pad_words))
        parts.append(w)
    if not parts:
        return jnp.zeros((0,), jnp.uint32), spec
    return jnp.concatenate(canonical_parts(parts)), spec


def unpack(arena: jax.Array, spec: ArenaSpec) -> Any:
    """Rebuild the pytree from the arena (static slices; jit-safe)."""
    leaves = [words_to_leaf(arena[l.offset:l.offset + l.n_words], l)
              for l in spec.leaves]
    return spec.treedef.unflatten(leaves)
