"""Bit-plane <-> word packing utilities.

The mMPU stores one bit per memristor; a logical W-bit word occupies W
memristors along a row (column).  On TPU we simulate bit-planes either as
bool arrays with a trailing bit axis (LSB first) or packed into uint32 words
(32 logical crossbar "rows" per lane word).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "to_bits",
    "from_bits",
    "pack_trials",
    "unpack_trials",
    "rotl32",
    "rotr32",
    "popcount32",
    "bit_position",
    "float_view_u32",
    "u32_view_float",
]

#: trials packed per uint32 lane word (the crossbar row-parallel axis)
PACK = 32


def to_bits(x: jax.Array, width: int) -> jax.Array:
    """Unpack integers into a bit-plane array, LSB first.

    x: integer array (...,)  ->  bool array (..., width)
    """
    x = x.astype(jnp.uint32) if width <= 32 else x.astype(jnp.uint64)
    shifts = jnp.arange(width, dtype=x.dtype)
    return ((x[..., None] >> shifts) & 1).astype(jnp.bool_)


def from_bits(bits: jax.Array, dtype=jnp.uint32) -> jax.Array:
    """Pack a bit-plane array (..., width) LSB-first into integers (...,)."""
    width = bits.shape[-1]
    acc_dtype = jnp.uint64 if width > 32 else jnp.uint32
    shifts = jnp.arange(width, dtype=acc_dtype)
    vals = (bits.astype(acc_dtype) << shifts).sum(axis=-1, dtype=acc_dtype)
    return vals.astype(dtype)


def pack_trials(bits: jax.Array) -> jax.Array:
    """Pack the leading *trials* axis 32-per-uint32 word, trial-major.

    bits: bool (trials, ...)  ->  uint32 (ceil(trials/32), ...) with trial t
    in bit t % 32 of word t // 32 (zero-padded — padding lanes carry 0).
    This is the packed-state layout of the netlist execution engines
    (core/scheduler.py, kernels/netlist_exec, kernels/crossbar_nor).
    """
    t = bits.shape[0]
    pad = (-t) % PACK
    if pad:
        bits = jnp.pad(bits, ((0, pad),) + ((0, 0),) * (bits.ndim - 1))
    bits = bits.reshape((-1, PACK) + bits.shape[1:]).astype(jnp.uint32)
    shifts = jnp.arange(PACK, dtype=jnp.uint32).reshape(
        (1, PACK) + (1,) * (bits.ndim - 2))
    return (bits << shifts).sum(axis=1, dtype=jnp.uint32)


def unpack_trials(words: jax.Array, trials: int) -> jax.Array:
    """Inverse of pack_trials: uint32 (tw, ...) -> bool (trials, ...)."""
    shifts = jnp.arange(PACK, dtype=jnp.uint32).reshape(
        (1, PACK) + (1,) * (words.ndim - 1))
    bits = ((words[:, None] >> shifts) & 1).astype(jnp.bool_)
    return bits.reshape((-1,) + words.shape[1:])[:trials]


def rotl32(x: jax.Array, r) -> jax.Array:
    """Rotate-left each uint32 by r (scalar or broadcastable array).

    This is the JAX analogue of the paper's barrel shifter: a diagonal of the
    bit matrix maps to a rotation of the packed word.
    """
    x = x.astype(jnp.uint32)
    r = jnp.asarray(r, dtype=jnp.uint32) % jnp.uint32(32)
    # jnp handles shift-by-zero fine; (x << 0) | (x >> 32) would be UB in C but
    # we mask the complementary shift through a where.
    left = x << r
    right = jnp.where(r == 0, jnp.uint32(0), x >> (jnp.uint32(32) - r))
    return left | right


def rotr32(x: jax.Array, r) -> jax.Array:
    r = jnp.asarray(r, dtype=jnp.uint32) % jnp.uint32(32)
    return rotl32(x, (jnp.uint32(32) - r) % jnp.uint32(32))


def popcount32(x: jax.Array) -> jax.Array:
    """Population count of each uint32."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def bit_position(x: jax.Array) -> jax.Array:
    """Index of the single set bit of each uint32 (undefined if popcount != 1).

    Returns int32 in [0, 32); 0 for x == 0.
    """
    x = x.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    isset = ((x[..., None] >> shifts) & 1).astype(jnp.int32)
    return (isset * jnp.arange(32, dtype=jnp.int32)).sum(axis=-1)


def float_view_u32(x: jax.Array) -> jax.Array:
    """Bit-cast a float32/bfloat16/int array to its raw uint bits (u32/u16)."""
    if x.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    if x.dtype == jnp.bfloat16:
        return jax.lax.bitcast_convert_type(x, jnp.uint16)
    if x.dtype in (jnp.int32, jnp.uint32):
        return x.astype(jnp.uint32)
    raise TypeError(f"unsupported dtype {x.dtype}")


def u32_view_float(bits: jax.Array, dtype) -> jax.Array:
    if dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(bits.astype(jnp.uint32), jnp.float32)
    if dtype == jnp.bfloat16:
        return jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.bfloat16)
    if dtype in (jnp.int32, jnp.uint32):
        return bits.astype(dtype)
    raise TypeError(f"unsupported dtype {dtype}")
