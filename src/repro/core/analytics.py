"""Closed-form reliability analytics for the paper's case study (§VI).

Methodology (matches the paper's extrapolation style):

* p_mult(p_gate): measured by Monte-Carlo at high p_gate; at low p_gate we
  use the exhaustive single-fault masking fraction alpha (the fraction of
  gate positions whose single fault corrupts the product, measured once with
  netlist.execute(fault_gate=arange(G))) and extrapolate
      p_mult ~= 1 - (1 - alpha * p_gate)^G.
* TMR: a voted output bit fails if >= 2 copies err on that bit, or voting
  itself errs.  We extrapolate from the same per-copy failure probability and
  the voting-gate count (2 gates per output bit, non-ideal).
* NN feed-forward (Fig. 4 bottom): with M multiplications per sample and
  masking fraction p_mask (G. Li et al.: 0.03% for AlexNet),
      p_misclassify = 1 - (1 - p_mask * p_mult)^M.
* Weight degradation (Fig. 5): accessing a bit corrupts it w.p. p_input per
  batch; a 32-bit weight survives a batch w.p. (1-p_input)^32; over T batches
  p_corrupt(T) = 1 - (1-q)^T.  With diagonal ECC scrubbed every batch, a
  block of m*m bits fails only on >= 2 errors per scrub interval.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict

import numpy as np

__all__ = [
    "AlexNetCaseStudy", "p_mult_from_alpha", "p_mult_tmr",
    "nn_misclassification", "weight_corruption_baseline",
    "weight_corruption_ecc", "expected_corrupted_weights",
    "ScrubTrajectory", "expected_scrub_rates",
]


@dataclasses.dataclass(frozen=True)
class AlexNetCaseStudy:
    """Constants from paper §VI (FloatPIM + AlexNet + ImageNet)."""

    M: float = 612e6          # multiplications per sample
    W: float = 62e6           # weights
    p_mask: float = 0.0003    # fraction of mult errors that flip classification
    inherent_error: float = 0.27  # AlexNet top-1 error (paper: ~27%)
    bits_per_weight: int = 32


def p_mult_from_alpha(p_gate: np.ndarray, alpha: float, n_gates: int) -> np.ndarray:
    """Unreliable-baseline multiplication failure probability.

    alpha = unmasked fraction from exhaustive single-fault injection.
    Exact for independent iid gate faults in the rare-fault regime; at high
    p_gate multi-fault cancellation makes this an upper bound (we use MC
    there instead).
    """
    p_gate = np.asarray(p_gate, dtype=np.float64)
    return 1.0 - np.power(1.0 - alpha * p_gate, n_gates)


def p_mult_tmr(p_gate: np.ndarray, alpha: float, n_gates: int,
               n_out_bits: int = 64, alpha_vote: float = 1.0,
               ideal_voting: bool = False) -> np.ndarray:
    """TMR multiplication failure probability (per-bit voting).

    A voted result is wrong if (a) >= 2 of 3 copies produce a wrong value on
    some common bit, or (b) a voting gate errs.  In the rare-fault regime
    copy errors on the *same* bit dominate the pairwise term; we
    conservatively use whole-word copy failure (upper bound, and the paper's
    own curves are word-level).  Voting uses 2 stateful gates per output bit.
    """
    p_gate = np.asarray(p_gate, dtype=np.float64)
    p_copy = 1.0 - np.power(1.0 - alpha * p_gate, n_gates)
    p_two_of_three = 3.0 * p_copy**2 * (1.0 - p_copy) + p_copy**3
    if ideal_voting:
        return p_two_of_three
    p_vote = 1.0 - np.power(1.0 - alpha_vote * p_gate, 2 * n_out_bits)
    return 1.0 - (1.0 - p_two_of_three) * (1.0 - p_vote)


def nn_misclassification(p_mult: np.ndarray, cs: AlexNetCaseStudy = AlexNetCaseStudy()) -> np.ndarray:
    """P[soft-error-induced misclassification of one sample] (Fig. 4 bottom)."""
    p_mult = np.asarray(p_mult, dtype=np.float64)
    # log1p form to stay stable for tiny probabilities at M = 6.1e8
    return -np.expm1(cs.M * np.log1p(-cs.p_mask * p_mult))


def weight_corruption_baseline(p_input: float, T: np.ndarray,
                               cs: AlexNetCaseStudy = AlexNetCaseStudy()) -> np.ndarray:
    """P[a given weight is corrupted after T batches], no ECC."""
    T = np.asarray(T, dtype=np.float64)
    q = -math.expm1(cs.bits_per_weight * math.log1p(-p_input))  # per-batch
    return -np.expm1(T * np.log1p(-q))


def weight_corruption_ecc(p_input: float, T: np.ndarray, m: int = 16,
                          cs: AlexNetCaseStudy = AlexNetCaseStudy()) -> np.ndarray:
    """P[a given weight is corrupted after T batches] with diagonal ECC,
    scrubbed every batch: a block (m*m bits) fails only if >= 2 of its bits
    flip within one scrub interval; the failing block corrupts the weights
    stored in it (bits_per_weight of its m*m bits belong to this weight)."""
    T = np.asarray(T, dtype=np.float64)
    n = m * m
    # P[>= 2 errors in a block in one batch]
    log_p0 = n * math.log1p(-p_input)
    p0 = math.exp(log_p0)
    p1 = n * p_input * math.exp((n - 1) * math.log1p(-p_input))
    p_block_fail = max(0.0, 1.0 - p0 - p1)
    # conservative: a block failure corrupts every weight stored in it
    p_weight_per_batch = p_block_fail
    return -np.expm1(T * np.log1p(-min(p_weight_per_batch, 1.0)))


def weight_corruption_ecc_refined(p_input: float, T: np.ndarray, m: int = 16,
                                  cs: AlexNetCaseStudy = AlexNetCaseStudy()) -> np.ndarray:
    """Refined ECC model: the *specific* weight is corrupted only if at least
    one of its own bits flips while the block is uncorrectable, i.e.
    (>=1 error in the weight's w bits) AND (>=1 more error elsewhere in the
    block), or >=2 errors within the weight itself.  First-order in p_input^2:

        p ~ w*p * (n-w)*p + C(w,2) p^2
    """
    T = np.asarray(T, dtype=np.float64)
    n, w = m * m, cs.bits_per_weight
    p = p_input
    p_weight_per_batch = w * p * (n - w) * p + (w * (w - 1) / 2) * p * p
    return -np.expm1(T * np.log1p(-min(p_weight_per_batch, 1.0)))


def expected_corrupted_weights(p_corrupt: np.ndarray,
                               cs: AlexNetCaseStudy = AlexNetCaseStudy()) -> np.ndarray:
    """E[# corrupted weights] (Fig. 5 y-axis)."""
    return cs.W * np.asarray(p_corrupt, dtype=np.float64)


# --------------------------------------------------------------------------
# scrub-engine telemetry (§IV mechanism observed live in the runtime)
# --------------------------------------------------------------------------

def expected_scrub_rates(p_bit: float, n_blocks: int,
                         words_per_block: int = 32,
                         bits_per_word: int = 32) -> Dict[str, float]:
    """Per-scrub expectations for the word-level code under iid bit flips.

    A 32-word block holds n = 32*32 data bits.  With per-bit flip
    probability p per scrub interval: a block is corrected if exactly one
    bit flipped, uncorrectable if >= 2 flipped (parity-word flips are not
    injected by inject_bit_flips, so parity_fixed ~ 0).
    """
    n = words_per_block * bits_per_word
    log_p0 = n * math.log1p(-p_bit) if p_bit < 1 else -math.inf
    p0 = math.exp(log_p0)
    p1 = n * p_bit * math.exp((n - 1) * math.log1p(-p_bit)) if p_bit < 1 else 0.0
    return {
        "corrected_per_scrub": n_blocks * p1,
        "uncorrectable_per_scrub": n_blocks * max(0.0, 1.0 - p0 - p1),
    }


@dataclasses.dataclass
class ScrubTrajectory:
    """Accumulates ScrubReport telemetry from the runtime loop and compares
    the observed correction stream against the closed-form model above."""

    n_blocks: int = 0
    steps: list = dataclasses.field(default_factory=list)
    corrected: list = dataclasses.field(default_factory=list)
    parity_fixed: list = dataclasses.field(default_factory=list)
    uncorrectable: list = dataclasses.field(default_factory=list)

    def add(self, step: int, corrected: int, parity_fixed: int,
            uncorrectable: int) -> None:
        self.steps.append(int(step))
        self.corrected.append(int(corrected))
        self.parity_fixed.append(int(parity_fixed))
        self.uncorrectable.append(int(uncorrectable))

    @property
    def n_scrubs(self) -> int:
        return len(self.steps)

    def totals(self) -> Dict[str, int]:
        return {"corrected": sum(self.corrected),
                "parity_fixed": sum(self.parity_fixed),
                "uncorrectable": sum(self.uncorrectable)}

    def observed_flip_rate(self) -> float:
        """MLE of the per-bit flip rate from the correction stream (valid in
        the sparse regime where nearly all flips are single-bit/block)."""
        if not self.n_scrubs or not self.n_blocks:
            return 0.0
        bits_scanned = self.n_scrubs * self.n_blocks * 32 * 32
        flips = sum(self.corrected) + 2 * sum(self.uncorrectable)
        return flips / bits_scanned

    def rate_per_scrub(self) -> float:
        """Observed correction *events* per scrub interval: corrected words
        plus double-weighted uncorrectable blocks (the flips-observed
        accounting shared with `observed_flip_rate` and the runtime's
        `obs.DriftDetector`)."""
        if not self.n_scrubs:
            return 0.0
        return (sum(self.corrected)
                + 2 * sum(self.uncorrectable)) / self.n_scrubs

    def drift_ratio(self, p_bit: float) -> float:
        """Observed-over-expected event rate for a known injection rate
        (1.0 = on-model).  Infinity when corrections appear with no model
        prior; 1.0 when both sides are silent."""
        observed = self.rate_per_scrub()
        if p_bit <= 0 or not self.n_blocks:
            return float("inf") if observed > 0 else 1.0
        exp = expected_scrub_rates(p_bit, self.n_blocks)
        expected = (exp["corrected_per_scrub"]
                    + 2 * exp["uncorrectable_per_scrub"])
        if expected == 0:
            return float("inf") if observed > 0 else 1.0
        return observed / expected

    def summary(self, p_bit: float = 0.0) -> Dict[str, float]:
        out: Dict[str, float] = dict(self.totals())
        out["n_scrubs"] = self.n_scrubs
        out["observed_flip_rate"] = self.observed_flip_rate()
        if p_bit > 0 and self.n_blocks:
            exp = expected_scrub_rates(p_bit, self.n_blocks)
            out["expected_corrected_per_scrub"] = exp["corrected_per_scrub"]
            out["expected_uncorrectable_per_scrub"] = exp["uncorrectable_per_scrub"]
            out["drift_ratio"] = self.drift_ratio(p_bit)
        return out
