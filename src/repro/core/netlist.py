"""Minority3-normalized gate netlists with fault injection.

The mMPU maps arithmetic functions to sequences of stateful gates (§III-B).
We model a function as a *netlist* of Minority3 gates (every FELIX/MAGIC gate
is Min3 with constant inputs: NOR(a,b)=Min3(a,b,1), NAND(a,b)=Min3(a,b,0),
NOT(a)=Min3(a,a,0)), executed sequentially — exactly the "micro-code gate
requests" the paper's modified MultPIM simulator injects faults into (§VI-A).

Execution is vectorized over trials (= crossbar row parallelism) with
`lax.scan` over gates.  Fault modes:

* iid          — every gate output flips w.p. p_gate (direct soft errors)
* single-fault — trial t flips exactly gate fault_gate[t]; with
                 fault_gate = arange(G) one pass measures logical masking of
                 every gate position exhaustively (used to extrapolate
                 p_mult at low p_gate, see analytics.py)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..faults.models import FaultModel

__all__ = ["Netlist", "NetlistBuilder", "execute", "full_adder"]


@dataclasses.dataclass(frozen=True)
class Netlist:
    n_wires: int
    inputs: np.ndarray        # (n_in,) wire ids
    outputs: np.ndarray       # (n_out,) wire ids
    gates: np.ndarray         # (G, 4) int32: in1, in2, in3, out (all Min3)

    @property
    def n_gates(self) -> int:
        return int(self.gates.shape[0])


class NetlistBuilder:
    """Builds Min3 netlists with constant folding, duplicate-input
    simplification and structural-hash CSE (keeps the gate count honest vs.
    hand-mapped micro-code).

    CSE: Min3 is symmetric and every gate is pure SSA (each output is a
    fresh wire computed only from earlier wires), so two gates with the
    same *sorted* input triple always carry the same value — the second
    emission returns the first gate's output wire instead of a new gate.
    Pass cse=False to keep duplicates (e.g. to measure the reduction).
    """

    ZERO = 0
    ONE = 1

    def __init__(self, cse: bool = True):
        self._n = 2                    # wires 0/1 are constants
        self._gates: List[tuple] = []
        self._inputs: List[int] = []
        self._outputs: List[int] = []
        self._cse: Optional[Dict[Tuple[int, int, int], int]] = {} if cse else None

    # -- wires ---------------------------------------------------------------
    def input_bits(self, n: int) -> List[int]:
        ws = list(range(self._n, self._n + n))
        self._n += n
        self._inputs.extend(ws)
        return ws

    def mark_outputs(self, wires: Sequence[int]) -> None:
        self._outputs.extend(int(w) for w in wires)

    def _emit(self, a: int, b: int, c: int) -> int:
        if self._cse is not None:
            key = tuple(sorted((a, b, c)))
            hit = self._cse.get(key)
            if hit is not None:
                return hit
        out = self._n
        self._n += 1
        self._gates.append((a, b, c, out))
        if self._cse is not None:
            self._cse[key] = out
        return out

    # -- primitive: Minority3 with folding -------------------------------------
    def min3(self, a: int, b: int, c: int) -> int:
        ins = sorted((a, b, c))
        consts = [w for w in ins if w in (self.ZERO, self.ONE)]
        # fully constant
        if len(consts) == 3:
            maj = sum(1 for w in ins if w == self.ONE) >= 2
            return self.ZERO if maj else self.ONE
        # two constants: result is const or NOT(x)
        if len(consts) == 2:
            x = next(w for w in ins if w not in (self.ZERO, self.ONE))
            ones = consts.count(self.ONE)
            if ones == 2:
                return self.ZERO            # maj = 1
            if ones == 0:
                return self.ONE             # maj = 0
            return self._emit(x, x, self.ZERO)  # maj = x -> NOT x
        # duplicate non-const input: Min3(a,a,c) = NOT a
        if a == b or a == c:
            return self._emit(a, a, self.ZERO)
        if b == c:
            return self._emit(b, b, self.ZERO)
        return self._emit(a, b, c)

    # -- derived gates ---------------------------------------------------------
    def not_(self, a: int) -> int:
        if a == self.ZERO:
            return self.ONE
        if a == self.ONE:
            return self.ZERO
        return self.min3(a, a, self.ZERO)

    def nor(self, a: int, b: int) -> int:
        return self.min3(a, b, self.ONE)

    def nand(self, a: int, b: int) -> int:
        return self.min3(a, b, self.ZERO)

    def and_(self, a: int, b: int) -> int:
        if a == self.ZERO or b == self.ZERO:
            return self.ZERO
        if a == self.ONE:
            return b
        if b == self.ONE:
            return a
        return self.not_(self.nand(a, b))

    def or_(self, a: int, b: int) -> int:
        if a == self.ONE or b == self.ONE:
            return self.ONE
        if a == self.ZERO:
            return b
        if b == self.ZERO:
            return a
        return self.not_(self.nor(a, b))

    def xor(self, a: int, b: int) -> int:
        if a == self.ZERO:
            return b
        if b == self.ZERO:
            return a
        if a == self.ONE:
            return self.not_(b)
        if b == self.ONE:
            return self.not_(a)
        if a == b:
            return self.ZERO
        # 5-NOR decomposition
        x1 = self.nor(a, b)
        x2 = self.nor(a, x1)
        x3 = self.nor(b, x1)
        return self.not_(self.nor(x2, x3))

    def maj3(self, a: int, b: int, c: int) -> int:
        if a == self.ZERO:
            return self.and_(b, c)
        if b == self.ZERO:
            return self.and_(a, c)
        if c == self.ZERO:
            return self.and_(a, b)
        if a == self.ONE:
            return self.or_(b, c)
        if b == self.ONE:
            return self.or_(a, c)
        if c == self.ONE:
            return self.or_(a, b)
        return self.not_(self.min3(a, b, c))

    def build(self) -> Netlist:
        return Netlist(
            n_wires=self._n,
            inputs=np.asarray(self._inputs, np.int32),
            outputs=np.asarray(self._outputs, np.int32),
            gates=np.asarray(self._gates, np.int32).reshape(-1, 4),
        )


def full_adder(bld: NetlistBuilder, a: int, b: int, c: int):
    """sum = a^b^c (10 gates), carry = Maj3 (2 gates); folds to a half adder
    when any input is constant."""
    s = bld.xor(bld.xor(a, b), c)
    cout = bld.maj3(a, b, c)
    return s, cout


def execute(nl: Netlist, inputs: jax.Array,
            key: Optional[jax.Array] = None, p_gate=0.0,
            fault_gate: Optional[jax.Array] = None) -> jax.Array:
    """Run the netlist on a batch of input vectors (reference lax.scan path).

    inputs:     bool (trials, n_in)
    key/p_gate: iid per-gate fault injection; p_gate may also be any
                faults.FaultModel (matching stateful_logic.maybe_flip) —
                gate gid's output is corrupted under fold_in(key, gid)
    fault_gate: int32 (trials,) — trial t flips exactly gate fault_gate[t]
                (exhaustive single-fault analysis); -1 disables for a trial.

    Returns bool (trials, n_out).  The levelized engines
    (core/scheduler.py, kernels/netlist_exec) are bit-exact against this
    path, fault streams included.
    """
    trials = inputs.shape[0]
    state = jnp.zeros((trials, nl.n_wires), jnp.bool_)
    state = state.at[:, 1].set(True)
    state = state.at[:, jnp.asarray(nl.inputs)].set(inputs)

    gates = jnp.asarray(nl.gates)                       # (G, 4)
    gate_ids = jnp.arange(nl.n_gates, dtype=jnp.int32)

    is_model = isinstance(p_gate, FaultModel)
    use_iid = key is not None and (is_model or p_gate > 0.0)
    use_single = fault_gate is not None

    def step(state, xs):
        gid, row = xs
        i1, i2, i3, out = row[0], row[1], row[2], row[3]
        a = jax.lax.dynamic_index_in_dim(state, i1, axis=1, keepdims=False)
        b = jax.lax.dynamic_index_in_dim(state, i2, axis=1, keepdims=False)
        c = jax.lax.dynamic_index_in_dim(state, i3, axis=1, keepdims=False)
        maj = (a & b) | (b & c) | (a & c)
        val = jnp.logical_not(maj)
        if use_iid:
            gk = jax.random.fold_in(key, gid)
            if is_model:
                val = p_gate.corrupt_bits(val, gk)
            else:
                val = jnp.logical_xor(
                    val, jax.random.bernoulli(gk, p_gate, (trials,)))
        if use_single:
            val = jnp.logical_xor(val, fault_gate == gid)
        state = jax.lax.dynamic_update_index_in_dim(state, val, out, axis=1)
        return state, None

    state, _ = jax.lax.scan(step, state, (gate_ids, gates))
    return state[:, jnp.asarray(nl.outputs)]
