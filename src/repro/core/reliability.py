"""TPU adaptation of the paper's reliability mechanisms (DESIGN.md §3).

The diagonal-parity code is re-tiled for the TPU memory hierarchy: instead of
an m x m crossbar block of memristors, a block is **32 consecutive uint32
words** of an HBM-resident parameter buffer — a 32 x 32 bit matrix whose
rows are words and whose columns are bit lanes.  The key identity:

    parity word of slope s over block W[0..31]  =  XOR_i rotl32(W[i], s*i)

i.e. bit k of the parity word is XOR_i W[i][(k - s*i) mod 32] — exactly the
paper's wrap-around diagonal, with the 32-bit *rotate playing the role of the
barrel shifter*.  Both "row" updates (a whole word rewritten) and "column"
updates (one bit lane across words, e.g. a sign-bit flip pattern) update the
parity in O(1) vector ops, preserving the paper's central property.

Families: slopes (1, 2, -1): (1,2) locate a single flipped bit per block
(gcd(2-1,32)=1); (-1) is the paper's counter-diagonal, kept as an integrity
check (see DESIGN.md §8).  Storage overhead = 3/32 ~ 9.4%.

`ReliableStore` wraps a parameter pytree: encode once, `scrub()` between
training steps verifies and corrects bit flips (SDC defense), and reports
uncorrectable blocks so the runtime can trigger a checkpoint restore —
connecting the paper's mechanism to large-scale fault tolerance.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .bitops import bit_position, popcount32, rotl32
from .tmr import vote_array

__all__ = ["WordEccConfig", "encode_words", "syndrome_words", "correct_words",
           "ReliableStore", "ScrubReport", "inject_bit_flips", "tmr_serve"]

BLOCK = 32  # words per block == bits per word


@dataclasses.dataclass(frozen=True)
class WordEccConfig:
    slopes: Tuple[int, ...] = (1, 2, -1)

    @property
    def n_parity_words(self) -> int:
        return len(self.slopes)


def _as_blocks(words: jax.Array) -> jax.Array:
    assert words.ndim == 1 and words.shape[0] % BLOCK == 0
    return words.reshape(-1, BLOCK)


def encode_words(words: jax.Array, cfg: WordEccConfig = WordEccConfig()) -> jax.Array:
    """Parity words for a flat uint32 buffer: (n_blocks, n_families).

    parity[b, f] = XOR_i rotl32(words[b*32 + i], slopes[f] * i)
    """
    blocks = _as_blocks(words)                              # (B, 32)
    i = jnp.arange(BLOCK, dtype=jnp.int32)
    outs = []
    for s in cfg.slopes:
        rot = rotl32(blocks, (s * i) % BLOCK)               # (B, 32)
        acc = rot[:, 0]
        for t in range(1, BLOCK):
            acc = acc ^ rot[:, t]
        outs.append(acc)
    return jnp.stack(outs, axis=-1)                         # (B, F)


def syndrome_words(words: jax.Array, parity: jax.Array,
                   cfg: WordEccConfig = WordEccConfig()) -> jax.Array:
    return encode_words(words, cfg) ^ parity


class ScrubReport(NamedTuple):
    corrected: jax.Array        # int32: blocks with a single bit corrected
    parity_fixed: jax.Array     # int32: blocks where a check word was fixed
    uncorrectable: jax.Array    # int32: blocks with >= 2 errors


def correct_words(words: jax.Array, parity: jax.Array,
                  cfg: WordEccConfig = WordEccConfig()):
    """Locate and correct one flipped bit per 32-word block.

    For an error in data word i0, bit j0: family-s syndrome is one-hot with
    hot bit k_s = (j0 + s*i0) mod 32 (rotl by s*i moves bit j to j + s*i).
    With slopes (1,2): i0 = k_2 - k_1, j0 = k_1 - i0 (mod 32).
    """
    slopes = list(cfg.slopes)
    syn = syndrome_words(words, parity, cfg)                # (B, F)
    pop = popcount32(syn)                                   # (B, F)
    hot = jnp.stack([bit_position(syn[:, f]) for f in range(len(slopes))], -1)
    nonzero = pop > 0
    onehot = pop == 1
    n_nonzero = nonzero.astype(jnp.int32).sum(-1)

    ia, ib = slopes.index(1), slopes.index(2)
    i0 = (hot[:, ib] - hot[:, ia]) % BLOCK
    j0 = (hot[:, ia] - i0) % BLOCK
    consistent = jnp.ones(syn.shape[0], dtype=bool)
    for f, s in enumerate(slopes):
        consistent &= hot[:, f] == (j0 + s * i0) % BLOCK

    data_err = (n_nonzero == len(slopes)) & onehot.all(-1) & consistent
    parity_err = (n_nonzero == 1) & (onehot | ~nonzero).all(-1)
    uncorrectable = (n_nonzero > 0) & ~data_err & ~parity_err

    blocks = _as_blocks(words)
    flip_word = jnp.where(data_err,
                          jnp.uint32(1) << j0.astype(jnp.uint32),
                          jnp.uint32(0))
    onehot_row = (jnp.arange(BLOCK)[None, :] == i0[:, None])
    blocks = blocks ^ (onehot_row.astype(jnp.uint32) * flip_word[:, None])
    parity_fix = jnp.where((parity_err[:, None] & nonzero), syn, jnp.uint32(0))
    report = ScrubReport(
        corrected=data_err.astype(jnp.int32).sum(),
        parity_fixed=parity_err.astype(jnp.int32).sum(),
        uncorrectable=uncorrectable.astype(jnp.int32).sum(),
    )
    return blocks.reshape(-1), parity ^ parity_fix, report


# --------------------------------------------------------------------------
# parameter-store integration
# --------------------------------------------------------------------------

def _leaf_to_words(x: jax.Array) -> Tuple[jax.Array, int]:
    """View any leaf as a zero-padded flat uint32 buffer (pad length in words)."""
    if x.dtype == jnp.bfloat16:
        # pack pairs of u16 halves into u32 words (pad to even length)
        u16 = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint16)
        if u16.shape[0] % 2:
            u16 = jnp.pad(u16, (0, 1))
        flat = u16[0::2].astype(jnp.uint32) | (u16[1::2].astype(jnp.uint32) << 16)
    elif x.dtype == jnp.float32:
        flat = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint32)
    elif x.dtype in (jnp.int32, jnp.uint32):
        flat = x.reshape(-1).astype(jnp.uint32)
    else:
        raise TypeError(f"ReliableStore: unsupported dtype {x.dtype}")
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def _words_to_leaf(words: jax.Array, like: jax.Array, pad: int) -> jax.Array:
    if pad:
        words = words[:-pad] if like.dtype != jnp.bfloat16 else words
    if like.dtype == jnp.bfloat16:
        u16 = jnp.stack([(words & 0xFFFF).astype(jnp.uint16),
                         (words >> 16).astype(jnp.uint16)], -1).reshape(-1)
        n = int(np_prod(like.shape))
        u16 = u16[:n]
        return jax.lax.bitcast_convert_type(u16, jnp.bfloat16).reshape(like.shape)
    if like.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(words, jnp.float32).reshape(like.shape)
    return words.astype(like.dtype).reshape(like.shape)


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


@jax.tree_util.register_pytree_node_class
class ReliableStore:
    """ECC-protected parameter pytree (the paper's §IV at datacenter scale).

    params are stored as-is (zero-copy for the forward pass); check words are
    held alongside.  `scrub()` re-derives syndromes and corrects single-bit
    flips per 32-word block, returning a ScrubReport.  Call `refresh(params)`
    after an optimizer step rewrites the weights (the "function output ECC
    update" of §IV — here whole buffers change, so re-encode; incremental
    column/row updates are exercised in core/ecc.py and the Pallas kernel).
    """

    def __init__(self, params: Any, parity: Any, cfg: WordEccConfig = WordEccConfig()):
        self.params = params
        self.parity = parity
        self.cfg = cfg

    @staticmethod
    def protect(params: Any, cfg: WordEccConfig = WordEccConfig()) -> "ReliableStore":
        def enc(x):
            words, _ = _leaf_to_words(x)
            return encode_words(words, cfg)
        return ReliableStore(params, jax.tree.map(enc, params), cfg)

    def refresh(self, new_params: Any) -> "ReliableStore":
        return ReliableStore.protect(new_params, self.cfg)

    def scrub(self) -> Tuple["ReliableStore", ScrubReport]:
        cfg = self.cfg

        def fix(x, par):
            words, pad = _leaf_to_words(x)
            fixed, par2, rep = correct_words(words, par, cfg)
            return _words_to_leaf(fixed, x, pad), par2, rep

        leaves, treedef = jax.tree.flatten(self.params)
        pleaves = treedef.flatten_up_to(self.parity)
        out_p, out_c, reps = [], [], []
        for x, par in zip(leaves, pleaves):
            xf, pf, rep = fix(x, par)
            out_p.append(xf)
            out_c.append(pf)
            reps.append(rep)
        total = ScrubReport(
            corrected=sum(r.corrected for r in reps),
            parity_fixed=sum(r.parity_fixed for r in reps),
            uncorrectable=sum(r.uncorrectable for r in reps),
        )
        return ReliableStore(treedef.unflatten(out_p), treedef.unflatten(out_c),
                             cfg), total

    # pytree plumbing
    def tree_flatten(self):
        return (self.params, self.parity), self.cfg

    @classmethod
    def tree_unflatten(cls, cfg, children):
        return cls(children[0], children[1], cfg)


def inject_bit_flips(params: Any, key: jax.Array, p_bit: float) -> Any:
    """Indirect-soft-error injector: flip each stored bit w.p. p_bit."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    out = []
    for x, k in zip(leaves, keys):
        words, pad = _leaf_to_words(x)
        nbits = words.shape[0] * 32
        flips = jax.random.bernoulli(k, p_bit, (words.shape[0], 32))
        mask = (flips.astype(jnp.uint32) << jnp.arange(32, dtype=jnp.uint32)[None, :]).sum(
            axis=1, dtype=jnp.uint32)
        out.append(_words_to_leaf(words ^ mask, x, pad))
    return treedef.unflatten(out)


def tmr_serve(serve_fn, mode: str = "serial"):
    """TMR-voted serving (paper §V on TPU): run the model 3x, vote per-bit.

    serve_fn(params, *inputs) -> pytree of arrays.  The three copies receive
    independently *scrubbed/corrupted* params via an optional corruptor in
    tests; in production the copies run on disjoint replica groups (parallel
    mode shards the leading replica axis over the mesh).
    """
    def serial(p1, p2, p3, *inputs):
        o1 = serve_fn(p1, *inputs)
        o2 = serve_fn(p2, *inputs)
        o3 = serve_fn(p3, *inputs)
        return jax.tree.map(vote_array, o1, o2, o3)

    def parallel(p1, p2, p3, *inputs):
        stacked = jax.tree.map(lambda a, b, c: jnp.stack([a, b, c]), p1, p2, p3)
        outs = jax.vmap(lambda p: serve_fn(p, *inputs))(stacked)
        o1, o2, o3 = (jax.tree.map(lambda x, i=i: x[i], outs) for i in range(3))
        return jax.tree.map(vote_array, o1, o2, o3)

    return serial if mode == "serial" else parallel
