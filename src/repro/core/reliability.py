"""TPU adaptation of the paper's reliability mechanisms (DESIGN.md §3).

The diagonal-parity code is re-tiled for the TPU memory hierarchy: instead of
an m x m crossbar block of memristors, a block is **32 consecutive uint32
words** of an HBM-resident parameter buffer — a 32 x 32 bit matrix whose
rows are words and whose columns are bit lanes.  The key identity:

    parity word of slope s over block W[0..31]  =  XOR_i rotl32(W[i], s*i)

i.e. bit k of the parity word is XOR_i W[i][(k - s*i) mod 32] — exactly the
paper's wrap-around diagonal, with the 32-bit *rotate playing the role of the
barrel shifter*.  Both "row" updates (a whole word rewritten) and "column"
updates (one bit lane across words, e.g. a sign-bit flip pattern) update the
parity in O(1) vector ops, preserving the paper's central property.

Families: slopes (1, 2, -1): (1,2) locate a single flipped bit per block
(gcd(2-1,32)=1); (-1) is the paper's counter-diagonal, kept as an integrity
check (see DESIGN.md §8).  Storage overhead = 3/32 ~ 9.4%.

`ReliableStore` wraps a parameter pytree.  The pytree is flattened into the
packed arena of core/arena.py — one contiguous uint32 buffer with every leaf
block-aligned — so protect, scrub and refresh are each ONE fused Pallas
launch over the whole model (DESIGN.md §9) instead of a per-leaf Python
loop.  `scrub()` verifies and corrects bit flips between training steps (SDC
defense) and reports uncorrectable blocks so the runtime can trigger a
checkpoint restore — connecting the paper's mechanism to large-scale fault
tolerance.  The pure-jnp word functions (`encode_words`, `correct_words`)
are retained both as the kernels' bit-exact oracle and as the
`backend="jnp"` fallback.

NOTE (DESIGN.md §12): the public protection API is now
`repro.reliability` — `DiagParityEcc()` wraps this module's machinery
behind the composable `Scheme` protocol and the backend registry.
`ReliableStore` and `tmr_serve` remain as bit-exact building blocks /
deprecation shims.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import arena
from .bitops import bit_position, popcount32, rotl32

__all__ = ["WordEccConfig", "encode_words", "syndrome_words", "correct_words",
           "ReliableStore", "ScrubReport", "tmr_serve",
           "protect_leaves", "scrub_leaves"]

BLOCK = arena.BLOCK  # words per block == bits per word


@dataclasses.dataclass(frozen=True)
class WordEccConfig:
    slopes: Tuple[int, ...] = (1, 2, -1)

    @property
    def n_parity_words(self) -> int:
        return len(self.slopes)


def _as_blocks(words: jax.Array) -> jax.Array:
    assert words.ndim == 1 and words.shape[0] % BLOCK == 0
    return words.reshape(-1, BLOCK)


def encode_words(words: jax.Array, cfg: WordEccConfig = WordEccConfig()) -> jax.Array:
    """Parity words for a flat uint32 buffer: (n_blocks, n_families).

    parity[b, f] = XOR_i rotl32(words[b*32 + i], slopes[f] * i)
    """
    blocks = _as_blocks(words)                              # (B, 32)
    i = jnp.arange(BLOCK, dtype=jnp.int32)
    outs = []
    for s in cfg.slopes:
        rot = rotl32(blocks, (s * i) % BLOCK)               # (B, 32)
        acc = rot[:, 0]
        for t in range(1, BLOCK):
            acc = acc ^ rot[:, t]
        outs.append(acc)
    return jnp.stack(outs, axis=-1)                         # (B, F)


def syndrome_words(words: jax.Array, parity: jax.Array,
                   cfg: WordEccConfig = WordEccConfig()) -> jax.Array:
    return encode_words(words, cfg) ^ parity


class ScrubReport(NamedTuple):
    corrected: jax.Array        # int32: blocks with a single bit corrected
    parity_fixed: jax.Array     # int32: blocks where a check word was fixed
    uncorrectable: jax.Array    # int32: blocks with >= 2 errors


def correct_words(words: jax.Array, parity: jax.Array,
                  cfg: WordEccConfig = WordEccConfig()):
    """Locate and correct one flipped bit per 32-word block.

    For an error in data word i0, bit j0: family-s syndrome is one-hot with
    hot bit k_s = (j0 + s*i0) mod 32 (rotl by s*i moves bit j to j + s*i).
    With slopes (1,2): i0 = k_2 - k_1, j0 = k_1 - i0 (mod 32).
    """
    slopes = list(cfg.slopes)
    syn = syndrome_words(words, parity, cfg)                # (B, F)
    pop = popcount32(syn)                                   # (B, F)
    hot = jnp.stack([bit_position(syn[:, f]) for f in range(len(slopes))], -1)
    nonzero = pop > 0
    onehot = pop == 1
    n_nonzero = nonzero.astype(jnp.int32).sum(-1)

    ia, ib = slopes.index(1), slopes.index(2)
    i0 = (hot[:, ib] - hot[:, ia]) % BLOCK
    j0 = (hot[:, ia] - i0) % BLOCK
    consistent = jnp.ones(syn.shape[0], dtype=bool)
    for f, s in enumerate(slopes):
        consistent &= hot[:, f] == (j0 + s * i0) % BLOCK

    data_err = (n_nonzero == len(slopes)) & onehot.all(-1) & consistent
    parity_err = (n_nonzero == 1) & (onehot | ~nonzero).all(-1)
    uncorrectable = (n_nonzero > 0) & ~data_err & ~parity_err

    blocks = _as_blocks(words)
    flip_word = jnp.where(data_err,
                          jnp.uint32(1) << j0.astype(jnp.uint32),
                          jnp.uint32(0))
    onehot_row = (jnp.arange(BLOCK)[None, :] == i0[:, None])
    blocks = blocks ^ (onehot_row.astype(jnp.uint32) * flip_word[:, None])
    parity_fix = jnp.where((parity_err[:, None] & nonzero), syn, jnp.uint32(0))
    report = ScrubReport(
        corrected=data_err.astype(jnp.int32).sum(),
        parity_fixed=parity_err.astype(jnp.int32).sum(),
        uncorrectable=uncorrectable.astype(jnp.int32).sum(),
    )
    return blocks.reshape(-1), parity ^ parity_fix, report


# --------------------------------------------------------------------------
# parameter-store integration (arena-backed)
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class ReliableStore:
    """ECC-protected parameter pytree (the paper's §IV at datacenter scale).

    params are stored as-is (zero-copy for the forward pass); the parity
    table of the *packed arena* — one (n_blocks, n_families) uint32 array
    covering every leaf — is held alongside.  `scrub()` packs the pytree,
    runs the fused encode->syndrome->locate->correct Pallas kernel in a
    single launch, and unpacks the corrected arena, returning a ScrubReport.
    Call `refresh(params)` after an optimizer step rewrites the weights (the
    "function output ECC update" of §IV — whole buffers change, so re-encode
    with the one-launch encode kernel; incremental column/row updates are
    exercised in core/ecc.py).

    backend="kernel" (default) dispatches the Pallas kernels;
    backend="jnp" runs the pure-jnp oracle on the same arena (bit-exact,
    used for verification and on hosts without Pallas support).
    """

    def __init__(self, params: Any, parity: jax.Array,
                 cfg: WordEccConfig = WordEccConfig(),
                 backend: str = "kernel"):
        assert backend in ("kernel", "jnp"), backend
        self.params = params
        self.parity = parity
        self.cfg = cfg
        self.backend = backend
        # best-effort cache of (packed arena, spec) for params as stored.
        # protect/scrub fill it, so a scrub right after a refresh (the loop's
        # steady state) does not pack the same pytree twice.  Dropped by
        # tree_flatten — stores crossing a jit boundary just repack.
        self._packed: Optional[Tuple[jax.Array, arena.ArenaSpec]] = None

    # the single implementation of pack/encode/scrub lives in the scheme
    # layer (DESIGN.md §12); this class adapts it to the historic surface
    def _scheme(self):
        from ..reliability.scheme import DiagParityEcc
        return DiagParityEcc(slopes=self.cfg.slopes, impl=self.backend)

    @classmethod
    def _from_protected(cls, prot, cfg: WordEccConfig,
                        backend: str) -> "ReliableStore":
        store = cls(prot.payload, prot.redundancy, cfg, backend)
        store._packed = prot._packed
        return store

    @staticmethod
    def protect(params: Any, cfg: WordEccConfig = WordEccConfig(),
                backend: str = "kernel") -> "ReliableStore":
        from ..reliability.scheme import DiagParityEcc
        scheme = DiagParityEcc(slopes=cfg.slopes, impl=backend)
        return ReliableStore._from_protected(scheme.protect(params),
                                             cfg, backend)

    def refresh(self, new_params: Any) -> "ReliableStore":
        return ReliableStore.protect(new_params, self.cfg, self.backend)

    def scrub(self) -> Tuple["ReliableStore", ScrubReport]:
        scheme = self._scheme()
        prot = scheme.adopt(self.params, self.parity)
        prot._packed = self._packed
        fixed, report = scheme.scrub(prot)
        return self._from_protected(fixed, self.cfg, self.backend), report

    @property
    def n_blocks(self) -> int:
        return int(self.parity.shape[0])

    # pytree plumbing
    def tree_flatten(self):
        return (self.params, self.parity), (self.cfg, self.backend)

    @classmethod
    def tree_unflatten(cls, aux, children):
        cfg, backend = aux
        return cls(children[0], children[1], cfg, backend)


# --------------------------------------------------------------------------
# legacy per-leaf path — N dispatches, one per pytree leaf.  Kept only as
# the baseline that benchmarks/kernels_bench.py measures the arena against.
# --------------------------------------------------------------------------

def _leaf_spec(x: jax.Array, n_words: int) -> arena.LeafSpec:
    return arena.LeafSpec(offset=0, n_words=n_words, pad_words=0,
                          dtype=x.dtype, shape=tuple(x.shape))


def _pad_leaf_words(x: jax.Array) -> jax.Array:
    words = arena.leaf_to_words(x)
    pad = (-words.shape[0]) % BLOCK
    return jnp.pad(words, (0, pad)) if pad else words


def protect_leaves(params: Any, cfg: WordEccConfig = WordEccConfig()) -> Any:
    """Per-leaf parity tree (the pre-arena layout): one encode per leaf."""
    return jax.tree.map(lambda x: encode_words(_pad_leaf_words(x), cfg), params)


def scrub_leaves(params: Any, parity_tree: Any,
                 cfg: WordEccConfig = WordEccConfig()):
    """Per-leaf jnp scrub loop (the pre-arena hot path): one dispatch per
    leaf plus a Python-level reduction of the reports."""
    leaves, treedef = jax.tree.flatten(params)
    pleaves = treedef.flatten_up_to(parity_tree)
    out_p, out_c, reps = [], [], []
    for x, par in zip(leaves, pleaves):
        words = _pad_leaf_words(x)
        fixed, par2, rep = correct_words(words, par, cfg)
        n_words = arena._words_per_leaf(x)
        out_p.append(arena.words_to_leaf(fixed[:n_words], _leaf_spec(x, n_words)))
        out_c.append(par2)
        reps.append(rep)
    total = ScrubReport(
        corrected=sum(r.corrected for r in reps),
        parity_fixed=sum(r.parity_fixed for r in reps),
        uncorrectable=sum(r.uncorrectable for r in reps),
    )
    return treedef.unflatten(out_p), treedef.unflatten(out_c), total


# Deprecated re-export (module attribute only — dropped from __all__): the
# canonical transient injector lives in repro.faults.models as part of the
# unified FaultModel taxonomy.  Kept one release so historic
# `from repro.core.reliability import inject_bit_flips` call sites keep
# working; new code must use repro.faults directly.
from ..faults.models import inject_bit_flips  # noqa: E402,F401


def tmr_serve(serve_fn, mode: str = "serial", use_kernel: bool = True):
    """DEPRECATED shim: TMR-voted serving via `repro.reliability.Tmr.wrap`.

    serve_fn(params, *inputs) -> pytree of arrays; the wrapper is called as
    wrapped(p1, p2, p3, *inputs) with per-copy parameter versions.  All
    three paper disciplines are accepted ('serial', 'parallel',
    'semi_parallel'); use_kernel=False selects the jnp voter.  New code
    should construct `Tmr(discipline=...).wrap(serve_fn)` directly
    (DESIGN.md §12) — this shim is bit-exact against it by construction.
    """
    from ..reliability.scheme import Tmr
    return Tmr(discipline=mode,
               impl=None if use_kernel else "jnp").wrap(serve_fn)
