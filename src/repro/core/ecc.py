"""Diagonal-parity ECC for high-throughput memristive PIM (paper §IV).

Check bits are stored along *wrap-around diagonals* of each m x m block of
the crossbar.  Because every diagonal intersects each row exactly once and
each column exactly once, the parity update after an in-row OR in-column
vectored operation is O(1) cycles — the property horizontal parity lacks
(Fig. 2(a) vs 2(b)).  Communication along diagonals is realized by a barrel
shifter (Fig. 2(c)); in JAX the barrel shifter is an index permutation
(`roll`), and on the TPU-word variant (reliability.py) it is a 32-bit rotate.

Parity group definition for slope s:  cell (i, j) of a block belongs to group
k = (j - s*i) mod m, i.e. P_s[k] = XOR_i B[i, (k + s*i) mod m].

Error location (multidimensional parity, [42]): a single flipped bit at
(i0, j0) produces a one-hot syndrome in every family with hot index
k_s = (j0 - s*i0) mod m.  Two families with slopes s_a, s_b locate the error
uniquely iff gcd(s_b - s_a, m) = 1:

    i0 = (k_a - k_b) * inv(s_b - s_a)  (mod m),      j0 = k_a + s_a*i0 (mod m)

The paper's families are (leading, counter) = (+1, -1): invertible iff m is
odd.  For even m (the paper's m ~ 16) we add a slope-2 family — (1, 2) always
locates, (-1) is kept as an integrity check (strictly stronger code, same
O(1) update property; see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["EccConfig", "encode", "syndrome", "correct", "verify",
           "update_parity_col", "update_parity_row", "parity_overhead"]

Parity = Dict[int, jax.Array]  # slope -> bool (nbi, nbj, m)


@dataclasses.dataclass(frozen=True)
class EccConfig:
    m: int = 16                       # block size (paper: m ~ 16, n ~ 1024)
    slopes: Tuple[int, ...] = (1, -1, 2)

    def __post_init__(self):
        if self.locating_pair() is None:
            raise ValueError(
                f"no slope pair with gcd(s_b - s_a, m) == 1 for m={self.m}, "
                f"slopes={self.slopes}; cannot locate errors")

    def locating_pair(self) -> Optional[Tuple[int, int]]:
        s = self.slopes
        for a in range(len(s)):
            for b in range(a + 1, len(s)):
                if math.gcd(s[b] - s[a], self.m) == 1:
                    return s[a], s[b]
        return None


def _gather_idx(m: int, s: int) -> jax.Array:
    """cols[i, k] = (k + s*i) mod m  — which column of row i is in group k."""
    i = jnp.arange(m)[:, None]
    k = jnp.arange(m)[None, :]
    return (k + s * i) % m


def _blocks(data: jax.Array, m: int) -> jax.Array:
    r, c = data.shape
    assert r % m == 0 and c % m == 0, f"data {data.shape} not divisible by m={m}"
    return data.reshape(r // m, m, c // m, m).transpose(0, 2, 1, 3)  # (nbi,nbj,m,m)


def _xor_reduce(x: jax.Array, axis: int) -> jax.Array:
    return (x.astype(jnp.uint8).sum(axis=axis) & 1).astype(jnp.bool_)


def encode(data: jax.Array, cfg: EccConfig = EccConfig()) -> Parity:
    """Compute all parity families of a bool matrix (R, C)."""
    m = cfg.m
    b = _blocks(data, m)                      # (nbi, nbj, m, m)
    rows = jnp.arange(m)[:, None]
    parity: Parity = {}
    for s in cfg.slopes:
        gathered = b[..., rows, _gather_idx(m, s)]   # (nbi,nbj,m,m): [.., i, k]
        parity[s] = _xor_reduce(gathered, axis=-2)   # (nbi,nbj,m)
    return parity


def syndrome(data: jax.Array, parity: Parity, cfg: EccConfig = EccConfig()) -> Parity:
    fresh = encode(data, cfg)
    return {s: jnp.logical_xor(fresh[s], parity[s]) for s in cfg.slopes}


def verify(data: jax.Array, parity: Parity, cfg: EccConfig = EccConfig()) -> jax.Array:
    """True iff every block of every family has a clean (zero) syndrome."""
    syn = syndrome(data, parity, cfg)
    return jnp.logical_not(
        jnp.any(jnp.stack([jnp.any(v, axis=-1) for v in syn.values()])))


def _modinv(a: int, m: int) -> int:
    a %= m
    for x in range(1, m):
        if (a * x) % m == 1:
            return x
    raise ValueError(f"{a} not invertible mod {m}")


def correct(data: jax.Array, parity: Parity, cfg: EccConfig = EccConfig()):
    """Detect and correct up to one flipped bit per block per family geometry.

    Returns (data', parity', stats) where stats has int32 counters:
      corrected_data, corrected_parity, uncorrectable.

    Cases per block (vectorized over all blocks):
      * all syndromes zero                         -> clean
      * exactly one family non-zero, one-hot       -> the check bit itself
                                                      flipped: fix parity
      * all families one-hot and mutually          -> data bit flipped: locate
        consistent                                    via the locating pair,
                                                      verify with the rest, flip
      * anything else                              -> uncorrectable (>= 2 errors)
    """
    m = cfg.m
    syn = syndrome(data, parity, cfg)
    slopes = list(cfg.slopes)
    syn_stack = jnp.stack([syn[s] for s in slopes])            # (F, nbi, nbj, m)
    pop = syn_stack.astype(jnp.int32).sum(axis=-1)             # (F, nbi, nbj)
    hot = jnp.argmax(syn_stack, axis=-1)                       # (F, nbi, nbj)
    nonzero = pop > 0
    onehot = pop == 1
    n_nonzero = nonzero.astype(jnp.int32).sum(axis=0)          # (nbi, nbj)

    sa, sb = cfg.locating_pair()
    ia, ib = slopes.index(sa), slopes.index(sb)
    inv = _modinv(sb - sa, m)
    i0 = ((hot[ia] - hot[ib]) * inv) % m                       # (nbi, nbj)
    j0 = (hot[ia] + sa * i0) % m
    # consistency: every family's hot index must match (j0 - s*i0) mod m
    consistent = jnp.ones_like(i0, dtype=bool)
    for f, s in enumerate(slopes):
        consistent &= hot[f] == (j0 - s * i0) % m
    all_onehot = jnp.all(onehot, axis=0)

    data_err = (n_nonzero == len(slopes)) & all_onehot & consistent
    parity_err = (n_nonzero == 1) & (onehot | ~nonzero).all(axis=0)
    uncorrectable = (n_nonzero > 0) & ~data_err & ~parity_err

    # --- fix data errors: flip bit (i0, j0) of flagged blocks ----------------
    nbi, nbj = i0.shape
    b = _blocks(data, m)
    flip = (jnp.arange(m)[None, None, :, None] == i0[..., None, None]) & \
           (jnp.arange(m)[None, None, None, :] == j0[..., None, None])
    flip &= data_err[..., None, None]
    b = jnp.logical_xor(b, flip)
    data_fixed = b.transpose(0, 2, 1, 3).reshape(data.shape)

    # --- fix parity errors: the flipped check bit equals the syndrome --------
    parity_fixed: Parity = {}
    for f, s in enumerate(slopes):
        fix_mask = (parity_err & nonzero[f])[..., None] & syn_stack[f]
        parity_fixed[s] = jnp.logical_xor(parity[s], fix_mask)

    stats = {
        "corrected_data": data_err.astype(jnp.int32).sum(),
        "corrected_parity": parity_err.astype(jnp.int32).sum(),
        "uncorrectable": uncorrectable.astype(jnp.int32).sum(),
    }
    return data_fixed, parity_fixed, stats


# --------------------------------------------------------------------------
# O(1) incremental updates — the paper's core claim (§IV, Fig. 2(b,c)).
# A vectored in-row op rewrites one *column* of the crossbar; a vectored
# in-column op rewrites one *row*.  Both update every parity family with a
# constant number of vector ops (a permutation = the barrel shifter + XOR),
# using "new parity = old parity XOR old bit XOR new bit" linearity.
# --------------------------------------------------------------------------

def update_parity_col(parity: Parity, old_col: jax.Array, new_col: jax.Array,
                      col: int, cfg: EccConfig = EccConfig()) -> Parity:
    """After writing column `col` (all rows at once), update all families.

    O(1) vector ops per family, independent of the number of rows.
    """
    m = cfg.m
    delta = jnp.logical_xor(old_col, new_col)          # (R,)
    nbi = delta.shape[0] // m
    dblk = delta.reshape(nbi, m)                       # (nbi, m): local row i
    bj, j_loc = col // m, col % m
    out: Parity = {}
    for s in cfg.slopes:
        k_of_i = (j_loc - s * jnp.arange(m)) % m       # group of local row i
        # barrel shift; scatter-add mod 2 (for |s| > 1 several rows may share
        # a group when gcd(s, m) != 1)
        scattered = (jnp.zeros(dblk.shape, jnp.uint8)
                     .at[:, k_of_i].add(dblk.astype(jnp.uint8)) & 1).astype(bool)
        out[s] = parity[s].at[:, bj, :].set(
            jnp.logical_xor(parity[s][:, bj, :], scattered))
    return out


def update_parity_row(parity: Parity, old_row: jax.Array, new_row: jax.Array,
                      row: int, cfg: EccConfig = EccConfig()) -> Parity:
    """After writing row `row` (all columns at once), update all families.

    Same O(1) property — this is the case where horizontal parity degrades to
    O(n) (Fig. 2(a)) and diagonal parity does not.
    """
    m = cfg.m
    delta = jnp.logical_xor(old_row, new_row)          # (C,)
    nbj = delta.shape[0] // m
    dblk = delta.reshape(nbj, m)                       # (nbj, m): local col j
    bi, i_loc = row // m, row % m
    out: Parity = {}
    for s in cfg.slopes:
        k_of_j = (jnp.arange(m) - s * i_loc) % m       # group of local col j
        # k_of_j is always a permutation (shift by s*i_loc), but use the same
        # scatter-add form for symmetry/safety
        scattered = (jnp.zeros(dblk.shape, jnp.uint8)
                     .at[:, k_of_j].add(dblk.astype(jnp.uint8)) & 1).astype(bool)
        out[s] = parity[s].at[bi, :, :].set(
            jnp.logical_xor(parity[s][bi, :, :], scattered))
    return out


def parity_overhead(cfg: EccConfig = EccConfig()) -> float:
    """Storage overhead: |families| * m check bits per m*m data bits."""
    return len(cfg.slopes) / cfg.m
