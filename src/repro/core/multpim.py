"""MultPIM-style in-memory fixed-point multiplication (paper §VI-A).

An N x N-bit unsigned array multiplier built from the FELIX gate set
(Min3/NOR + derived), expressed as a Min3 netlist: partial products via
NAND+NOT, carry-save accumulation rows of full adders, final ripple
carry-propagate adder.  For N = 32 this is ~14k stateful gates — the same
order as MultPIM's micro-code — and the error-injection experiments inject
faults into exactly these gate requests, accounting for logical masking, as
the paper's modified simulator does.

The TMR experiment wraps this netlist per §V: three executions + per-bit
Minority3 voting (the voting gates are fault-injected too — "non-ideal
voting").
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..reliability import backend
from .bitops import from_bits, to_bits
from .netlist import Netlist, NetlistBuilder, full_adder
from .stateful_logic import g_maj3

__all__ = ["multiplier_netlist", "multiply_bits", "multiply_words",
           "multiply_tmr_bits", "true_product_bits", "execute_netlist"]


def execute_netlist(nl: Netlist, inputs: jax.Array,
                    key: Optional[jax.Array] = None, p_gate=0.0,
                    fault_gate: Optional[jax.Array] = None,
                    impl: Optional[str] = None) -> jax.Array:
    """Dispatch a netlist execution through the backend registry
    (op ``netlist_exec``: "scan" — the lax.scan reference, "level" — the
    levelized bit-packed jnp default, "kernel" — one Pallas launch; see
    reliability/backend.py for the REPRO_IMPL override).  All three are
    bit-exact to each other, fault streams included."""
    fn = backend.dispatch("netlist_exec", impl)
    return fn(nl, inputs, key=key, p_gate=p_gate, fault_gate=fault_gate)


@functools.lru_cache(maxsize=None)
def multiplier_netlist(n_bits: int, cse: bool = True) -> Netlist:
    """Build the N-bit unsigned multiplier netlist (cached per width).

    Inputs: a[0..N-1] LSB-first, then b[0..N-1].  Outputs: product, 2N bits
    LSB-first.  cse=False keeps structurally duplicate gates (the honest
    hand-mapped micro-code count, used to measure the CSE reduction).
    """
    bld = NetlistBuilder(cse=cse)
    a = bld.input_bits(n_bits)
    b = bld.input_bits(n_bits)

    # partial products pp[i][j] = a[j] & b[i]
    pp = [[bld.and_(a[j], b[i]) for j in range(n_bits)] for i in range(n_bits)]

    prod = [bld.ZERO] * (2 * n_bits)
    # carry-save accumulation: S/C words aligned at the current row weight
    S = list(pp[0])            # S[j] has weight 2^(i+j) after row i
    C = [bld.ZERO] * n_bits
    prod[0] = S[0]
    for i in range(1, n_bits):
        newS, newC = [], []
        for j in range(n_bits):
            s_above = S[j + 1] if j + 1 < n_bits else bld.ZERO
            s, c = full_adder(bld, pp[i][j], s_above, C[j])
            newS.append(s)
            newC.append(c)
        S, C = newS, newC
        prod[i] = S[0]
    # final carry-propagate add of the leftover S (shifted) and C words
    carry = bld.ZERO
    for j in range(n_bits):
        u = S[j + 1] if j + 1 < n_bits else bld.ZERO
        s, carry = full_adder(bld, u, C[j], carry)
        prod[n_bits + j] = s
    bld.mark_outputs(prod)
    return bld.build()


def _pack_inputs(a_words: jax.Array, b_words: jax.Array, n_bits: int) -> jax.Array:
    a_bits = to_bits(a_words, n_bits)
    b_bits = to_bits(b_words, n_bits)
    return jnp.concatenate([a_bits, b_bits], axis=-1)


def multiply_bits(a_words: jax.Array, b_words: jax.Array, n_bits: int,
                  key: Optional[jax.Array] = None, p_gate=0.0,
                  fault_gate: Optional[jax.Array] = None,
                  impl: Optional[str] = None) -> jax.Array:
    """Multiply batches of N-bit words through the in-memory netlist.

    p_gate may be a float rate or any faults.FaultModel; impl selects the
    execution engine (backend registry op ``netlist_exec``) — the result is
    bit-exact across engines.  Returns the 2N-bit product as a bool bit-plane (trials, 2N),
    LSB first — bit-exact regardless of x64 mode.
    """
    nl = multiplier_netlist(n_bits)
    return execute_netlist(nl, _pack_inputs(a_words, b_words, n_bits),
                           key=key, p_gate=p_gate, fault_gate=fault_gate,
                           impl=impl)


def multiply_words(a_words: jax.Array, b_words: jax.Array, n_bits: int,
                   key: Optional[jax.Array] = None, p_gate=0.0,
                   fault_gate: Optional[jax.Array] = None,
                   impl: Optional[str] = None) -> jax.Array:
    """As multiply_bits but packed to (trials, 2) uint32 words (lo, hi)."""
    bits = multiply_bits(a_words, b_words, n_bits, key, p_gate, fault_gate,
                         impl=impl)
    lo = from_bits(bits[..., :n_bits], jnp.uint32)
    hi = from_bits(bits[..., n_bits:], jnp.uint32)
    return jnp.stack([lo, hi], axis=-1)


def multiply_tmr_bits(a_words: jax.Array, b_words: jax.Array, n_bits: int,
                      key: jax.Array, p_gate, ideal_voting: bool = False,
                      impl: Optional[str] = None) -> jax.Array:
    """TMR multiplication (serial discipline): three netlist executions with
    independent fault streams, then per-bit Minority3+NOT voting.

    With ideal_voting=False the two voting gates per output bit are
    fault-injected as well (paper Fig. 4: non-ideal voting becomes the
    bottleneck near p_gate = 1e-9).  Returns bool bits (trials, 2N).
    """
    nl = multiplier_netlist(n_bits)
    inputs = _pack_inputs(a_words, b_words, n_bits)
    k1, k2, k3, kv = jax.random.split(key, 4)
    o1 = execute_netlist(nl, inputs, key=k1, p_gate=p_gate, impl=impl)
    o2 = execute_netlist(nl, inputs, key=k2, p_gate=p_gate, impl=impl)
    o3 = execute_netlist(nl, inputs, key=k3, p_gate=p_gate, impl=impl)
    if ideal_voting:
        return g_maj3(o1, o2, o3)
    return g_maj3(o1, o2, o3, kv, p_gate)


def true_product_bits(a_words, b_words, n_bits: int):
    """Oracle product bits via numpy uint64 (no x64 dependency in JAX)."""
    import numpy as np
    a = np.asarray(a_words).astype(np.uint64)
    b = np.asarray(b_words).astype(np.uint64)
    prod = a * b
    shifts = np.arange(2 * n_bits, dtype=np.uint64)
    return ((prod[..., None] >> shifts) & 1).astype(bool)
