"""AdamW with global-norm clipping, warmup+cosine schedule, and ZeRO-1
sharding of optimizer state (the m/v moments additionally shard a large
replicated dim over the "data" axis — see sharding_rules.py)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, dtype=jnp.float32) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    # dot-product form: jnp.sum(square(x)) materializes an fp32 square of
    # every gradient leaf (XLA lowers the reduction via reduce-window);
    # a dot contraction accumulates in fp32 with no intermediate buffer.
    def sq(x):
        # no reshape: flattening a sharded leaf makes GSPMD replicate it
        return jax.lax.dot_general(
            x, x, (((tuple(range(x.ndim)),) * 2), ((), ())),
            preferred_element_type=jnp.float32)
    return jnp.sqrt(sum(sq(x) for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: dict, params: Any):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = warmup_cosine(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    # keep the clipped grads in their storage dtype: an fp32 copy of every
    # gradient leaf here is a full extra parameter-sized buffer at peak
    grads = jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    # moments keep their storage dtype (bf16 moments supported for the
    # largest configs); accumulation happens in fp32
    m = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32)
                                   + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32)
                                   + (1 - b2) * jnp.square(g.astype(jnp.float32))
                                   ).astype(v.dtype),
                     opt_state["v"], grads)
    c = count.astype(jnp.float32)
    mh = 1.0 - b1 ** c
    vh = 1.0 - b2 ** c

    def upd(p, m, v):
        m, v = m.astype(jnp.float32), v.astype(jnp.float32)
        step = (m / mh) / (jnp.sqrt(v / vh) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    new_opt = {"m": m, "v": v, "count": count}
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
