"""ZeRO-1 sharding for optimizer state.

Parameters are TP-sharded over "model"; the Adam moments (2x fp32 the size
of the params) would otherwise be replicated across the "data"/"pod" axes.
We derive moment Specs from parameter Specs by assigning the largest
physically-replicated dim the logical axis "zero" (mapped to the data axis
in ShardingRules), so m/v shard over data — ZeRO stage 1."""
from __future__ import annotations

from typing import Any

import jax

from ..models.params import Spec
from ..pshard import DEFAULT_RULES

__all__ = ["opt_spec_tree"]

_REPLICATED = (None, "model_dim", "seq")  # logicals that resolve to ()


def _zero_shard(s: Spec) -> Spec:
    # pick the largest dim whose logical axis is physically replicated
    best, best_size = None, 0
    for i, (size, name) in enumerate(zip(s.shape, s.axes)):
        if name in _REPLICATED and size > best_size:
            best, best_size = i, size
    if best is None:
        return Spec(s.shape, s.axes, "zeros")
    axes = tuple("zero" if i == best else a for i, a in enumerate(s.axes))
    return Spec(s.shape, axes, "zeros")


def opt_spec_tree(param_specs: Any) -> Any:
    """Spec tree for one Adam moment (m or v), ZeRO-1 sharded."""
    return jax.tree.map(_zero_shard, param_specs,
                        is_leaf=lambda x: isinstance(x, Spec))
