"""Derived sharding rules: ZeRO-1 optimizer state + reliability placement.

Parameters are TP-sharded over "model"; the Adam moments (2x fp32 the size
of the params) would otherwise be replicated across the "data"/"pod" axes.
We derive moment Specs from parameter Specs by assigning the largest
physically-replicated dim the logical axis "zero" (mapped to the data axis
in ShardingRules), so m/v shard over data — ZeRO stage 1.

The reliability placement helpers (DESIGN.md §14) put redundancy where the
data it protects lives: ECC parity tables shard their leading arena-block
axis across the whole mesh (logical "arena_block"), and stacked TMR copies
ride the "copy" mesh axis of a `launch.mesh.fold_copy_axis` mesh — each
copy owns a disjoint replica group, so parallel TMR reuses data-parallel
replicas instead of tripling any one device's work."""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

from ..models.params import Spec
from ..pshard import DEFAULT_RULES, ShardingRules, spec_for

__all__ = ["opt_spec_tree", "parity_pspec", "copy_stack_pspec"]

_REPLICATED = (None, "model_dim", "seq")  # logicals that resolve to ()


def _zero_shard(s: Spec) -> Spec:
    # pick the largest dim whose logical axis is physically replicated
    best, best_size = None, 0
    for i, (size, name) in enumerate(zip(s.shape, s.axes)):
        if name in _REPLICATED and size > best_size:
            best, best_size = i, size
    if best is None:
        return Spec(s.shape, s.axes, "zeros")
    axes = tuple("zero" if i == best else a for i, a in enumerate(s.axes))
    return Spec(s.shape, axes, "zeros")


def opt_spec_tree(param_specs: Any) -> Any:
    """Spec tree for one Adam moment (m or v), ZeRO-1 sharded."""
    return jax.tree.map(_zero_shard, param_specs,
                        is_leaf=lambda x: isinstance(x, Spec))


def parity_pspec(n_blocks: int, n_slopes: int, mesh,
                 rules: Optional[ShardingRules] = None) -> P:
    """PartitionSpec for an ECC parity table of shape (n_blocks, n_slopes):
    the arena block axis shards across the whole mesh so each shard holds
    exactly the parity rows of the arena blocks it scrubs (degrades to
    replication when n_blocks doesn't divide)."""
    return spec_for((n_blocks, n_slopes), ("arena_block", None), mesh, rules)


def copy_stack_pspec(pspec: P, mesh, copies: int = 3,
                     rules: Optional[ShardingRules] = None) -> P:
    """PartitionSpec for a (copies, *shape) stacked-TMR-copy array: prepend
    the "copy" logical axis to a per-copy spec.  On a fold_copy_axis mesh
    the leading dim shards over the copy replica groups; on plain meshes
    (no "copy" axis, or one whose size doesn't divide `copies`) it degrades
    to replication — correct, just not free."""
    rules = rules or DEFAULT_RULES
    axes = tuple(a for a in rules.axes_for("copy") if a in mesh.axis_names)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if not axes or copies % total != 0:
        return P(None, *pspec)
    return P(axes if len(axes) > 1 else axes[0], *pspec)
