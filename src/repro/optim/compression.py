"""Error-feedback int8 gradient compression.

Distributed-optimization trick for scaling the data-parallel all-reduce:
gradients are quantized to int8 with a per-tile fp32 scale before the
cross-replica reduction and the quantization error is carried to the next
step (error feedback keeps convergence).  At 1000+ nodes the DP all-reduce
is the dominant inter-pod collective; int8 cuts its bytes 4x vs fp32 (2x vs
bf16).

In the GSPMD path the reduction is implicit, so compression is applied as a
(de)quantization transform around the gradient: the compiled collective then
moves int8.  The transform is exact-shape-preserving and unit-tested for the
error-feedback contraction property.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_decompress"]

TILE = 256


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % TILE
    flat = jnp.pad(flat, (0, pad))
    tiles = flat.reshape(-1, TILE)
    scale = jnp.max(jnp.abs(tiles), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(tiles / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_decompress(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Apply error-feedback int8 round-trip: returns (decompressed grads,
    new error state).  g_hat = Q(g + e); e' = (g + e) - g_hat."""

    def f(g, e):
        target = g.astype(jnp.float32) + e
        q, s = _quantize(target)
        deq = _dequantize(q, s, g.shape)
        return deq.astype(g.dtype), target - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err)
    outs = [f(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in outs]),
            td.unflatten([o[1] for o in outs]))
