from .adamw import AdamWConfig, adamw_update, init_opt_state, warmup_cosine
from .compression import compress_decompress, init_error_state
from .sharding_rules import opt_spec_tree

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "warmup_cosine",
           "compress_decompress", "init_error_state", "opt_spec_tree"]
