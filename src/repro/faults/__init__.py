"""Fault-injection subsystem: unified error models + Monte-Carlo campaigns.

Single source of truth for every error process in the repo (DESIGN.md §10):

  models.py   — the FaultModel taxonomy: transient bit/gate flips, permanent
                stuck-at-0/1 defect masks, time-dependent retention drift.
                Each model is a pure JAX sampler keyed by (key, shape, dt),
                so fault streams are deterministic, replayable and vmappable.
  campaign.py — batched Monte-Carlo runner: vmapped trials over seeds,
                streaming Wilson-interval statistics, sweep grids and an
                early-stop rule on confidence-interval width.

The fused inject→encode→syndrome→correct Pallas kernel that executes a whole
trial's corruption+scrub as one launch lives in kernels/inject_scrub/.
"""
from .models import (CompositeFault, FaultModel, RetentionDrift,
                     StuckAtFaults, TransientBitFlips, TransientGateFaults,
                     inject_bit_flips)
from .campaign import (CampaignConfig, CampaignResult, run_campaign, sweep,
                       sweep_schemes, wilson_interval)

__all__ = [
    "FaultModel", "TransientBitFlips", "TransientGateFaults", "StuckAtFaults",
    "RetentionDrift", "CompositeFault", "inject_bit_flips",
    "CampaignConfig", "CampaignResult", "run_campaign", "sweep",
    "sweep_schemes", "wilson_interval",
]
