"""Unified fault-model taxonomy (DESIGN.md §10).

The paper evaluates reliability along two axes: short-term soft errors
(transient gate/bit flips, §II-B) and long-term degradation of large-scale
applications (permanent defects and retention drift, §VI).  Every error
process the repo simulates is expressed here as a `FaultModel` — a frozen
dataclass whose samplers are *pure functions of (key, shape, dt)*, so a
fault stream is fully determined by its PRNG key: campaigns replay
deterministically, disjoint keys give independent streams, and the samplers
vmap over a batch of trial keys without host-side state.

Three corruption surfaces, one model object:

* boolean state (crossbar cells, netlist gate outputs):
  `bit_flips(key, shape, dt)` / `corrupt_bits(bits, key, dt)`;
* packed uint32 words (the ECC arena of core/arena.py):
  `word_mask(key, words, dt)` / `corrupt_words(words, key, dt)` — the XOR
  mask feeds the fused inject+scrub kernel (kernels/inject_scrub/);
* parameter pytrees: `corrupt(params, key, dt)` (the canonical home of the
  former `core.reliability.inject_bit_flips`).

`dt` is the length of the exposure interval in model time units; transient
and drift models scale their per-interval flip probability as
1 - (1 - p)^dt, permanent stuck-at masks are dt-invariant (the defect is a
property of the device, not of the interval).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..core import arena
from ..core.bitops import PACK, pack_trials

__all__ = ["FaultModel", "TransientBitFlips", "TransientGateFaults",
           "StuckAtFaults", "RetentionDrift", "CompositeFault",
           "inject_bit_flips", "pack_flip_mask"]

BLOCK = arena.BLOCK


def _p_interval(p: float, dt: float) -> float:
    """Per-interval flip probability for a per-unit-time rate p over dt."""
    if dt == 1.0 or p <= 0.0:
        return p
    if p >= 1.0:
        return 1.0
    return -math.expm1(dt * math.log1p(-p))


def pack_flip_mask(flips: jax.Array) -> jax.Array:
    """Pack a (..., 32) bool flip plane into a (...,) uint32 XOR mask."""
    shifts = jnp.arange(BLOCK, dtype=jnp.uint32)
    return (flips.astype(jnp.uint32) << shifts).sum(axis=-1, dtype=jnp.uint32)


class FaultModel:
    """Abstract error process.  Subclasses are frozen dataclasses (hashable,
    usable as static jit arguments); all sampling is keyed and pure."""

    @property
    def permanent(self) -> bool:
        """True when the model describes a fixed device property (defect
        maps) rather than an exposure process: consumers that corrupt
        repeatedly (e.g. once per training step) must then reuse a stable
        key instead of re-keying per interval, or the 'permanent' defects
        would relocate every draw."""
        return False

    # -- boolean-state surface ------------------------------------------------
    def bit_flips(self, key: jax.Array, shape: Tuple[int, ...],
                  dt: float = 1.0) -> jax.Array:
        """Bool XOR plane: True where a stored bit flips during dt."""
        raise NotImplementedError(
            f"{type(self).__name__} is data-dependent; use corrupt_bits")

    def corrupt_bits(self, bits: jax.Array, key: jax.Array,
                     dt: float = 1.0) -> jax.Array:
        return jnp.logical_xor(bits, self.bit_flips(key, bits.shape, dt))

    # -- packed-word surface (ECC arena) --------------------------------------
    def word_mask(self, key: jax.Array, words: jax.Array,
                  dt: float = 1.0) -> jax.Array:
        """uint32 XOR mask over `words` (may inspect the data for stuck-at)."""
        return pack_flip_mask(self.bit_flips(key, words.shape + (BLOCK,), dt))

    def corrupt_words(self, words: jax.Array, key: jax.Array,
                      dt: float = 1.0) -> jax.Array:
        return words ^ self.word_mask(key, words, dt)

    # -- packed-trial surface (netlist execution engines) ----------------------
    def gate_lane_masks(self, key: jax.Array, trials: int,
                        dt: float = 1.0) -> Tuple[jax.Array, jax.Array]:
        """Per-gate corruption as lane masks over trial-packed words.

        Any single-bit boolean corruption is affine per lane, so one gate's
        output column packed 32-trials-per-word (core/bitops.pack_trials
        layout) corrupts as ``(val & keep) ^ flip``.  Returns
        (keep, flip) uint32 (ceil(trials/32),), bit-exact against
        ``corrupt_bits`` on the unpacked (trials,) plane under the same key
        — the levelized/kernel netlist engines stay stream-identical to the
        lax.scan reference.  Padding lanes are don't-care (their trials are
        discarded on unpack).
        """
        flip = pack_trials(self.bit_flips(key, (trials,), dt))
        return jnp.full_like(flip, jnp.uint32(0xFFFFFFFF)), flip

    # -- pytree surface -------------------------------------------------------
    def corrupt(self, params: Any, key: jax.Array, dt: float = 1.0) -> Any:
        """Corrupt every leaf's stored bits (via the arena word view)."""
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(key, len(leaves))
        out = []
        for x, k in zip(leaves, keys):
            words = arena.leaf_to_words(x)
            spec = arena.LeafSpec(offset=0, n_words=words.shape[0],
                                  pad_words=0, dtype=x.dtype,
                                  shape=tuple(x.shape))
            out.append(arena.words_to_leaf(
                self.corrupt_words(words, k, dt), spec))
        return treedef.unflatten(out)


@dataclasses.dataclass(frozen=True)
class TransientBitFlips(FaultModel):
    """Indirect soft errors: each stored bit flips i.i.d. w.p. p_bit per
    interval (read disturb / access corruption, paper §II-B)."""

    p_bit: float = 0.0

    def bit_flips(self, key, shape, dt: float = 1.0):
        return jax.random.bernoulli(key, _p_interval(self.p_bit, dt), shape)


@dataclasses.dataclass(frozen=True)
class TransientGateFaults(FaultModel):
    """Direct soft errors: a stateful gate writes the wrong output w.p.
    p_gate per evaluation (independently per row/column, paper §II-B)."""

    p_gate: float = 0.0

    def bit_flips(self, key, shape, dt: float = 1.0):
        return jax.random.bernoulli(key, _p_interval(self.p_gate, dt), shape)


@dataclasses.dataclass(frozen=True)
class StuckAtFaults(FaultModel):
    """Permanent defects: each cell is stuck-at-0 w.p. p_stuck0 and
    stuck-at-1 w.p. p_stuck1 (disjoint events).  The defect map is a pure
    function of the key and ignores dt — the same key always yields the
    same mask, so repeated corruption is idempotent."""

    p_stuck0: float = 0.0
    p_stuck1: float = 0.0

    @property
    def permanent(self) -> bool:
        return True

    def stuck_masks(self, key: jax.Array, shape: Tuple[int, ...]):
        """(sa0, sa1) bool defect maps; disjoint by construction."""
        u = jax.random.uniform(key, shape)
        sa0 = u < self.p_stuck0
        sa1 = (u >= self.p_stuck0) & (u < self.p_stuck0 + self.p_stuck1)
        return sa0, sa1

    def corrupt_bits(self, bits, key, dt: float = 1.0):
        sa0, sa1 = self.stuck_masks(key, bits.shape)
        return (bits & ~sa0) | sa1

    def word_mask(self, key, words, dt: float = 1.0):
        sa0, sa1 = self.stuck_masks(key, words.shape + (BLOCK,))
        sa0w, sa1w = pack_flip_mask(sa0), pack_flip_mask(sa1)
        return (words & sa0w) | (~words & sa1w)

    def gate_lane_masks(self, key, trials: int, dt: float = 1.0):
        # (v & ~sa0) | sa1 == (v & ~(sa0|sa1)) ^ sa1 — sa0/sa1 are disjoint
        sa0, sa1 = self.stuck_masks(key, (trials,))
        sa1w = pack_trials(sa1)
        return ~(pack_trials(sa0) | sa1w), sa1w


@dataclasses.dataclass(frozen=True)
class RetentionDrift(FaultModel):
    """Time-dependent conductance drift (the paper's long-term axis): a
    stored bit decays w.p. 1 - (1 - p_unit)^dt over an interval of length
    dt — the continuous-time process behind `Crossbar.drift`."""

    p_unit: float = 0.0

    def bit_flips(self, key, shape, dt: float = 1.0):
        return jax.random.bernoulli(key, _p_interval(self.p_unit, dt), shape)


@dataclasses.dataclass(frozen=True)
class CompositeFault(FaultModel):
    """Sequential composition: each member corrupts with an independent
    subkey (e.g. drift + stuck-at defects in one campaign scenario)."""

    models: Tuple[FaultModel, ...] = ()

    @property
    def permanent(self) -> bool:
        return bool(self.models) and all(m.permanent for m in self.models)

    def corrupt_bits(self, bits, key, dt: float = 1.0):
        for m, k in zip(self.models, jax.random.split(key, len(self.models))):
            bits = m.corrupt_bits(bits, k, dt)
        return bits

    def corrupt_words(self, words, key, dt: float = 1.0):
        for m, k in zip(self.models, jax.random.split(key, len(self.models))):
            words = m.corrupt_words(words, k, dt)
        return words

    def word_mask(self, key, words, dt: float = 1.0):
        return self.corrupt_words(words, key, dt) ^ words

    def gate_lane_masks(self, key, trials: int, dt: float = 1.0):
        # lanewise affine composition: f2(f1(v)) with f = (v & K) ^ F gives
        # K = K1 & K2, F = (F1 & K2) ^ F2 — same member order and key split
        # as corrupt_bits, so the packed stream matches the scan reference.
        keep = jnp.full((-(-trials // PACK),), 0xFFFFFFFF, jnp.uint32)
        flip = jnp.zeros_like(keep)
        for m, k in zip(self.models, jax.random.split(key, len(self.models))):
            k2, f2 = m.gate_lane_masks(k, trials, dt)
            keep = keep & k2
            flip = (flip & k2) ^ f2
        return keep, flip


def inject_bit_flips(params: Any, key: jax.Array, p_bit: float) -> Any:
    """Canonical transient injector: flip each stored bit w.p. p_bit.

    Draw-compatible with the historic `core.reliability.inject_bit_flips`
    (same per-leaf key split, same Bernoulli plane, same packing).
    """
    return TransientBitFlips(p_bit).corrupt(params, key)
