"""Batched Monte-Carlo fault campaigns with streaming Wilson statistics.

A campaign estimates a failure probability empirically: run many
independent trials of `trial_fn(key) -> failed?`, stream the pass/fail
counts, and report a Wilson score interval.  Design points (DESIGN.md §10):

* **batched** — trials are vmapped over a batch of PRNG keys and reduced
  *on device*; only scalar counters cross to the host, so per-trial results
  are never materialized (a 4096-trial campaign moves a handful of ints);
* **deterministic** — batch b draws its keys from fold_in(key, b); a
  campaign is replayable from (key, config) alone;
* **early stop** — after `min_trials`, the campaign stops as soon as the
  Wilson interval half-width drops below `ci_halfwidth` (0 disables);
* **sweeps** — `sweep()` runs one campaign per grid point (e.g. over
  p_gate / p_bit / scrub interval), deriving a distinct key per point.

Trials can also return auxiliary per-trial counters (corrected,
uncorrectable, injected, ...) as a dict of scalars; these are summed into
`CampaignResult.extras` by the same streaming reduction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CampaignConfig", "CampaignResult", "wilson_interval",
           "run_campaign", "sweep", "sweep_schemes"]


def wilson_interval(k: int, n: int, z: float = 1.96) -> Tuple[float, float]:
    """Wilson score interval for k failures in n Bernoulli trials.

    Preferred over the normal approximation because campaign operating
    points sit in the rare-event regime (k near 0), where Wald intervals
    collapse to a width-0 lie.
    """
    if n <= 0:
        return 0.0, 1.0
    p = k / n
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n))
    return max(0.0, center - half), min(1.0, center + half)


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    batch_size: int = 256        # trials per device launch
    max_trials: int = 4096       # hard budget
    min_trials: int = 512        # never early-stop before this many
    ci_halfwidth: float = 0.0    # stop once Wilson half-width <= this (0 = off)
    z: float = 1.96              # 95% interval


@dataclasses.dataclass
class CampaignResult:
    """Streaming summary of one campaign (one operating point)."""

    name: str
    n_trials: int
    failures: int
    z: float = 1.96
    extras: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def p_hat(self) -> float:
        return self.failures / self.n_trials if self.n_trials else 0.0

    @property
    def ci(self) -> Tuple[float, float]:
        return wilson_interval(self.failures, self.n_trials, self.z)

    @property
    def ci_halfwidth(self) -> float:
        lo, hi = self.ci
        return (hi - lo) / 2.0

    def contains(self, p_model: float) -> bool:
        """Does the closed-form prediction fall inside the Wilson interval?"""
        lo, hi = self.ci
        return lo <= p_model <= hi

    def describe(self) -> str:
        lo, hi = self.ci
        s = (f"{self.name}: p_hat={self.p_hat:.4g} "
             f"[{lo:.4g}, {hi:.4g}] n={self.n_trials}")
        if self.extras:
            s += " " + " ".join(f"{k}={v:g}" for k, v in
                                sorted(self.extras.items()))
        return s


def _normalize(out) -> Tuple[jax.Array, Mapping[str, jax.Array]]:
    if isinstance(out, tuple):
        fail, extras = out
        return jnp.asarray(fail), extras
    return jnp.asarray(out), {}


def run_campaign(trial_fn: Callable, key: jax.Array,
                 cfg: CampaignConfig = CampaignConfig(), *,
                 batched: bool = False, name: str = "") -> CampaignResult:
    """Estimate P[failure] of `trial_fn` by batched Monte Carlo.

    trial_fn signatures:
      batched=False: trial_fn(key) -> failed_bool  (or (failed, extras_dict))
                     — vmapped over a key batch and jit'd here;
      batched=True:  trial_fn(key, n) -> failed_bool[n] (or (failed, extras))
                     — the trial already runs a whole batch in one launch
                     (e.g. one arena block per trial through the fused
                     inject+scrub kernel).

    Per-batch results are reduced on device; only the scalar sums are
    pulled to the host (streaming — no per-trial materialization).
    """
    if batched:
        batch_fn = trial_fn
    else:
        vmapped = jax.jit(jax.vmap(trial_fn))

        def batch_fn(k, n):
            return vmapped(jax.random.split(k, n))

    n = failures = 0
    extras_acc: Dict[str, float] = {}
    b = 0
    while n < cfg.max_trials:
        size = min(cfg.batch_size, cfg.max_trials - n)
        fail, extras = _normalize(batch_fn(jax.random.fold_in(key, b), size))
        b += 1
        assert fail.shape == (size,), (fail.shape, size)
        failures += int(jnp.sum(fail))
        n += size
        for k2, v in extras.items():
            extras_acc[k2] = extras_acc.get(k2, 0.0) + float(jnp.sum(v))
        if cfg.ci_halfwidth > 0 and n >= cfg.min_trials:
            lo, hi = wilson_interval(failures, n, cfg.z)
            if (hi - lo) / 2.0 <= cfg.ci_halfwidth:
                break
    return CampaignResult(name=name, n_trials=n, failures=failures,
                          z=cfg.z, extras=extras_acc)


def sweep(make_trial: Callable[..., Callable], points: Sequence[Mapping[str, Any]],
          key: jax.Array, cfg: CampaignConfig = CampaignConfig(), *,
          batched: bool = False) -> List[Tuple[Mapping[str, Any], CampaignResult]]:
    """Run one campaign per grid point.

    make_trial(**point) builds the trial function for that operating point
    (static parameters — p_gate, p_bit, scrub interval — are closed over,
    so each point jit-compiles once).  Point i draws its campaign key from
    fold_in(key, i): points are independent and individually replayable.
    """
    out = []
    for i, pt in enumerate(points):
        trial = make_trial(**pt)
        label = ",".join(f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in pt.items())
        out.append((pt, run_campaign(trial, jax.random.fold_in(key, i), cfg,
                                     batched=batched, name=label)))
    return out


def sweep_schemes(make_trial: Callable, schemes: Sequence,
                  key: jax.Array, cfg: CampaignConfig = CampaignConfig(), *,
                  batched: bool = False) -> List[Tuple[Any, CampaignResult]]:
    """Run one campaign per protection scheme (DESIGN.md §12).

    THE code path every consumer uses to walk the `repro.reliability`
    Scheme design space: `make_trial(scheme)` closes the (static, hashable)
    scheme into a trial function, and each grid point runs as an
    independent, individually replayable campaign labeled `scheme.name`.
    """
    out = []
    for i, scheme in enumerate(schemes):
        trial = make_trial(scheme)
        out.append((scheme, run_campaign(trial, jax.random.fold_in(key, i),
                                         cfg, batched=batched,
                                         name=scheme.name)))
    return out
