"""Fault-tolerant training loop.

Composes the substrates: data prefetch, jit'd train step, periodic
checkpointing, heartbeat/straggler monitoring, and the paper's reliability
layer — a composable protection `Scheme` (repro.reliability, DESIGN.md §12)
verifying the parameter store between steps under injected soft errors.

Scheme scheduling is interval-based: redundancy is refreshed after every
parameter write (for `DiagParityEcc` that is one fused encode launch over
the packed arena) and every `scrub_every` steps `scheme.scrub` verifies and
corrects the store.  Each ScrubReport feeds two consumers: the
HeartbeatMonitor (an uncorrectable block returns Decision.RESTART, which
triggers a checkpoint restore) and a core.analytics.ScrubTrajectory
(observed correction stream vs the closed-form model).  `run()` survives
(simulated) preemptions by restoring the latest checkpoint and replaying
the data stream from the step counter (the synthetic pipeline is
deterministic in step).

Scrub telemetry performs ONE host fetch per scrub interval (the monitor's
restore decision needs the counts); an optional `eval_fn` hook — e.g.
`launch.engine.make_eval_hook`, a compiled one-launch sample generation —
fires every `eval_every` steps on the post-scrub params, keeping its
results on device in `eval_history`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import Checkpointer
from ..core import arena
from ..core.analytics import ScrubTrajectory
from ..core.reliability import ReliableStore, WordEccConfig
from ..faults.models import FaultModel, TransientBitFlips
from ..obs import NULL_TRACER, DriftDetector, ScrubMetrics, Tracer
from ..reliability import backend
from ..reliability.scheme import (ArenaEcc, Compose, DiagParityEcc,
                                 Protected, Scheme,
                                  Tmr, parse_scheme)
from .adaptive import AdaptiveScrub
from .monitor import Decision, HeartbeatMonitor, StragglerPolicy

__all__ = ["LoopConfig", "TrainLoop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    scrub_every: int = 0          # 0 = scheme scrubbing disabled
    log_every: int = 10
    eval_every: int = 0           # 0 = eval hook disabled; else the loop's
                                  # eval_fn fires every this many steps
    inject_p_bit: float = 0.0     # simulated indirect soft-error rate per scrub interval
    inject_seed: int = 0
    fault_model: Optional[FaultModel] = None  # overrides inject_p_bit: any
                                  # repro.faults model drives the injection
    scheme: Optional[Scheme] = None  # protection scheme (repro.reliability);
                                  # None -> DiagParityEcc() on attach_scheme()
    max_scrub_restores: int = 3   # consecutive scheme restores before giving up
                                  # and continuing with best-effort correction
    adaptive_scrub: Any = None    # pay-as-you-fault cadence: an
                                  # AdaptiveScrub instance, or True to build
                                  # one from the injection prior on
                                  # attach_scheme(); overrides scrub_every


class TrainLoop:
    def __init__(self, train_step: Callable, state: Any, batch_at: Callable[[int], Any],
                 cfg: LoopConfig, ckpt: Optional[Checkpointer] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 log: Callable[[str], None] = print,
                 inject_fn: Optional[Callable[[Any, int], Any]] = None,
                 eval_fn: Optional[Callable[[Any, int], Any]] = None,
                 tracer: Tracer = NULL_TRACER):
        self.train_step = train_step
        self.state = state
        self.batch_at = batch_at
        self.cfg = cfg
        self.ckpt = ckpt
        self.monitor = monitor or HeartbeatMonitor()
        self.log = log
        self.step = 0
        self.scheme: Optional[Scheme] = None         # active protection scheme
        self.protected: Optional[Protected] = None   # scheme-wrapped params
        self.inject_fn = inject_fn    # deterministic corruptor hook (tests)
        self.eval_fn = eval_fn        # e.g. launch.engine.make_eval_hook —
                                      # compiled sample generation every
                                      # cfg.eval_every steps
        self.tracer = tracer          # obs.Tracer: launch spans + heartbeat
                                      # events (NULL_TRACER = zero overhead)
        self.metrics_history: list = []
        self.eval_history: list = []
        self.scrub_reports: list = []
        self.scrub_trajectory = ScrubTrajectory()
        self.adaptive: Optional[AdaptiveScrub] = None
        self.total_restores = 0
        self._consecutive_scrub_restores = 0

    # -- reliability hooks -----------------------------------------------------
    # Protocol (paper §IV adapted): redundancy is refreshed after every
    # parameter write (the optimizer step == the mMPU "function output");
    # scrubbing verifies/corrects accumulated storage flips between
    # refreshes.  For DiagParityEcc both are single fused launches over the
    # packed arena; TMR/Compose schemes vote across held copies instead.
    @property
    def parity(self):
        if self.protected is not None and self.scheme.checkpoint_redundancy:
            return self.protected.redundancy
        return None

    @property
    def store(self) -> Optional[ReliableStore]:
        """DEPRECATED back-compat view: the ECC store as a ReliableStore.

        Only meaningful for `DiagParityEcc`-protected loops (None
        otherwise); scrubbing the view is bit-exact vs `scheme.scrub` —
        both run the same fused pass over the same arena+parity.
        """
        if self.protected is None \
                or not isinstance(self.scheme, DiagParityEcc):
            return None
        s = ReliableStore(self.protected.payload, self.protected.redundancy,
                          WordEccConfig(self.scheme.slopes),
                          backend.resolve("diag_parity", self.scheme.impl))
        s._packed = self.protected._packed
        return s

    def _default_scheme(self) -> Scheme:
        if self.cfg.scheme is not None:
            return self.cfg.scheme
        return DiagParityEcc()

    def attach_scheme(self, scheme: Optional[Scheme] = None) -> None:
        """Arm the protection scheme over the current parameter store.

        When the loop injects transient flips at a known `p_bit` and the
        scheme carries ECC, a `obs.DriftDetector` is armed on the monitor:
        observed correction rates vs the closed-form expectation become a
        health signal in `monitor.summary()["drift"]`."""
        self.scheme = scheme or self._default_scheme()
        self.protected = self.scheme.protect(self.state["params"])
        self.scrub_trajectory.n_blocks = self._n_blocks()
        model = self._resolved_model()
        p_bit = getattr(model, "p_bit", None)
        if p_bit and not getattr(model, "permanent", False) \
                and self.monitor.drift is None \
                and isinstance(self.scheme, (ArenaEcc, Compose)):
            # Compose scrubs three independently corrupted copies per
            # interval, so the expected event stream is 3x one arena's
            copies = 3 if isinstance(self.scheme, Compose) else 1
            self.monitor.drift = DriftDetector(
                p_bit, self._n_blocks() * copies)
        if self.cfg.adaptive_scrub and self.adaptive is None:
            if isinstance(self.cfg.adaptive_scrub, AdaptiveScrub):
                self.adaptive = self.cfg.adaptive_scrub
            else:
                # prior-seeded controller: the injection rate (if known)
                # sizes interval0; the monitor's drift detector (if armed
                # above) vetoes relaxation while corrections run hot
                copies = 3 if isinstance(self.scheme,
                                         (Tmr, Compose)) else 1
                self.adaptive = AdaptiveScrub.from_prior(
                    p_bit or 0.0, self._n_blocks() * copies,
                    detector=self.monitor.drift,
                    # record_scrub already feeds the shared detector
                    feed_detector=False,
                    interval0=max(1, self.cfg.scrub_every or 32))

    def _n_blocks(self) -> int:
        return arena.arena_spec(self.state["params"]).n_blocks

    def _refresh(self) -> None:
        if self.protected is not None:
            self.protected = self.scheme.refresh(self.state["params"])

    def _inject_key(self, model: FaultModel) -> jax.Array:
        if model.permanent:
            # defect maps are device properties: one stable key for the
            # whole run, or the "permanent" faults would relocate every
            # scrub interval (and survive restores, correctly)
            return jax.random.PRNGKey(self.cfg.inject_seed)
        # fold the restore count in: real soft errors do not replay, so a
        # post-restore replay of this step must draw fresh flips (else an
        # uncorrectable draw would recur identically and livelock the run)
        return jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.inject_seed + self.step),
            self.total_restores)

    def _resolved_model(self) -> Optional[FaultModel]:
        model = self.cfg.fault_model
        if model is None and self.cfg.inject_p_bit > 0:
            model = TransientBitFlips(self.cfg.inject_p_bit)
        return model

    def _corrupt(self, params: Any) -> Any:
        """One interval's exposure applied to a plain pytree (key semantics
        shared with _corrupted_store; kept as the single-copy surface)."""
        model = self._resolved_model()
        if model is None:
            return params
        # dt=1: one model time unit == one scrub interval (inject_p_bit
        # has always been a per-scrub-interval rate)
        return model.corrupt(params, self._inject_key(model), dt=1.0)

    def _corrupted_store(self) -> Protected:
        """The protected store after this interval's simulated exposure."""
        params = self.state["params"]
        if self.inject_fn is not None:
            # deterministic test hook: corrupts the payload copy only
            corrupted = self.inject_fn(params, self.step)
            if corrupted is params:
                return self.protected
            return self.scheme.adopt(corrupted, self.protected.redundancy)
        model = self._resolved_model()
        if model is None:
            # no injection: scrub the just-refreshed store, reusing its
            # cached packed arena instead of packing the pytree again
            return self.protected
        # corrupt EVERY held data copy (copy-based schemes draw independent
        # subkeys per copy, so TMR double-faults and uncorrectable words are
        # actually reachable); dt as in _corrupt
        return self.scheme.corrupt_store(self.protected, model,
                                         self._inject_key(model), dt=1.0)

    def _vote_disagreements(self, corrected: int, uncorrectable: int) -> int:
        """Vote-outcome share of a fetched scrub report.  For `Tmr` every
        repair and every conflict IS a copy disagreement; for `Compose`
        only the post-ECC three-way conflicts are separable from the
        merged report (pairwise repaired disagreements are folded into
        `corrected` with the ECC counts — a documented undercount)."""
        if isinstance(self.scheme, Tmr):
            return corrected + uncorrectable
        if isinstance(self.scheme, Compose):
            return uncorrectable
        return 0

    def _scrub(self) -> bool:
        """One scheme scrub pass; returns True if a restore rolled back the
        step counter (the caller must not finish the current iteration)."""
        with self.tracer.trace("scrub", step=self.step,
                               scheme=self.scheme.name):
            fixed, report = self.scheme.scrub(self._corrupted_store())
            self.scrub_reports.append((self.step, report))
            # ONE host fetch per scrub interval: the monitor's restore
            # decision genuinely needs the counter values on the host, but
            # everything downstream (trajectory, monitor, drift detector)
            # reuses the same fetched triple — not six independent int()
            # syncs against the device
            corrected, parity_fixed, uncorrectable = (
                int(v) for v in jax.device_get((report.corrected,
                                                report.parity_fixed,
                                                report.uncorrectable)))
        self.scrub_trajectory.add(self.step, corrected, parity_fixed,
                                  uncorrectable)
        if self.adaptive is not None:
            # the controller reuses the SAME fetched triple (no extra
            # sync); it reschedules the next scrub from these counts
            self.adaptive.record(self.step, corrected, uncorrectable,
                                 parity_fixed)
        injected = int(self.inject_fn is not None
                       or self._resolved_model() is not None)
        record = ScrubMetrics(
            corrected=corrected, parity_fixed=parity_fixed,
            uncorrectable=uncorrectable, injected=injected,
            vote_disagreements=self._vote_disagreements(corrected,
                                                        uncorrectable))
        decision = self.monitor.record_scrub(record)
        self.tracer.metrics({"step": self.step, "scheme": self.scheme.name,
                             "corrected": corrected,
                             "parity_fixed": parity_fixed,
                             "uncorrectable": uncorrectable,
                             "vote_disagreements":
                             record.vote_disagreements,
                             "decision": decision}, kind="scrub")
        if decision == Decision.RESTART and self.ckpt is not None \
                and self.ckpt.latest_step() is not None:
            if self._consecutive_scrub_restores < self.cfg.max_scrub_restores:
                self._consecutive_scrub_restores += 1
                self.log(f"[reliability] step {self.step}: "
                         f"{uncorrectable} uncorrectable blocks -> restore")
                return self.restore()
            # the same replay window keeps producing uncorrectable blocks:
            # restoring again cannot help, so accept the best-effort
            # correction and keep training rather than livelock
            self.log(f"[reliability] step {self.step}: restore limit "
                     f"({self.cfg.max_scrub_restores}) reached; continuing "
                     f"with best-effort corrected params")
        else:
            self._consecutive_scrub_restores = 0
        self.state = dict(self.state, params=fixed.payload)
        self.protected = fixed
        return False

    # -- checkpoint/restore ------------------------------------------------------
    def save(self) -> None:
        if self.ckpt is not None:
            snap = {"state": self.state, "step": self.step}
            if self.protected is not None:
                # scheme-name marker: lets a fresh process re-arm copy-based
                # schemes whose redundancy is rebuilt from params (no parity
                # table to detect them by)
                snap["scheme"] = self.scheme.name
            parity = self.parity
            if parity is not None:
                snap["parity"] = parity
            self.ckpt.save(self.step, snap)

    def restore(self) -> bool:
        if self.ckpt is None:
            return False
        # an async re-save may be mid-rename on the dir we are about to
        # read; drain it before resolving snapshots
        self.ckpt.wait()
        if self.ckpt.latest_step() is None:
            return False
        self.tracer.instant("restore", step=self.step)
        snap = self.ckpt.restore()
        self.state = jax.tree.map(jax.numpy.asarray, snap["state"])
        self.total_restores += 1
        if "parity" in snap:
            # a parity table in the snapshot means the saving run had an ECC
            # scheme attached — re-arm it even in a fresh process (scheme is
            # None), or scrubbing would silently stop across preemption
            # restarts.  A legacy per-leaf parity pytree (pre-arena
            # checkpoints) is not usable as the (n_blocks, F) table:
            # re-encode from params.
            self.scheme = self.scheme or self._default_scheme()
            parity = snap["parity"]
            if not self.scheme.checkpoint_redundancy:
                # the snapshot came from an ECC run but this loop runs a
                # copy-based scheme: the parity table simply doesn't apply
                self.log(f"[restore] snapshot parity ignored (current "
                         f"scheme {self.scheme.name} rebuilds redundancy "
                         f"from params)")
                self.protected = self.scheme.protect(self.state["params"])
            elif hasattr(parity, "shape") \
                    and getattr(parity, "ndim", 0) == 2:
                self.protected = self.scheme.adopt(
                    self.state["params"], jax.numpy.asarray(parity))
            else:
                self.log("[restore] legacy/unknown parity layout in snapshot;"
                         " re-protecting from restored params")
                self.protected = self.scheme.protect(self.state["params"])
            self.scrub_trajectory.n_blocks = self._n_blocks()
        elif self.protected is not None:
            self.protected = self.scheme.refresh(self.state["params"])
        elif "scheme" in snap:
            # the saving run had a copy-based scheme armed (no parity table
            # in the snapshot) — re-arm it in this fresh process, or
            # scrubbing would silently stop across preemption restarts
            name = str(np.asarray(snap["scheme"]).item())
            self.scheme = self.scheme or self.cfg.scheme \
                or parse_scheme(name)
            self.log(f"[restore] re-armed protection scheme "
                     f"{self.scheme.name} (snapshot ran {name})")
            self.protected = self.scheme.protect(self.state["params"])
            self.scrub_trajectory.n_blocks = self._n_blocks()
        self.step = int(snap["step"])
        self.log(f"[restore] resumed from step {self.step}")
        return True

    # -- main loop ----------------------------------------------------------------
    def run(self, fail_at: Optional[int] = None) -> Dict:
        """Run to total_steps.  fail_at simulates a preemption at that step
        (raises, caller re-invokes run(); state restores from checkpoint)."""
        c = self.cfg
        while self.step < c.total_steps:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"simulated preemption at step {self.step}")
            t0 = time.perf_counter()
            with self.tracer.trace("train_step", step=self.step):
                batch = self.batch_at(self.step)
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            decision = self.monitor.record_step(dt)
            self.step += 1
            if c.log_every and self.step % c.log_every == 0:
                loss = float(metrics.get("loss", metrics.get("total", np.nan)))
                self.log(f"step {self.step:5d} loss {loss:.4f} ({dt:.3f}s)")
                self.metrics_history.append((self.step, loss))
                # heartbeat as a structured event: step timing + monitor
                # state, one JSONL record / counter track per log interval
                self.tracer.metrics(
                    {"step": self.step, "loss": loss, "step_s": dt,
                     **{k: v for k, v in self.monitor.summary().items()
                        if not isinstance(v, dict)}}, kind="heartbeat")
                self.tracer.counter("step_s", dt)
            if self.protected is not None:
                self._refresh()
                due = (self.adaptive.due(self.step)
                       if self.adaptive is not None
                       else c.scrub_every
                       and self.step % c.scrub_every == 0)
                if due:
                    if self._scrub():
                        continue   # restored: step rolled back, re-enter loop
            if self.eval_fn is not None and c.eval_every \
                    and self.step % c.eval_every == 0:
                # post-scrub, so the store the eval sees is the corrected
                # one; results stay on device (fetch after training)
                with self.tracer.trace("eval", step=self.step):
                    self.eval_history.append(
                        self.eval_fn(self.state["params"], self.step))
            if (c.checkpoint_every and self.step % c.checkpoint_every == 0) \
                    or decision == Decision.CHECKPOINT_NOW:
                with self.tracer.trace("checkpoint", step=self.step):
                    self.save()
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"final_step": self.step, "monitor": self.monitor.summary(),
                "scrub": self.scrub_trajectory.summary(c.inject_p_bit)}
