"""Fault-tolerant training loop.

Composes the substrates: data prefetch, jit'd train step, periodic
checkpointing, heartbeat/straggler monitoring, and the paper's reliability
layer — the arena-backed scrub engine (core/reliability.py) verifying the
parameter store between steps and injected soft errors for validation.

Scrub scheduling is interval-based: parity is refreshed after every
parameter write (one fused encode launch over the packed arena) and every
`scrub_every` steps the fused scrub kernel verifies/corrects the store.
Each ScrubReport feeds two consumers: the HeartbeatMonitor (an
uncorrectable block returns Decision.RESTART, which triggers a checkpoint
restore) and a core.analytics.ScrubTrajectory (observed correction stream
vs the closed-form model).  `run()` survives (simulated) preemptions by
restoring the latest checkpoint and replaying the data stream from the step
counter (the synthetic pipeline is deterministic in step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import Checkpointer
from ..core.analytics import ScrubTrajectory
from ..core.reliability import ReliableStore, WordEccConfig
from ..faults.models import FaultModel, TransientBitFlips
from .monitor import Decision, HeartbeatMonitor, StragglerPolicy

__all__ = ["LoopConfig", "TrainLoop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    scrub_every: int = 0          # 0 = ECC scrubbing disabled
    log_every: int = 10
    inject_p_bit: float = 0.0     # simulated indirect soft-error rate per scrub interval
    inject_seed: int = 0
    fault_model: Optional[FaultModel] = None  # overrides inject_p_bit: any
                                  # repro.faults model drives the injection
    ecc_backend: str = "kernel"   # "kernel" (fused Pallas scrub) or "jnp"
    max_scrub_restores: int = 3   # consecutive ECC restores before giving up
                                  # and continuing with best-effort correction


class TrainLoop:
    def __init__(self, train_step: Callable, state: Any, batch_at: Callable[[int], Any],
                 cfg: LoopConfig, ckpt: Optional[Checkpointer] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 log: Callable[[str], None] = print,
                 inject_fn: Optional[Callable[[Any, int], Any]] = None):
        self.train_step = train_step
        self.state = state
        self.batch_at = batch_at
        self.cfg = cfg
        self.ckpt = ckpt
        self.monitor = monitor or HeartbeatMonitor()
        self.log = log
        self.step = 0
        self.store: Optional[ReliableStore] = None   # ECC store (params + arena parity)
        self.inject_fn = inject_fn    # deterministic corruptor hook (tests)
        self.metrics_history: list = []
        self.scrub_reports: list = []
        self.scrub_trajectory = ScrubTrajectory()
        self.total_restores = 0
        self._consecutive_scrub_restores = 0

    # -- reliability hooks -----------------------------------------------------
    # Protocol (paper §IV adapted): parity is refreshed after every parameter
    # write (the optimizer step == the mMPU "function output"); scrubbing
    # verifies/corrects accumulated storage flips between refreshes.  Both
    # are single fused launches over the packed arena.
    @property
    def parity(self):
        return self.store.parity if self.store is not None else None

    def attach_ecc(self) -> None:
        self.store = ReliableStore.protect(self.state["params"],
                                           backend=self.cfg.ecc_backend)
        self.scrub_trajectory.n_blocks = self.store.n_blocks

    def _refresh_parity(self) -> None:
        if self.store is not None:
            self.store = self.store.refresh(self.state["params"])

    def _corrupt(self, params: Any) -> Any:
        if self.inject_fn is not None:
            return self.inject_fn(params, self.step)
        model = self.cfg.fault_model
        if model is None and self.cfg.inject_p_bit > 0:
            model = TransientBitFlips(self.cfg.inject_p_bit)
        if model is not None:
            if model.permanent:
                # defect maps are device properties: one stable key for the
                # whole run, or the "permanent" faults would relocate every
                # scrub interval (and survive restores, correctly)
                key = jax.random.PRNGKey(self.cfg.inject_seed)
            else:
                # fold the restore count in: real soft errors do not replay,
                # so a post-restore replay of this step must draw fresh flips
                # (else an uncorrectable draw would recur identically and
                # livelock the run)
                key = jax.random.fold_in(
                    jax.random.PRNGKey(self.cfg.inject_seed + self.step),
                    self.total_restores)
            # dt=1: one model time unit == one scrub interval (inject_p_bit
            # has always been a per-scrub-interval rate)
            return model.corrupt(params, key, dt=1.0)
        return params

    def _scrub(self) -> bool:
        """One fused scrub pass; returns True if a restore rolled back the
        step counter (the caller must not finish the current iteration)."""
        params = self.state["params"]
        corrupted = self._corrupt(params)
        if corrupted is params:
            # no injection: scrub the just-refreshed store, reusing its
            # cached packed arena instead of packing the pytree again
            store = self.store
        else:
            store = ReliableStore(corrupted, self.store.parity,
                                  self.store.cfg, self.store.backend)
        fixed, report = store.scrub()
        self.scrub_reports.append((self.step, report))
        self.scrub_trajectory.add(self.step, int(report.corrected),
                                  int(report.parity_fixed),
                                  int(report.uncorrectable))
        decision = self.monitor.record_scrub(int(report.corrected),
                                             int(report.parity_fixed),
                                             int(report.uncorrectable))
        if decision == Decision.RESTART and self.ckpt is not None \
                and self.ckpt.latest_step() is not None:
            if self._consecutive_scrub_restores < self.cfg.max_scrub_restores:
                self._consecutive_scrub_restores += 1
                self.log(f"[reliability] step {self.step}: "
                         f"{int(report.uncorrectable)} uncorrectable blocks -> restore")
                return self.restore()
            # the same replay window keeps producing uncorrectable blocks:
            # restoring again cannot help, so accept the best-effort
            # correction and keep training rather than livelock
            self.log(f"[reliability] step {self.step}: restore limit "
                     f"({self.cfg.max_scrub_restores}) reached; continuing "
                     f"with best-effort corrected params")
        else:
            self._consecutive_scrub_restores = 0
        self.state = dict(self.state, params=fixed.params)
        self.store = fixed
        return False

    # -- checkpoint/restore ------------------------------------------------------
    def save(self) -> None:
        if self.ckpt is not None:
            snap = {"state": self.state, "step": self.step}
            if self.store is not None:
                snap["parity"] = self.store.parity
            self.ckpt.save(self.step, snap)

    def restore(self) -> bool:
        if self.ckpt is None:
            return False
        # an async re-save may be mid-rename on the dir we are about to
        # read; drain it before resolving snapshots
        self.ckpt.wait()
        if self.ckpt.latest_step() is None:
            return False
        snap = self.ckpt.restore()
        self.state = jax.tree.map(jax.numpy.asarray, snap["state"])
        self.total_restores += 1
        if "parity" in snap:
            # a parity table in the snapshot means the saving run had ECC
            # attached — re-arm it even in a fresh process (store is None),
            # or scrubbing would silently stop across preemption restarts.
            # A legacy per-leaf parity pytree (pre-arena checkpoints) is not
            # usable as the (n_blocks, F) table: re-encode from params.
            parity = snap["parity"]
            if self.store is not None:
                cfg, backend = self.store.cfg, self.store.backend
            else:
                cfg, backend = WordEccConfig(), self.cfg.ecc_backend
            if hasattr(parity, "shape") and getattr(parity, "ndim", 0) == 2:
                self.store = ReliableStore(self.state["params"],
                                           jax.numpy.asarray(parity),
                                           cfg, backend)
            else:
                self.log("[restore] legacy/unknown parity layout in snapshot;"
                         " re-encoding from restored params")
                self.store = ReliableStore.protect(self.state["params"],
                                                   cfg, backend)
            self.scrub_trajectory.n_blocks = self.store.n_blocks
        elif self.store is not None:
            self.store = self.store.refresh(self.state["params"])
        self.step = int(snap["step"])
        self.log(f"[restore] resumed from step {self.step}")
        return True

    # -- main loop ----------------------------------------------------------------
    def run(self, fail_at: Optional[int] = None) -> Dict:
        """Run to total_steps.  fail_at simulates a preemption at that step
        (raises, caller re-invokes run(); state restores from checkpoint)."""
        c = self.cfg
        while self.step < c.total_steps:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"simulated preemption at step {self.step}")
            t0 = time.perf_counter()
            batch = self.batch_at(self.step)
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            decision = self.monitor.record_step(dt)
            self.step += 1
            if c.log_every and self.step % c.log_every == 0:
                loss = float(metrics.get("loss", metrics.get("total", np.nan)))
                self.log(f"step {self.step:5d} loss {loss:.4f} ({dt:.3f}s)")
                self.metrics_history.append((self.step, loss))
            if self.store is not None:
                self._refresh_parity()
                if c.scrub_every and self.step % c.scrub_every == 0:
                    if self._scrub():
                        continue   # restored: step rolled back, re-enter loop
            if (c.checkpoint_every and self.step % c.checkpoint_every == 0) \
                    or decision == Decision.CHECKPOINT_NOW:
                self.save()
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"final_step": self.step, "monitor": self.monitor.summary(),
                "scrub": self.scrub_trajectory.summary(c.inject_p_bit)}
