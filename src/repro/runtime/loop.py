"""Fault-tolerant training loop.

Composes the substrates: data prefetch, jit'd train step, periodic
checkpointing, heartbeat/straggler monitoring, and the paper's reliability
layer — ECC scrubbing of the parameter store between steps and injected
soft errors for validation.  `run()` survives (simulated) preemptions by
restoring the latest checkpoint and replaying the data stream from the step
counter (the synthetic pipeline is deterministic in step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import Checkpointer
from ..core.reliability import ReliableStore, inject_bit_flips
from .monitor import Decision, HeartbeatMonitor, StragglerPolicy

__all__ = ["LoopConfig", "TrainLoop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    scrub_every: int = 0          # 0 = ECC scrubbing disabled
    log_every: int = 10
    inject_p_bit: float = 0.0     # simulated indirect soft-error rate per scrub interval
    inject_seed: int = 0


class TrainLoop:
    def __init__(self, train_step: Callable, state: Any, batch_at: Callable[[int], Any],
                 cfg: LoopConfig, ckpt: Optional[Checkpointer] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 log: Callable[[str], None] = print):
        self.train_step = train_step
        self.state = state
        self.batch_at = batch_at
        self.cfg = cfg
        self.ckpt = ckpt
        self.monitor = monitor or HeartbeatMonitor()
        self.log = log
        self.step = 0
        self.parity = None            # ECC check words (outside the jit state)
        self.metrics_history: list = []
        self.scrub_reports: list = []

    # -- reliability hooks -----------------------------------------------------
    # Protocol (paper §IV adapted): parity is refreshed after every parameter
    # write (the optimizer step == the mMPU "function output"); scrubbing
    # verifies/corrects accumulated storage flips between refreshes.
    def attach_ecc(self) -> None:
        self.parity = ReliableStore.protect(self.state["params"]).parity

    def _refresh_parity(self) -> None:
        if self.parity is not None:
            self.parity = ReliableStore.protect(self.state["params"]).parity

    def _scrub(self) -> None:
        params = self.state["params"]
        if self.cfg.inject_p_bit > 0:
            key = jax.random.PRNGKey(self.cfg.inject_seed + self.step)
            params = inject_bit_flips(params, key, self.cfg.inject_p_bit)
        fixed, report = ReliableStore(params, self.parity).scrub()
        self.scrub_reports.append((self.step, report))
        if int(report.uncorrectable) > 0 and self.ckpt is not None \
                and self.ckpt.latest_step() is not None:
            self.log(f"[reliability] step {self.step}: "
                     f"{int(report.uncorrectable)} uncorrectable blocks -> restore")
            self.restore()
            return
        self.state = dict(self.state, params=fixed.params)
        self.parity = fixed.parity

    # -- checkpoint/restore ------------------------------------------------------
    def save(self) -> None:
        if self.ckpt is not None:
            snap = {"state": self.state, "step": self.step}
            if self.parity is not None:
                snap["parity"] = self.parity
            self.ckpt.save(self.step, snap)

    def restore(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        snap = self.ckpt.restore()
        self.state = jax.tree.map(jax.numpy.asarray, snap["state"])
        if "parity" in snap:
            self.parity = jax.tree.map(jax.numpy.asarray, snap["parity"])
        self.step = int(snap["step"])
        self.log(f"[restore] resumed from step {self.step}")
        return True

    # -- main loop ----------------------------------------------------------------
    def run(self, fail_at: Optional[int] = None) -> Dict:
        """Run to total_steps.  fail_at simulates a preemption at that step
        (raises, caller re-invokes run(); state restores from checkpoint)."""
        c = self.cfg
        while self.step < c.total_steps:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"simulated preemption at step {self.step}")
            t0 = time.perf_counter()
            batch = self.batch_at(self.step)
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            decision = self.monitor.record_step(dt)
            self.step += 1
            if c.log_every and self.step % c.log_every == 0:
                loss = float(metrics.get("loss", metrics.get("total", np.nan)))
                self.log(f"step {self.step:5d} loss {loss:.4f} ({dt:.3f}s)")
                self.metrics_history.append((self.step, loss))
            if self.parity is not None:
                self._refresh_parity()
                if c.scrub_every and self.step % c.scrub_every == 0:
                    self._scrub()
            if (c.checkpoint_every and self.step % c.checkpoint_every == 0) \
                    or decision == Decision.CHECKPOINT_NOW:
                self.save()
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"final_step": self.step, "monitor": self.monitor.summary()}
