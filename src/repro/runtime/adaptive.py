"""Telemetry-driven adaptive scrub controller (ROADMAP item 2, DESIGN.md §18).

Fixed-interval scrubbing prices reliability at the *worst-case* fault
rate: a serving engine scrubbing every N ticks pays the same maintenance
tax whether the device is storming or silent.  The controller here makes
scrub cadence **pay-as-you-fault**: it watches the correction counts each
scrub actually returns and moves the interval inside
``[min_interval, max_interval]`` with a hysteresis band —

* ``events > high_events`` (or ANY uncorrectable block) — the store is
  hotter than one scrub per interval can absorb: **halve** the interval
  immediately.  Uncorrectables slam regardless of the band because every
  missed one is a potential silent corruption (SEC codes) or a restore
  (the runtime's RESTART path).
* ``events < low_events`` for ``patience`` consecutive scrubs — the
  store is quiet: **double** the interval.  The patience streak is the
  hysteresis; a single quiet scrub after a storm never relaxes cadence.
* otherwise the interval holds and the quiet streak resets.

``events`` is the drift detector's accounting: one corrected word, or
two per uncorrectable block (`obs.DriftDetector`, `ScrubTrajectory`).

The controller is **deterministic and replay-exact**: its state is a
pure function of the configuration and the sequence of
``record(index, counts)`` calls, with no clocks or randomness, so a
replay that presents the same counts at the same indices reproduces the
same scrub schedule bit-for-bit (tests/test_adaptive.py).  Scrub *decisions*
happen on the host — the controller never traces into jit.

Priors: `from_prior(p_bit, n_blocks)` seeds the initial interval from
the closed-form expectation (`core.analytics.expected_scrub_rates`) so a
run with a known fault-rate estimate starts near its steady state, and
`from_trajectory` replays a finished run's `ScrubTrajectory` as the
prior — yesterday's telemetry is today's interval0.  An optional
`obs.DriftDetector` gates *relaxation*: while the detector's verdict is
hot (observed corrections running above the model with enough evidence),
the controller refuses to lengthen the interval even through a lucky
quiet streak.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["AdaptiveScrubConfig", "AdaptiveScrub"]


@dataclasses.dataclass(frozen=True)
class AdaptiveScrubConfig:
    """Controller law parameters (hysteresis band + bounds).

    interval0     : initial scrub interval (ticks/steps between scrubs).
    min_interval  : floor — the storm-mode cadence.
    max_interval  : ceiling — how far a silent store may back off.
    low_events    : quiet threshold (events/scrub) for lengthening.
    high_events   : hot threshold (events/scrub) for immediate halving.
    patience      : consecutive quiet scrubs required before lengthening
                    (the hysteresis width).
    """

    interval0: int = 32
    min_interval: int = 1
    max_interval: int = 1024
    low_events: float = 0.5
    high_events: float = 4.0
    patience: int = 3

    def __post_init__(self):
        if not (1 <= self.min_interval <= self.interval0
                <= self.max_interval):
            raise ValueError(
                f"need 1 <= min_interval <= interval0 <= max_interval: "
                f"{self}")
        if self.low_events > self.high_events:
            raise ValueError(f"low_events > high_events: {self}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1: {self}")


class AdaptiveScrub:
    """Hysteresis-bounded scrub-interval controller (module doc).

    Protocol, from the owning loop/scheduler::

        ctl = AdaptiveScrub.from_prior(p_bit, n_blocks)
        ...
        if ctl.due(index):                   # index = step/tick counter
            counts = pool.scrub()            # or scheme.scrub(...)
            ctl.record(index, corrected, uncorrectable)

    `due` is pure (no state change); `record` applies the law and
    schedules the next scrub at ``index + interval``.
    """

    def __init__(self, cfg: AdaptiveScrubConfig = AdaptiveScrubConfig(),
                 detector=None, feed_detector: bool = True):
        self.cfg = cfg
        self.detector = detector        # optional obs.DriftDetector
        #: does `record` ingest counts into the detector?  Set False when
        #: another consumer (HeartbeatMonitor.record_scrub) already feeds
        #: the SAME detector instance, or every scrub would be counted
        #: twice in its window
        self.feed_detector = feed_detector
        self.interval = cfg.interval0
        self._next = cfg.interval0
        self._quiet = 0
        #: (index, events, interval-after-update) per recorded scrub
        self.history: List[Tuple[int, float, int]] = []

    # -- priors ---------------------------------------------------------------

    @classmethod
    def from_prior(cls, p_bit: float, n_blocks: int, *,
                   target_events: float = 2.0, detector=None,
                   feed_detector: bool = True,
                   **cfg_kw) -> "AdaptiveScrub":
        """Seed interval0 from the closed-form fault model: pick the
        interval whose expected events/scrub sits mid-band
        (``target_events``), assuming one model exposure unit per
        step/tick.  Unknown or zero p_bit keeps the configured default."""
        cfg = AdaptiveScrubConfig(**cfg_kw)
        per_step = _expected_events_per_exposure(p_bit, n_blocks)
        if per_step > 0:
            i0 = max(cfg.min_interval,
                     min(cfg.max_interval,
                         int(round(target_events / per_step)) or 1))
            cfg = dataclasses.replace(cfg, interval0=i0)
        return cls(cfg, detector=detector, feed_detector=feed_detector)

    @classmethod
    def from_trajectory(cls, trajectory, *, target_events: float = 2.0,
                        detector=None, feed_detector: bool = True,
                        **cfg_kw) -> "AdaptiveScrub":
        """Seed interval0 from a finished run's observed correction
        stream (`core.analytics.ScrubTrajectory`): events per recorded
        step become the exposure rate the prior interval is sized for."""
        cfg = AdaptiveScrubConfig(**cfg_kw)
        steps = list(getattr(trajectory, "steps", ()))
        if steps:
            span = max(steps) - min(steps) + 1
            events = (sum(trajectory.corrected)
                      + 2.0 * sum(trajectory.uncorrectable))
            per_step = events / span if span > 0 else 0.0
            if per_step > 0:
                i0 = max(cfg.min_interval,
                         min(cfg.max_interval,
                             int(round(target_events / per_step)) or 1))
                cfg = dataclasses.replace(cfg, interval0=i0)
        return cls(cfg, detector=detector, feed_detector=feed_detector)

    # -- the law --------------------------------------------------------------

    @property
    def next_due(self) -> int:
        """The index at which the next scrub fires."""
        return self._next

    def due(self, index: int) -> bool:
        """Should the caller scrub at this step/tick?  Pure — repeated
        calls at the same index agree."""
        return index >= self._next

    def record(self, index: int, corrected: int, uncorrectable: int = 0,
               parity_fixed: int = 0) -> int:
        """Ingest one scrub's fetched counts, apply the hysteresis law,
        and schedule the next scrub.  Returns the (possibly updated)
        interval.  ``parity_fixed`` is accepted for report-shape
        uniformity; parity-row heals are maintenance, not data events,
        so they never move the interval."""
        events = float(corrected) + 2.0 * float(uncorrectable)
        if self.detector is not None and self.feed_detector:
            self.detector.observe(int(corrected), int(uncorrectable))
        if uncorrectable > 0 or events > self.cfg.high_events:
            self.interval = max(self.cfg.min_interval, self.interval // 2)
            self._quiet = 0
        elif events < self.cfg.low_events:
            self._quiet += 1
            if self._quiet >= self.cfg.patience and not self._hot():
                self.interval = min(self.cfg.max_interval,
                                    self.interval * 2)
                self._quiet = 0
        else:
            self._quiet = 0
        self._next = index + self.interval
        self.history.append((int(index), events, self.interval))
        return self.interval

    def _hot(self) -> bool:
        """Drift-detector veto on relaxation: only an *evidenced* hot
        verdict blocks (DriftStatus.hot requires the evidence floor —
        `DriftDetector.confident` — by construction, so cold-start
        windows never pin the interval)."""
        return self.detector is not None and self.detector.status().hot

    def summary(self) -> dict:
        """Host-side summary for logs/benchmarks."""
        return {"interval": self.interval, "next_due": self._next,
                "n_scrubs": len(self.history),
                "intervals": [i for _, _, i in self.history]}


def _expected_events_per_exposure(p_bit: float, n_blocks: int) -> float:
    """Expected correction events from ONE exposure unit (dt=1) over an
    n_blocks arena — the drift detector's events accounting applied to
    `expected_scrub_rates`."""
    if not p_bit or p_bit <= 0 or n_blocks <= 0:
        return 0.0
    from ..core.analytics import expected_scrub_rates
    exp = expected_scrub_rates(p_bit, n_blocks)
    return (exp["corrected_per_scrub"]
            + 2.0 * exp["uncorrectable_per_scrub"])
