"""Heartbeat / straggler / integrity monitoring.

At 1000+ nodes the failure model is: slow nodes (stragglers), dead nodes
(preemption/hardware), and silent data corruption (the paper's subject).
The monitor tracks per-step wall times, flags statistical stragglers,
ingests the scrub engine's ScrubReport telemetry, and exposes a decision:
CONTINUE / CHECKPOINT_NOW / RESTART.  An uncorrectable ECC block is the one
signal that demands RESTART — the stored weights are known-corrupt beyond
repair, so the only safe move is a checkpoint restore.  In a real
deployment the same policy runs per-host and feeds the cluster scheduler;
here it drives the TrainLoop's simulated fault handling and is unit-tested.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["HeartbeatMonitor", "StragglerPolicy", "Decision"]


class Decision:
    CONTINUE = "continue"
    CHECKPOINT_NOW = "checkpoint_now"
    RESTART = "restart"


@dataclasses.dataclass
class StragglerPolicy:
    window: int = 32            # steps in the rolling window
    slow_factor: float = 2.0    # step slower than factor x median -> straggler
    max_consecutive_slow: int = 5
    heartbeat_timeout_s: float = 300.0


class HeartbeatMonitor:
    def __init__(self, policy: StragglerPolicy = StragglerPolicy()):
        self.policy = policy
        self.times: Deque[float] = deque(maxlen=policy.window)
        self.consecutive_slow = 0
        self.last_heartbeat = time.monotonic()
        self.flags: List[str] = []
        self.scrubs = 0
        self.bits_corrected = 0
        self.parity_fixed = 0
        self.uncorrectable = 0

    def record_step(self, seconds: float) -> str:
        self.last_heartbeat = time.monotonic()
        med = self.median()
        self.times.append(seconds)
        if med is not None and seconds > self.policy.slow_factor * med:
            self.consecutive_slow += 1
            self.flags.append(f"straggler step ({seconds:.3f}s vs median {med:.3f}s)")
        else:
            self.consecutive_slow = 0
        if self.consecutive_slow >= self.policy.max_consecutive_slow:
            # persistent slowness: snapshot so the scheduler can migrate us
            return Decision.CHECKPOINT_NOW
        return Decision.CONTINUE

    def record_scrub(self, corrected: int, parity_fixed: int,
                     uncorrectable: int) -> str:
        """Ingest one ScrubReport; uncorrectable blocks demand RESTART."""
        self.scrubs += 1
        self.bits_corrected += int(corrected)
        self.parity_fixed += int(parity_fixed)
        self.uncorrectable += int(uncorrectable)
        if int(uncorrectable) > 0:
            self.flags.append(
                f"uncorrectable ECC: {int(uncorrectable)} blocks")
            return Decision.RESTART
        return Decision.CONTINUE

    def heartbeat_ok(self) -> bool:
        return (time.monotonic() - self.last_heartbeat) < self.policy.heartbeat_timeout_s

    def median(self) -> Optional[float]:
        if not self.times:
            return None
        s = sorted(self.times)
        return s[len(s) // 2]

    def summary(self) -> Dict:
        return {"median_step_s": self.median(),
                "consecutive_slow": self.consecutive_slow,
                "n_flags": len(self.flags),
                "scrubs": self.scrubs,
                "bits_corrected": self.bits_corrected,
                "parity_fixed": self.parity_fixed,
                "uncorrectable": self.uncorrectable}
