"""Heartbeat / straggler / integrity monitoring.

At 1000+ nodes the failure model is: slow nodes (stragglers), dead nodes
(preemption/hardware), and silent data corruption (the paper's subject).
The monitor tracks per-step wall times, flags statistical stragglers,
ingests the scrub engine's ScrubReport telemetry, and exposes a decision:
CONTINUE / CHECKPOINT_NOW / RESTART.  An uncorrectable ECC block is the one
signal that demands RESTART — the stored weights are known-corrupt beyond
repair, so the only safe move is a checkpoint restore.  In a real
deployment the same policy runs per-host and feeds the cluster scheduler;
here it drives the TrainLoop's simulated fault handling and is unit-tested.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

from ..obs import DriftDetector, ScrubMetrics

__all__ = ["HeartbeatMonitor", "StragglerPolicy", "Decision"]


class Decision:
    CONTINUE = "continue"
    CHECKPOINT_NOW = "checkpoint_now"
    RESTART = "restart"


@dataclasses.dataclass
class StragglerPolicy:
    window: int = 32            # steps in the rolling window
    slow_factor: float = 2.0    # step slower than factor x median -> straggler
    max_consecutive_slow: int = 5
    heartbeat_timeout_s: float = 300.0


class HeartbeatMonitor:
    def __init__(self, policy: StragglerPolicy = StragglerPolicy(),
                 drift: Optional[DriftDetector] = None):
        self.policy = policy
        self.times: Deque[float] = deque(maxlen=policy.window)
        self.consecutive_slow = 0
        self.last_heartbeat = time.monotonic()
        self.flags: List[str] = []
        self.scrubs = 0
        self.bits_corrected = 0
        self.parity_fixed = 0
        self.uncorrectable = 0
        self.vote_disagreements = 0
        self.faults_injected = 0
        #: optional obs.DriftDetector — observed correction rates vs the
        #: closed-form model; attached by TrainLoop.attach_scheme when the
        #: loop injects at a known p_bit (or set directly)
        self.drift = drift
        self._was_drifting = False

    def record_step(self, seconds: float) -> str:
        self.last_heartbeat = time.monotonic()
        med = self.median()
        self.times.append(seconds)
        if med is not None and seconds > self.policy.slow_factor * med:
            self.consecutive_slow += 1
            self.flags.append(f"straggler step ({seconds:.3f}s vs median {med:.3f}s)")
        else:
            self.consecutive_slow = 0
        if self.consecutive_slow >= self.policy.max_consecutive_slow:
            # persistent slowness: snapshot so the scheduler can migrate us
            return Decision.CHECKPOINT_NOW
        return Decision.CONTINUE

    def record_scrub(self, record: ScrubMetrics) -> str:
        """Ingest one scrub interval's `obs.ScrubMetrics`; uncorrectable
        blocks demand RESTART."""
        self.scrubs += 1
        self.bits_corrected += record.corrected
        self.parity_fixed += record.parity_fixed
        self.uncorrectable += record.uncorrectable
        self.vote_disagreements += record.vote_disagreements
        self.faults_injected += record.injected
        if self.drift is not None:
            status = self.drift.observe(record.corrected,
                                        record.uncorrectable)
            if status.drifting and not self._was_drifting:
                self.flags.append(
                    f"correction-rate drift: observed "
                    f"{status.observed_per_scrub:.3g}/scrub vs expected "
                    f"{status.expected_per_scrub:.3g} "
                    f"({'hot' if status.hot else 'cold'})")
            self._was_drifting = status.drifting
        if record.uncorrectable > 0:
            self.flags.append(
                f"uncorrectable ECC: {record.uncorrectable} blocks")
            return Decision.RESTART
        return Decision.CONTINUE

    def heartbeat_ok(self) -> bool:
        return (time.monotonic() - self.last_heartbeat) < self.policy.heartbeat_timeout_s

    def median(self) -> Optional[float]:
        if not self.times:
            return None
        s = sorted(self.times)
        return s[len(s) // 2]

    def summary(self) -> Dict:
        out = {"median_step_s": self.median(),
               "consecutive_slow": self.consecutive_slow,
               "n_flags": len(self.flags),
               "scrubs": self.scrubs,
               "bits_corrected": self.bits_corrected,
               "parity_fixed": self.parity_fixed,
               "uncorrectable": self.uncorrectable,
               "vote_disagreements": self.vote_disagreements,
               "faults_injected": self.faults_injected}
        if self.drift is not None:
            out["drift"] = self.drift.status().as_dict()
        return out
