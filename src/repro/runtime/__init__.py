from .adaptive import AdaptiveScrub, AdaptiveScrubConfig
from .monitor import HeartbeatMonitor, StragglerPolicy
from .loop import TrainLoop, LoopConfig

__all__ = ["AdaptiveScrub", "AdaptiveScrubConfig", "HeartbeatMonitor",
           "StragglerPolicy", "TrainLoop", "LoopConfig"]
