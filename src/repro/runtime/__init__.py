from .monitor import HeartbeatMonitor, StragglerPolicy
from .loop import TrainLoop, LoopConfig

__all__ = ["HeartbeatMonitor", "StragglerPolicy", "TrainLoop", "LoopConfig"]
