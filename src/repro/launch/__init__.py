from . import mesh, specs

__all__ = ["mesh", "specs"]
