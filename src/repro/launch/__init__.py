from . import mesh, specs
from .batching import (BatchSpec, ContinuousBatcher, PagedKVPool, Request,
                       RequestResult, poisson_trace, sequential_slot_steps)
from .engine import GenerationEngine, fetch_telemetry, make_eval_hook

__all__ = ["mesh", "specs", "GenerationEngine", "fetch_telemetry",
           "make_eval_hook", "BatchSpec", "ContinuousBatcher", "PagedKVPool",
           "Request", "RequestResult", "poisson_trace",
           "sequential_slot_steps"]
