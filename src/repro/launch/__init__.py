from . import mesh, specs
from .engine import GenerationEngine, fetch_telemetry, make_eval_hook

__all__ = ["mesh", "specs", "GenerationEngine", "fetch_telemetry",
           "make_eval_hook"]
