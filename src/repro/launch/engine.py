"""Scan-compiled generation engine (DESIGN.md §13).

Replaces the interpreted serve loop (one jitted decode launch *per token*,
TMR as three *sequential* full generations, host syncs mid-hot-path) with
compiled generation under any protection scheme:

* **scan execution** — prefill + ``lax.scan`` over decode steps, so a whole
  ``gen``-token generation is one jitted launch; the KV-cache/token carry
  lives on device for the entire scan (XLA reuses the carry buffers
  in place — the donation the Python loop had to approximate per step).
* **copy axis** — TMR disciplines map onto real execution strategies
  instead of cost-model labels: the three (independently corrupted,
  per-copy ECC-scrubbed for `Compose`) stores are stacked on a leading
  copy axis; 'parallel'/'semi_parallel' ``vmap`` the generation over it
  (one batched launch), 'serial' re-runs the same compiled single-copy
  scan per copy (3x latency, but never 3x in-flight activations/cache —
  the paper's 1x-area property).
* **in-scan voting** — with ``vote_every=k`` the scan body votes the
  per-copy token ids (and, with ``vote_cache=True``, the KV caches) every
  k decode steps *before* divergence compounds; ``vote_every=0`` votes
  only the final token sequences, which is bit-exact against the legacy
  three-sequential-generations path under identical fault keys.
* **zero-sync telemetry** — every scrub/vote report stays on device as
  stacked counters inside the returned telemetry dict; `fetch_telemetry`
  performs the single host transfer after timing stops.
* **mesh execution** — constructed with ``mesh=``, the engine shards the
  store/caches/batch via the logical-axis rules, folds the TMR copy axis
  onto data-replica groups (`launch.mesh.fold_copy_axis`) so parallel
  disciplines reuse replicas that already exist, and runs arena scrubs as
  per-shard shard_map launches with psum'd counters (DESIGN.md §14) —
  bit-exact against the single-device engine under identical fault keys.

Typical use (serve.py, serve_bench.py, examples/serve_tmr.py)::

    engine = GenerationEngine(cfg, scheme, gen=64)
    store, prep = engine.prepare(params, key=key, fault=model)
    tokens, telem = engine.generate(store, batch)     # compiled hot path
    stats = fetch_telemetry({**prep, **telem})        # ONE host sync

The interpreted reference survives as ``execution='loop'`` /
``generate_loop`` — the bit-exactness oracle and the benchmark baseline.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding

from ..models.config import ModelConfig
from ..models.params import partition_specs
from ..models.steps import make_decode_step, make_prefill_step
from ..models.transformer import model_specs
from ..obs import LatencyTimeline, NULL_TRACER, Tracer
from ..obs import fetch_telemetry  # noqa: F401  (re-export: the PR-5 name;
#                                   now schema-validated by obs.registry)
from ..optim.sharding_rules import copy_stack_pspec
from ..pshard import DEFAULT_RULES, ShardingRules, use_mesh_and_rules
from ..reliability.scheme import (ArenaEcc, Compose, Scheme, Tmr,
                                  Unprotected)
from ..core import arena
from .mesh import fold_copy_axis

__all__ = ["GenerationEngine", "fetch_telemetry", "make_eval_hook"]


def _stack_copies(copies) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *copies)


def _copy(stacked, i: int) -> Any:
    return jax.tree.map(lambda x: x[i], stacked)


def _disagreements(t3: jax.Array) -> jax.Array:
    """Token positions where the three copies do not all agree (int32)."""
    d = (t3[0] != t3[1]) | (t3[0] != t3[2]) | (t3[1] != t3[2])
    return d.sum(dtype=jnp.int32)


def _with_emitted(tokens: jax.Array,
                  telem: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Add the `tokens_emitted` counter (static shape — a host int wrapped
    as a device scalar, no device->host transfer)."""
    out = dict(telem)
    out["tokens_emitted"] = jnp.asarray(tokens.size, jnp.int32)
    return out


class GenerationEngine:
    """Compiled batched generation under a protection scheme.

    Parameters
    ----------
    cfg         : model config (any architecture family).
    scheme      : protection scheme; `Unprotected`, `DiagParityEcc`,
                  `Tmr(discipline)`, or `Compose` (None -> Unprotected).
    gen         : number of tokens to generate (prompt excluded).
    cache_len   : decode-cache length (default prompt_len + gen).
    vote_every  : TMR/Compose with a concurrent discipline (parallel/
                  semi) — vote the per-copy token ids every k decode
                  steps inside the scan (0 = vote only at the end;
                  bit-exact vs the legacy sequential path).
    vote_cache  : also vote the KV caches at each in-scan vote point
                  (requires vote_every > 0).
    execution   : 'scan' (compiled, default) or 'loop' (interpreted
                  reference) — what `generate()` dispatches to.
    mesh        : optional jax Mesh — shard the store, KV caches and
                  batch over it (DESIGN.md §14).  Concurrent TMR
                  disciplines fold the copy axis onto data replica groups
                  when `data % 3 == 0` (`launch.mesh.fold_copy_axis`);
                  arena scrubs run shard-wise with psum'd counters.
                  Bit-exact vs mesh=None under identical fault keys.
    rules       : ShardingRules for logical-axis resolution on `mesh`
                  (default DEFAULT_RULES).
    """

    def __init__(self, cfg: ModelConfig, scheme: Optional[Scheme] = None, *,
                 gen: int, cache_len: Optional[int] = None,
                 vote_every: int = 0, vote_cache: bool = False,
                 execution: str = "scan", mesh=None,
                 rules: Optional[ShardingRules] = None,
                 cost_spec=None):
        if execution not in ("scan", "loop"):
            raise ValueError(f"execution must be 'scan' or 'loop', "
                             f"got {execution!r}")
        self.cfg = cfg
        self.scheme = scheme if scheme is not None else Unprotected()
        if vote_every or vote_cache:
            # loud no-op guards: in-scan voting only exists on the scan
            # engine's concurrent copy-axis path
            if not isinstance(self.scheme, (Tmr, Compose)):
                raise ValueError("vote_every/vote_cache require a TMR or "
                                 "Compose scheme (no copy axis to vote over)")
            if execution == "loop":
                raise ValueError("in-scan voting requires execution='scan' "
                                 "(the loop reference votes final sequences "
                                 "only)")
            if vote_cache and not vote_every:
                raise ValueError("vote_cache needs vote_every > 0 (cache "
                                 "votes happen at the in-scan vote points)")
            if self._discipline() == "serial":
                raise ValueError("in-scan voting needs concurrently "
                                 "executing copies; the serial discipline "
                                 "re-runs them sequentially (use "
                                 "tmr-parallel/tmr-semi, or vote_every=0)")
        self.gen = int(gen)
        self.cache_len = cache_len
        self.vote_every = int(vote_every)
        self.vote_cache = bool(vote_cache)
        self.execution = execution
        self.mesh = mesh
        self.rules = rules if rules is not None else DEFAULT_RULES
        # optional mMPU cost projection (costmodel.DeviceSpec): when set,
        # telemetry gains mmpu_* gauges computed from a host-side event
        # stream compiled ONCE per batch geometry — no device work, no
        # per-token cost; None (the default) adds exactly nothing.
        self.cost_spec = cost_spec
        self._mmpu_cache: Dict[int, Any] = {}
        self._built: Dict[int, Any] = {}   # prompt_len -> compiled fns
        # chunk steps -> compiled fns; LRU-bounded (see _build_chunk):
        # _chunk_sizes buckets tails to powers of two so one engine serving
        # at one chunk size compiles at most 1 + log2(chunk) programs, and
        # the LRU cap bounds the cache across callers sweeping chunk sizes.
        self._chunk_built: "OrderedDict[int, Any]" = OrderedDict()

    # -- scheme plumbing ----------------------------------------------------

    @property
    def copy_axis(self) -> bool:
        """Does the store carry a leading 3-copy axis?"""
        return isinstance(self.scheme, (Tmr, Compose))

    def _tmr(self) -> Optional[Tmr]:
        if isinstance(self.scheme, Tmr):
            return self.scheme
        if isinstance(self.scheme, Compose):
            return self.scheme.tmr
        return None

    # -- mMPU cost projection (costmodel, DESIGN.md §17) --------------------

    def mmpu_projection(self, batch_size: int):
        """(event stream, MmpuCost) for one full generation at this batch
        geometry, or None without a cost_spec.  Compiled host-side and
        cached per batch size; `serve --mmpu-events` dumps the stream."""
        if self.cost_spec is None:
            return None
        key = int(batch_size)
        if key not in self._mmpu_cache:
            from .. import costmodel
            profile = costmodel.StepProfile.from_model_config(
                self.cfg, batch=key)
            stream = costmodel.scale_stream(
                costmodel.lower_step(self.scheme, profile, self.cost_spec),
                self.gen)
            cost = costmodel.fold(stream, self.cost_spec,
                                  tokens=key * self.gen)
            self._mmpu_cache[key] = (stream, cost)
        return self._mmpu_cache[key]

    def _finish_telemetry(self, tokens, telem):
        """tokens_emitted plus, when cost_spec is set, the mmpu_* gauges
        (host constants wrapped as device scalars — no transfers)."""
        out = _with_emitted(tokens, telem)
        proj = self.mmpu_projection(tokens.shape[0])
        if proj is not None:
            _, cost = proj
            out["mmpu_cycles_per_token"] = jnp.asarray(
                cost.cycles_per_token, jnp.float32)
            out["mmpu_energy_pj_per_token"] = jnp.asarray(
                cost.energy_pj_per_token, jnp.float32)
            out["mmpu_events"] = jnp.asarray(cost.n_events, jnp.int32)
        return out

    def _discipline(self) -> Optional[str]:
        tmr = self._tmr()
        return tmr.discipline if tmr is not None else None

    # -- mesh plumbing (DESIGN.md §14) --------------------------------------

    @property
    def exec_mesh(self):
        """Mesh the compiled programs actually run under.  Concurrent TMR
        disciplines fold the copy axis onto data replica groups when the
        data axis can host the three copies; the serial discipline (one
        copy in flight at a time) and non-copy schemes keep the
        constructor mesh."""
        if self.mesh is None:
            return None
        if self.copy_axis and self._discipline() != "serial":
            folded = fold_copy_axis(self.mesh)
            if folded is not None:
                return folded
        return self.mesh

    def _param_shardings(self, stacked: bool):
        """NamedSharding tree for the serving store on the exec mesh:
        `partition_specs` resolution of the model's logical axes, with the
        leading 3-copy axis prepended (sharded over the "copy" axis on a
        folded mesh, replicated otherwise) when `stacked`."""
        mesh = self.exec_mesh
        pspecs = partition_specs(model_specs(self.cfg), mesh, self.rules)
        if stacked:
            pspecs = jax.tree.map(
                lambda s: copy_stack_pspec(s, mesh, rules=self.rules), pspecs)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    def shard_store(self, store: Any) -> Any:
        """Place a prepared store on the engine's exec mesh (no-op without
        one).  `prepare` calls this; it is public so externally built
        stores (checkpoint restores) can be placed the same way."""
        if self.mesh is None:
            return store
        return jax.device_put(
            store, self._param_shardings(stacked=self.copy_axis))

    def _shard_batch(self, batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        if self.mesh is None:
            return batch
        mesh = self.exec_mesh
        from ..pshard import spec_for
        return {
            k: jax.device_put(v, NamedSharding(mesh, spec_for(
                v.shape, ("batch",) + (None,) * (v.ndim - 1), mesh,
                self.rules)))
            for k, v in batch.items()}

    def prepare(self, params: Any, key: Optional[jax.Array] = None,
                fault=None, dt: float = 1.0) -> Tuple[Any, Dict[str, Any]]:
        """Build the scheme's serving store from clean params.

        Applies one exposure interval of `fault` to every held data copy
        (copy i under ``fold_in(key, 100 + i)`` — the serve-driver key
        convention, so engine stores are bit-identical to the legacy
        driver's under the same seed), then applies the scheme's
        *storage-side* protection: ECC schemes scrub the corrupted
        store(s) — for `Compose` all three copies in one fused launch —
        and TMR schemes stack the copies on the leading copy axis.

        Returns (store, prep_telemetry); the telemetry values are
        on-device scalars (fetch once via `fetch_telemetry`).

        With a mesh, the finished store is placed by `shard_store` and the
        arena scrubs run shard-wise (`scrub_sharded`) with psum'd counters
        — same bits, same counts as the single-device path.
        """
        scheme = self.scheme
        mesh = self.exec_mesh

        def corrupt(i: int) -> Any:
            if fault is None:
                return params
            return fault.corrupt(params, jax.random.fold_in(key, 100 + i), dt)

        def place(store, telem):
            return self.shard_store(store), telem

        with use_mesh_and_rules(mesh, self.rules):
            if isinstance(scheme, Unprotected):
                return place(corrupt(0), {})
            if isinstance(scheme, ArenaEcc):
                prot = scheme.protect(params)
                fixed, rep = scheme.scrub(scheme.adopt(corrupt(0),
                                                       prot.redundancy),
                                          mesh=mesh)
                return place(fixed.payload,
                             {"ecc_corrected": rep.corrected,
                              "ecc_parity_fixed": rep.parity_fixed,
                              "ecc_uncorrectable": rep.uncorrectable})
            if isinstance(scheme, Tmr):
                return place(_stack_copies([corrupt(i) for i in range(3)]),
                             {})
            if isinstance(scheme, Compose):
                buf, spec = arena.pack(params)
                parity = scheme.ecc._encode(buf)
                packed = [arena.pack(corrupt(i))[0] for i in range(3)]
                bufs, _, counts = scheme.ecc.scrub_copies(
                    packed, [parity] * 3, mesh=mesh)
                copies = [arena.unpack(b, spec) for b in bufs]
                return place(_stack_copies(copies),
                             {"ecc_corrected": counts[0],
                              "ecc_parity_fixed": counts[1],
                              "ecc_uncorrectable": counts[2]})
        raise ValueError(f"unhandled scheme {scheme!r}")

    # -- compiled paths -----------------------------------------------------

    def _build(self, prompt_len: int):
        if prompt_len in self._built:
            return self._built[prompt_len]
        cfg, gen = self.cfg, self.gen
        cache_len = self.cache_len or (prompt_len + gen)
        prefill = make_prefill_step(cfg, cache_len=cache_len)
        decode = make_decode_step(cfg)
        tmr = self._tmr()
        vote = tmr._vote() if tmr is not None else None
        vote_every, vote_cache = self.vote_every, self.vote_cache

        def single_scan(params, batch):
            tok0, _, cache = prefill(params, batch)
            if gen == 1:
                return tok0, {}

            def body(carry, _):
                tok, cache = carry
                ntok, _, cache = decode(params, tok, cache)
                return (ntok, cache), ntok

            _, toks = jax.lax.scan(body, (tok0, cache), None, length=gen - 1)
            # toks (gen-1, B, 1) -> (B, gen-1); tok0 (B, 1)
            return jnp.concatenate([tok0, toks[:, :, 0].T], axis=1), {}

        # concurrent copy-axis evaluator for 'parallel'/'semi_parallel':
        # vmap prefill+scan over the stacked copies (one batched launch).
        # On a copy-folded mesh (exec_mesh) the stacked axis is sharded
        # over three disjoint replica groups — each group runs ONE copy —
        # and the per-step vote/disagreement reads become tiny cross-
        # replica collectives on the token ids (DESIGN.md §14).  The
        # 'serial' discipline never enters this path — it re-runs the
        # single-copy scan per copy (generate_scan), keeping the paper's
        # 1x-area property: no 3x activations/cache in flight.
        def tmr_scan(stacked, batch):
            tok3, _, cache3 = jax.vmap(
                lambda p: prefill(p, batch))(stacked)

            def body(carry, step):
                tok3, cache3 = carry
                ntok3, _, cache3 = jax.vmap(decode)(stacked, tok3, cache3)
                dis = _disagreements(ntok3)
                if vote_every:
                    do = (step + 1) % vote_every == 0
                    voted = vote(ntok3[0], ntok3[1], ntok3[2])
                    ntok3 = jnp.where(do, voted[None], ntok3)
                    if vote_cache:
                        cache3 = jax.lax.cond(
                            do,
                            lambda c: jax.tree.map(
                                lambda x: jnp.broadcast_to(
                                    vote(x[0], x[1], x[2])[None],
                                    x.shape).astype(x.dtype), c),
                            lambda c: c, cache3)
                return (ntok3, cache3), (ntok3, dis)

            telem: Dict[str, jax.Array] = {}
            if gen == 1:
                seq3 = tok3
                telem["tmr_step_disagreements"] = \
                    _disagreements(tok3)[None]
            else:
                _, (steps3, dis) = jax.lax.scan(
                    body, (tok3, cache3), jnp.arange(gen - 1))
                # (gen-1, 3, B, 1) + (3, B, 1) -> per-copy (3, B, gen)
                seq3 = jnp.concatenate([tok3[None], steps3], axis=0)
                seq3 = jnp.moveaxis(seq3[..., 0], 0, -1)
                telem["tmr_step_disagreements"] = jnp.concatenate(
                    [_disagreements(tok3)[None], dis])
            out = vote(seq3[0], seq3[1], seq3[2])
            telem["tmr_final_disagreements"] = _disagreements(seq3)
            return out, telem

        def tmr_prefill(stacked, batch):
            return jax.vmap(lambda p: prefill(p, batch))(stacked)

        # donation: the Python-loop path re-launches decode per token; on
        # accelerators the cache carry is donated so each step updates the
        # KV buffers in place (CPU has no donation — skip the warning spam)
        donate = (2,) if jax.default_backend() != "cpu" else ()
        concurrent = tmr is not None and tmr.discipline != "serial"
        fns = {
            "prefill": jax.jit(prefill),
            "decode": jax.jit(decode, donate_argnums=donate),
            "single_scan": jax.jit(single_scan),
            "tmr_prefill": jax.jit(tmr_prefill) if concurrent else None,
            "tmr_scan": jax.jit(tmr_scan) if concurrent else None,
        }
        self._built[prompt_len] = fns
        return fns

    def _build_chunk(self, n: int):
        """Compiled decode-chunk programs: `n` scan steps from a (token,
        cache) carry.  Independent of prompt length (the cache shapes are
        traced), so keyed by chunk size only.  The TMR chunk takes the
        global step `offset` as a *traced* scalar, so the in-scan vote
        schedule `(step + 1) % vote_every == 0` lines up with the
        unchunked scan bit for bit at any chunk size — no recompile per
        chunk position."""
        if n in self._chunk_built:
            self._chunk_built.move_to_end(n)   # LRU touch
            return self._chunk_built[n]
        decode = make_decode_step(self.cfg)
        tmr = self._tmr()
        vote = tmr._vote() if tmr is not None else None
        vote_every, vote_cache = self.vote_every, self.vote_cache

        def chunk_scan(params, tok, cache):
            def body(carry, _):
                tok, cache = carry
                ntok, _, cache = decode(params, tok, cache)
                return (ntok, cache), ntok

            (tok, cache), toks = jax.lax.scan(body, (tok, cache), None,
                                              length=n)
            # toks (n, B, 1) -> (B, n)
            return tok, cache, toks[:, :, 0].T

        def tmr_chunk(stacked, tok3, cache3, offset):
            # identical body to _build's tmr_scan, stepped from `offset`
            def body(carry, step):
                tok3, cache3 = carry
                ntok3, _, cache3 = jax.vmap(decode)(stacked, tok3, cache3)
                dis = _disagreements(ntok3)
                if vote_every:
                    do = (step + 1) % vote_every == 0
                    voted = vote(ntok3[0], ntok3[1], ntok3[2])
                    ntok3 = jnp.where(do, voted[None], ntok3)
                    if vote_cache:
                        cache3 = jax.lax.cond(
                            do,
                            lambda c: jax.tree.map(
                                lambda x: jnp.broadcast_to(
                                    vote(x[0], x[1], x[2])[None],
                                    x.shape).astype(x.dtype), c),
                            lambda c: c, cache3)
                return (ntok3, cache3), (ntok3, dis)

            (tok3, cache3), (steps3, dis) = jax.lax.scan(
                body, (tok3, cache3), offset + jnp.arange(n))
            return tok3, cache3, steps3, dis

        donate = (2,) if jax.default_backend() != "cpu" else ()
        concurrent = tmr is not None and tmr.discipline != "serial"
        fns = {
            "chunk": jax.jit(chunk_scan, donate_argnums=donate),
            "tmr_chunk": (jax.jit(tmr_chunk, donate_argnums=donate)
                          if concurrent else None),
        }
        self._chunk_built[n] = fns
        while len(self._chunk_built) > self.CHUNK_CACHE_MAX:
            self._chunk_built.popitem(last=False)   # evict least recent
        assert len(self._chunk_built) <= self.CHUNK_CACHE_MAX
        return fns

    #: compiled-chunk cache bound: generous vs the <= 1 + log2(chunk)
    #: sizes one serving configuration produces, small enough that a
    #: caller sweeping chunk sizes can't grow the cache without bound.
    CHUNK_CACHE_MAX = 8

    def _chunk_sizes(self, chunk: int):
        """Chunk-size schedule for `gen - 1` decode steps: full `chunk`
        launches, then the tail bucketed into descending powers of two —
        every size drawn from {chunk} | {2^k < chunk}, so varying `gen`
        at a fixed chunk size reuses at most 1 + log2(chunk) compiled
        programs instead of compiling one per distinct tail."""
        rem = self.gen - 1
        while rem >= chunk:
            yield chunk
            rem -= chunk
        if rem > 0:
            p = 1 << (rem.bit_length() - 1)
            while rem > 0:
                if rem >= p:
                    yield p
                    rem -= p
                p >>= 1

    # -- public entry points ------------------------------------------------

    def generate(self, store: Any, batch: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Generate `gen` tokens: (tokens (B, gen) int32, telemetry).

        Dispatches on the configured execution mode; telemetry values are
        on-device counters (single fetch via `fetch_telemetry`)."""
        if self.execution == "loop":
            return self.generate_loop(store, batch)
        return self.generate_scan(store, batch)

    def generate_scan(self, store, batch):
        """The compiled path: one jitted prefill+scan launch per copy —
        one total for single stores and the vmapped parallel/semi copy
        axis; the serial discipline re-runs the same compiled program per
        copy (3x latency, 1x in-flight activations/cache) and votes the
        three token sequences."""
        with use_mesh_and_rules(self.exec_mesh, self.rules):
            batch = self._shard_batch(batch)
            fns = self._build(batch["tokens"].shape[1])
            if not self.copy_axis:
                tokens, telem = fns["single_scan"](store, batch)
            elif self._discipline() == "serial":
                outs = [fns["single_scan"](_copy(store, i), batch)[0]
                        for i in range(3)]
                tokens = self._tmr()._vote()(*outs)
                telem = {"tmr_final_disagreements":
                         _disagreements(jnp.stack(outs))}
            else:
                tokens, telem = fns["tmr_scan"](store, batch)
            return tokens, self._finish_telemetry(tokens, telem)

    def generate_chunked(self, store, batch, *, chunk: int,
                         timeline: Optional[LatencyTimeline] = None,
                         tracer: Tracer = NULL_TRACER
                         ) -> Tuple[jax.Array, Dict[str, jax.Array],
                                    LatencyTimeline]:
        """Latency-observable generation: the scan split into compiled
        chunk launches, a `LatencyTimeline` mark after each one lands.

        Bit-exact against `generate_scan` under every scheme and
        `vote_every` (the chunk programs thread the global step offset, so
        the in-scan vote schedule is unchanged).  Each mark is a
        `jax.block_until_ready` + `perf_counter` read — a sync point, NOT
        a device->host data transfer; telemetry stays on device and
        `fetch_telemetry` remains the single host sync.

        The first mark is TTFT (prefill -> first token); subsequent marks
        time each `chunk`-token launch, feeding `timeline.tpot_samples()`.
        The serial discipline runs copies 0 and 1 to completion first
        (preserving the 1x in-flight property), so its marks — and its
        honest TTFT — start at the third copy's prefill, when voted
        tokens first exist.

        Returns (tokens, telemetry, timeline).
        """
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if self.execution == "loop":
            raise ValueError("chunked generation requires execution='scan' "
                             "(the loop reference is already per-token)")
        timeline = timeline if timeline is not None else LatencyTimeline()
        with use_mesh_and_rules(self.exec_mesh, self.rules):
            batch = self._shard_batch(batch)
            fns = self._build(batch["tokens"].shape[1])
            timeline.begin()
            if not self.copy_axis:
                with tracer.trace("prefill", tokens=1):
                    tok, _, cache = fns["prefill"](store, batch)
                    jax.block_until_ready(tok)
                timeline.mark(1)
                parts = [tok]
                for n in self._chunk_sizes(chunk):
                    with tracer.trace("decode_chunk", tokens=n):
                        tok, cache, toks = self._build_chunk(n)["chunk"](
                            store, tok, cache)
                        jax.block_until_ready(toks)
                    timeline.mark(n)
                    parts.append(toks)
                tokens = jnp.concatenate(parts, axis=1)
                telem: Dict[str, jax.Array] = {}
            elif self._discipline() == "serial":
                tokens, telem = self._chunked_serial(
                    store, batch, fns, chunk, timeline, tracer)
            else:
                tokens, telem = self._chunked_concurrent(
                    store, batch, fns, chunk, timeline, tracer)
            return tokens, self._finish_telemetry(tokens, telem), timeline

    def _chunked_concurrent(self, store, batch, fns, chunk, timeline,
                            tracer):
        """Chunked 'parallel'/'semi' TMR: vmapped prefill + chunked vmapped
        scans; per-step disagreements and vote points identical to the
        unchunked tmr_scan (global-step offset threading)."""
        vote = self._tmr()._vote()
        with tracer.trace("tmr_prefill", tokens=1):
            tok3, _, cache3 = fns["tmr_prefill"](store, batch)
            jax.block_until_ready(tok3)
        timeline.mark(1)
        seq_parts = [tok3[None]]                       # (1, 3, B, 1)
        dis_parts = [_disagreements(tok3)[None]]
        off = 0
        for n in self._chunk_sizes(chunk):
            with tracer.trace("tmr_decode_chunk", tokens=n, offset=off):
                tok3, cache3, steps3, dis = \
                    self._build_chunk(n)["tmr_chunk"](
                        store, tok3, cache3, jnp.int32(off))
                jax.block_until_ready(steps3)
            timeline.mark(n)
            seq_parts.append(steps3)
            dis_parts.append(dis)
            off += n
        # (gen, 3, B, 1) -> per-copy (3, B, gen), as in tmr_scan
        seq3 = jnp.concatenate(seq_parts, axis=0)
        seq3 = jnp.moveaxis(seq3[..., 0], 0, -1)
        tokens = vote(seq3[0], seq3[1], seq3[2])
        return tokens, {
            "tmr_step_disagreements": jnp.concatenate(dis_parts),
            "tmr_final_disagreements": _disagreements(seq3)}

    def _chunked_serial(self, store, batch, fns, chunk, timeline, tracer):
        """Chunked serial TMR: copies 0/1 run to completion (sequentially,
        no marks — only their token sequences are kept), then copy 2's
        launches each complete a *voted* chunk (majority vote is
        elementwise, so chunk-wise voting equals the final-sequence
        vote)."""
        vote = self._tmr()._vote()
        per_copy = []                      # copies 0, 1: [tok0, chunk, ...]
        for i in range(2):
            params = _copy(store, i)
            with tracer.trace(f"serial_copy{i}", copy=i):
                tok, _, cache = fns["prefill"](params, batch)
                parts = [tok]
                for n in self._chunk_sizes(chunk):
                    tok, cache, toks = self._build_chunk(n)["chunk"](
                        params, tok, cache)
                    parts.append(toks)
            per_copy.append(parts)
        params = _copy(store, 2)
        with tracer.trace("serial_copy2_prefill", tokens=1):
            tok, _, cache = fns["prefill"](params, batch)
            voted = vote(per_copy[0][0], per_copy[1][0], tok)
            jax.block_until_ready(voted)
        timeline.mark(1)
        parts2, voted_parts = [tok], [voted]
        for idx, n in enumerate(self._chunk_sizes(chunk), start=1):
            with tracer.trace("serial_decode_chunk", tokens=n, copy=2):
                tok, cache, toks = self._build_chunk(n)["chunk"](
                    params, tok, cache)
                v = vote(per_copy[0][idx], per_copy[1][idx], toks)
                jax.block_until_ready(v)
            timeline.mark(n)
            parts2.append(toks)
            voted_parts.append(v)
        tokens = jnp.concatenate(voted_parts, axis=1)
        seq3 = jnp.stack([jnp.concatenate(p, axis=1)
                          for p in (per_copy[0], per_copy[1], parts2)])
        return tokens, {"tmr_final_disagreements": _disagreements(seq3)}

    def generate_loop(self, store, batch):
        """Interpreted reference: jitted prefill + per-token decode
        launches; TMR as three sequential full generations with one final
        vote (the legacy serving path — the bit-exactness oracle)."""
        with use_mesh_and_rules(self.exec_mesh, self.rules):
            batch = self._shard_batch(batch)
            fns = self._build(batch["tokens"].shape[1])

            def one(params):
                tok, _, cache = fns["prefill"](params, batch)
                toks = [tok]
                for _ in range(self.gen - 1):
                    tok, _, cache = fns["decode"](params, tok, cache)
                    toks.append(tok)
                return jnp.concatenate(toks, axis=1)

            if not self.copy_axis:
                tokens = one(store)
                return tokens, self._finish_telemetry(tokens, {})
            outs = [one(_copy(store, i)) for i in range(3)]
            seq3 = jnp.stack(outs)
            voted = self._tmr()._vote()(*outs)
            return voted, self._finish_telemetry(
                voted, {"tmr_final_disagreements": _disagreements(seq3)})

    def ttft(self, store, batch) -> jax.Array:
        """First generated token(s) only — the prefill launch.  Time this
        (after warmup) for time-to-first-token."""
        with use_mesh_and_rules(self.exec_mesh, self.rules):
            batch = self._shard_batch(batch)
            fns = self._build(batch["tokens"].shape[1])
            if not self.copy_axis:
                tok, _, _ = fns["prefill"](store, batch)
                return tok
            if self._discipline() == "serial":
                toks = [fns["prefill"](_copy(store, i), batch)[0]
                        for i in range(3)]
            else:
                tok3, _, _ = fns["tmr_prefill"](store, batch)
                toks = [tok3[0], tok3[1], tok3[2]]
            return self._tmr()._vote()(*toks)


def make_eval_hook(engine: GenerationEngine, batch: Dict[str, jax.Array]
                   ) -> Callable[[Any, int], Dict[str, Any]]:
    """A `TrainLoop` eval hook: compiled generation from the current params.

    The loop's scheme has already scrubbed/voted the store before the hook
    fires, so the hook runs the engine's single-copy scan path on the plain
    params — one launch per eval, tokens left on device (the loop keeps
    them in `eval_history`; fetch after training)."""
    def eval_fn(params: Any, step: int) -> Dict[str, Any]:
        with use_mesh_and_rules(engine.exec_mesh, engine.rules):
            fns = engine._build(batch["tokens"].shape[1])
            tokens, _ = fns["single_scan"](params, batch)
        return {"step": step, "tokens": tokens}

    return eval_fn
