"""Shape-set registry + abstract input construction for the dry-run.

`input_specs(arch, shape, mesh)` returns weak-type-correct, shardable
ShapeDtypeStruct stand-ins for every input of the lowered step function —
no device allocation ever happens for the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import get_config, get_rules_overrides, get_train_policy
from ..data.synthetic import make_batch_specs
from ..models.config import ModelConfig
from ..models.params import Spec, abstractify
from ..models.transformer import cache_specs, model_specs
from ..optim.sharding_rules import opt_spec_tree
from ..pshard import DEFAULT_RULES, ShardingRules

__all__ = ["SHAPES", "ShapeSpec", "applicable", "arch_rules",
           "abstract_inputs", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

#: encoder memory length for encdec decode shapes (fixed audio context)
ENCDEC_MEM_LEN = 4096


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.family} is full-attention (see DESIGN.md §5)")
    return None


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    return skip_reason(cfg, shape) is None


def arch_rules(arch: str, extra: Optional[dict] = None,
               serve: bool = False) -> ShardingRules:
    rules = DEFAULT_RULES.replace(**get_rules_overrides(arch, serve=serve))
    if extra:
        rules = rules.replace(**extra)
    return rules


def _mem_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if cfg.family == "vlm":
        return cfg.vis_tokens
    if cfg.family == "encdec":
        return shape.seq if shape.kind == "train" else ENCDEC_MEM_LEN
    return 0


def abstract_inputs(arch: str, shape_name: str, mesh,
                    rules: Optional[ShardingRules] = None) -> Dict[str, Any]:
    """Build all abstract inputs for the (arch, shape) cell.

    Returns a dict with keys depending on shape.kind:
      train  : state (params+opt), batch
      prefill: params, batch
      decode : params, token, cache
    plus 'cfg', 'rules', 'shape'.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        raise ValueError(f"{arch} x {shape_name} skipped: {reason}")
    rules = rules or arch_rules(arch)

    pspecs = model_specs(cfg)
    out: Dict[str, Any] = {"cfg": cfg, "rules": rules, "shape": shape}

    if shape.kind == "train":
        policy = get_train_policy(arch)
        out["policy"] = policy
        params = abstractify(pspecs, mesh, jnp.dtype(policy["param_dtype"]), rules)
        bspecs = make_batch_specs(cfg, shape.batch, shape.seq,
                                  mem_len=_mem_len(cfg, shape))
        batch = abstractify(bspecs, mesh, cfg.cdtype, rules)
        opt_specs = opt_spec_tree(pspecs)
        odt = jnp.dtype(policy["opt_dtype"])
        opt = {
            "m": abstractify(opt_specs, mesh, odt, rules),
            "v": abstractify(opt_specs, mesh, odt, rules),
            "count": abstractify(Spec((), (), "zeros", dtype="int32"), mesh,
                                 jnp.int32, rules),
        }
        out["state"] = {"params": params, "opt": opt}
        out["batch"] = batch
        return out

    # serving cells hold bf16 (compute-dtype) parameters
    params = abstractify(pspecs, mesh, cfg.cdtype, rules)
    if shape.kind == "prefill":
        bspecs = make_batch_specs(cfg, shape.batch, shape.seq,
                                  mem_len=_mem_len(cfg, shape))
        out["params"] = params
        out["batch"] = abstractify(bspecs, mesh, cfg.cdtype, rules)
    else:  # decode
        cspecs = cache_specs(cfg, shape.batch, shape.seq,
                             mem_len=_mem_len(cfg, shape))
        out["params"] = params
        out["cache"] = abstractify(cspecs, mesh, cfg.cdtype, rules)
        out["token"] = abstractify(
            Spec((shape.batch, 1), ("batch", None), dtype="int32"),
            mesh, jnp.int32, rules)
    return out
