import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512 "
    # LICM hoists a loop-invariant convert(residual-stack) out of the
    # backward while-loop: one fp32 copy of ALL saved layer inputs
    # (+11.9 GiB/device on deepseek-67b train_4k, the single largest buffer).
    # Disabling the pass converts per-slice instead: same bandwidth, 1/95th
    # the memory.  Measured in EXPERIMENTS.md §Perf.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")
# ^ MUST run before any other import: jax locks the device count on first init.

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..models.config import ModelConfig
from ..models.steps import make_decode_step, make_prefill_step, make_train_step
from ..optim import AdamWConfig
from ..pshard import use_mesh_and_rules
from .hlo_stats import parse_collectives
from .mesh import make_production_mesh
from .specs import SHAPES, abstract_inputs, arch_rules, skip_reason

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh)
cell with abstract inputs, prove it fits (memory_analysis) and extract the
roofline terms (cost_analysis + collective parsing).  No arrays are ever
allocated for the full configs."""


def lower_cell(arch: str, shape_name: str, mesh, rules_extra: Optional[dict] = None,
               donate: bool = True):
    """Returns (lowered, inputs-dict)."""
    serve = SHAPES[shape_name].kind != "train"
    rules = arch_rules(arch, rules_extra, serve=serve)
    with use_mesh_and_rules(mesh, rules):
        inp = abstract_inputs(arch, shape_name, mesh, rules)
        cfg: ModelConfig = inp["cfg"]
        kind = inp["shape"].kind
        if kind == "train":
            from ..models.params import partition_specs
            from ..models.transformer import model_specs
            # clamp microbatches so each slice still divides the DP axes
            dp = 1
            for ax in ("pod", "data"):
                if ax in mesh.axis_names:
                    dp *= mesh.shape[ax]
            K = min(inp["policy"]["microbatches"],
                    max(1, inp["shape"].batch // dp))
            pspecs = partition_specs(model_specs(cfg), mesh, rules)
            step = make_train_step(cfg, AdamWConfig(), microbatches=K,
                                   param_pspecs=pspecs,
                                   grad_dtype=jnp.dtype(inp["policy"]["grad_dtype"]))
            fn = jax.jit(step, donate_argnums=(0,) if donate else ())
            lowered = fn.lower(inp["state"], inp["batch"])
        elif kind == "prefill":
            step = make_prefill_step(cfg)
            lowered = jax.jit(step).lower(inp["params"], inp["batch"])
        else:
            step = make_decode_step(cfg)
            fn = jax.jit(step, donate_argnums=(2,) if donate else ())
            lowered = fn.lower(inp["params"], inp["token"], inp["cache"])
    return lowered, inp


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_extra: Optional[dict] = None,
             save_hlo: Optional[str] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": reason}

    t0 = time.time()
    lowered, _ = lower_cell(arch, shape_name, mesh, rules_extra)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "devices": int(n_dev),
        "kind": shape.kind,
        "seq": shape.seq,
        "batch": shape.batch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # memory_analysis is per-device
        "arg_bytes": int(ma.argument_size_in_bytes),
        "out_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        # cost_analysis is per-device
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": {
            "per_op_bytes": colls.per_op_bytes,
            "per_op_count": colls.per_op_count,
            "per_op_group": colls.per_op_group,
            "link_traffic_bytes": colls.link_traffic_bytes(),
        },
    }
    return result


def run_engine_cell(arch: str, scheme_spec: str = "tmr-parallel",
                    batch: int = 20, prompt_len: int = 64,
                    gen: int = 8) -> Dict[str, Any]:
    """Lower + compile the sharded generation engine's hot program on the
    dedicated TMR serving mesh (copy=3 x data=5 x model=16 — 240 chips of a
    256-chip pod, DESIGN.md §14) with abstract sharded inputs: proves the
    copy-folded store, KV caches and cross-replica vote collectives produce
    a coherent program and reports its per-device memory/collective
    footprint without allocating a single parameter."""
    from jax.sharding import NamedSharding

    from ..models.params import abstractify, partition_specs
    from ..models.transformer import model_specs
    from ..optim.sharding_rules import copy_stack_pspec
    from ..pshard import spec_for
    from ..reliability import parse_scheme
    from .engine import GenerationEngine
    from .mesh import make_tmr_serving_mesh

    mesh = make_tmr_serving_mesh()
    cfg = get_config(arch)
    engine = GenerationEngine(cfg, parse_scheme(scheme_spec), gen=gen,
                              mesh=mesh)
    emesh, rules = engine.exec_mesh, engine.rules
    with use_mesh_and_rules(emesh, rules):
        specs = model_specs(cfg)
        one = abstractify(specs, emesh, rules=rules)
        pspecs = partition_specs(specs, emesh, rules)
        store = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(
                (3,) + a.shape, a.dtype,
                sharding=NamedSharding(emesh, copy_stack_pspec(
                    s, emesh, rules=rules))),
            one, pspecs)
        tokens = jax.ShapeDtypeStruct(
            (batch, prompt_len), jnp.int32,
            sharding=NamedSharding(emesh, spec_for(
                (batch, prompt_len), ("batch", None), emesh, rules)))
        fns = engine._build(prompt_len)
        fn = fns["tmr_scan"] if engine.copy_axis else fns["single_scan"]
        t0 = time.time()
        lowered = fn.lower(store, {"tokens": tokens})
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    colls = parse_collectives(compiled.as_text())
    return {
        "arch": arch, "cell": "engine", "scheme": scheme_spec,
        "mesh": dict(emesh.shape), "devices": int(emesh.devices.size),
        "batch": batch, "prompt_len": prompt_len, "gen": gen,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "peak_bytes": int(ma.argument_size_in_bytes
                          + ma.output_size_in_bytes + ma.temp_size_in_bytes
                          - ma.alias_size_in_bytes),
        "collectives": {
            "per_op_bytes": colls.per_op_bytes,
            "per_op_count": colls.per_op_count,
            "link_traffic_bytes": colls.link_traffic_bytes(),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--rules", default=None,
                    help='JSON sharding-rule overrides, e.g. \'{"kv_seq": []}\'')
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--engine-cell", action="store_true",
                    help="lower the sharded generation engine (tmr_scan) on "
                         "the copy x data x model TMR serving mesh instead "
                         "of the train/prefill/decode cells")
    ap.add_argument("--scheme", default="tmr-parallel",
                    help="protection scheme for --engine-cell")
    args = ap.parse_args()

    if args.engine_cell:
        arch = "phi3-mini-3.8b" if args.arch == "all" else args.arch
        tag = f"{arch} x engine[{args.scheme}] x 3x5x16"
        try:
            res = run_engine_cell(arch, args.scheme)
        except Exception as e:
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            sys.exit(1)
        gb = res["peak_bytes"] / 2**30
        print(f"[ OK ] {tag}: peak {gb:.2f} GiB/dev, "
              f"collectives {res['collectives']['per_op_count']}, "
              f"compile {res['compile_s']}s", flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
        sys.exit(0)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rules_extra = json.loads(args.rules) if args.rules else None

    ok = True
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    res = run_cell(arch, shape, mp, rules_extra, args.save_hlo)
                except Exception as e:  # a failing cell is a bug in the system
                    ok = False
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"[FAIL] {tag}: {res['error']}", flush=True)
                else:
                    if "skipped" in res:
                        print(f"[SKIP] {tag}: {res['skipped']}", flush=True)
                    else:
                        gb = res["peak_bytes"] / 2**30
                        print(f"[ OK ] {tag}: peak {gb:.2f} GiB/dev, "
                              f"{res['flops']/1e12:.2f} TF/dev, "
                              f"compile {res['compile_s']}s", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(res) + "\n")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
