"""Continuous-batching reliable serving (DESIGN.md §16).

The scan engine (launch/engine.py) serves one fixed batch to completion:
every request in the batch pays for the longest generation, and a new
request waits for the whole batch to drain.  This module adds the serving
layer that keeps the batch full *without giving up any of the reliability
invariants*:

* **paged KV pool** (`PagedKVPool`) — KV state for all in-flight requests
  lives in fixed-size pages of one pool array per k/v.  The pool packs
  into the block-aligned uint32 arena (core/arena.py) — every page spans
  a whole number of ECC blocks — so the *same* fused diagonal-parity
  launches that protect the weights cover the KV state: `scrub()` is one
  fused scrub over the whole pool, `inject_scrub()` one fused
  corrupt+repair (kernels/inject_scrub).  Because pages are rewritten by
  every decode tick, parity follows a write-back discipline: the tick and
  admission programs re-encode the pool parity in-program
  (`DiagParityEcc.encode_arena`), so a later scrub never "corrects" fresh
  data toward stale parity.  Page 0 is reserved scratch: empty slots and
  unreserved page-table entries point at it, so masked rows read/write
  real storage that no active request ever depends on.

* **chunk-boundary scheduler** (`ContinuousBatcher`) — requests join and
  leave the in-flight batch only between compiled decode chunks.  The
  tick program has ONE shape (fixed `slots` batch rows, fixed `chunk`
  scan steps, fixed page-table width), so the compile cache stays at one
  tick program plus one admission program per prompt bucket.  Admission
  prefills at the bucket length, scatters the prefilled KV into reserved
  pages and writes the first token — one launch; each tick gathers every
  slot's page table into a (L, slots, S_cap, ...) cache view, scans
  `chunk` decode steps with *per-slot* positions, scatters the pages
  back and appends the new tokens to a per-slot output ring — one launch
  (per copy for the serial TMR discipline; one vmapped launch for
  parallel/semi).

* **zero-sync telemetry contract** — a tick performs no device->host
  data transfer except ONE batched `jax.device_get` of finished rows on
  the ticks where requests complete (completion itself is host-side
  integer arithmetic over the known generation lengths).  Scrub/vote
  counters accumulate on device through `obs.MetricsRegistry`; TMR final
  votes for finished requests are bitwise 2-of-3 majority computed on
  host *from the already-fetched* per-copy rows — same per-bit semantics
  as the `tmr_vote` kernel, zero extra syncs.

Bit-exactness: per-request tokens are independent of what the other
slots are doing.  Every decode op is batch-row-local (masked attention
reads only the row's own pages; page indirection is value-copying), so a
request admitted into a live batch produces exactly the tokens — and
exactly the vote disagreements — it produces when served through the
scheduler alone, under every `standard_grid()` scheme.  Tested in
tests/test_batching.py, including on a forced-host 2x2 mesh.

Typical use (serve.py --server, benchmarks/serve_load.py)::

    spec = BatchSpec(slots=4, page_tokens=16, chunk=8,
                     prompt_buckets=(16,), gen_cap=32)
    b = ContinuousBatcher(cfg, scheme, spec)
    prep = b.prepare(params, key=key, fault=fault)
    results = b.run(poisson_trace(32, rate_rps=8.0, spec=spec,
                                  vocab=cfg.vocab), realtime=True)
    stats = fetch_telemetry({**prep, **b.telemetry()})
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import arena
from ..models.config import ModelConfig
from ..models.steps import make_decode_step, make_prefill_step
from ..obs import DEFAULT_REGISTRY, LatencyTimeline, MetricsRegistry
from ..pshard import use_mesh_and_rules
from ..reliability.backend import dispatch as _backend
from ..reliability.scheme import ArenaEcc, Compose, Scheme
from .engine import GenerationEngine

__all__ = ["BatchSpec", "Request", "RequestResult", "PagedKVPool",
           "ContinuousBatcher", "poisson_trace", "sequential_slot_steps"]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """Static shape of the serving configuration — everything the compiled
    tick program's shapes depend on, so one spec == one tick program.

    slots          : batch rows of the tick program (the max in-flight
                     requests).
    page_tokens    : tokens per KV page.
    chunk          : decode steps per scheduler tick (the join/leave
                     granularity).
    prompt_buckets : admissible prompt lengths; one compiled admission
                     program per bucket (requests carry a bucket length).
    gen_cap        : max tokens a request may ask for.
    n_pages        : pool pages (default: full occupancy, slots views of
                     the whole cache window).
    """

    slots: int = 4
    page_tokens: int = 16
    chunk: int = 8
    prompt_buckets: Tuple[int, ...] = (16,)
    gen_cap: int = 32
    n_pages: Optional[int] = None

    def __post_init__(self):
        if self.slots < 1 or self.chunk < 1 or self.gen_cap < 1:
            raise ValueError(f"slots/chunk/gen_cap must be >= 1: {self}")
        if self.page_tokens < 1:
            raise ValueError(f"page_tokens must be >= 1: {self}")
        if not self.prompt_buckets:
            raise ValueError("need at least one prompt bucket")

    @property
    def max_prompt(self) -> int:
        return max(self.prompt_buckets)

    @property
    def cache_tokens(self) -> int:
        """S_cap: the per-slot cache window every gathered view exposes.
        Includes `chunk` slack so the final tick's overgenerated writes
        (discarded tokens past a request's length) land inside the window
        instead of clamping onto live history."""
        raw = self.max_prompt + self.gen_cap + self.chunk
        return _ceil_div(raw, self.page_tokens) * self.page_tokens

    @property
    def max_pages(self) -> int:
        """Page-table width: pages per slot covering the full window."""
        return self.cache_tokens // self.page_tokens

    @property
    def pool_pages(self) -> int:
        return self.n_pages if self.n_pages is not None \
            else self.slots * self.max_pages

    @property
    def out_cap(self) -> int:
        """Output-ring width: gen_cap plus chunk slack for the final
        tick's overgenerated (discarded) tokens."""
        return self.gen_cap + self.chunk

    def pages_for(self, prompt_len: int, gen: int) -> int:
        """Pages reserved at admission — the whole request up front, so an
        admitted request can never stall mid-stream on allocation."""
        return _ceil_div(prompt_len + gen, self.page_tokens)


@dataclasses.dataclass
class Request:
    """One serving request.  `prompt` length must be a spec bucket."""
    rid: int
    prompt: np.ndarray
    gen: int
    arrival_s: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray          # (gen,) int32 — voted for TMR schemes
    ttft_s: float               # submit -> first token (queue wait included)
    tpot_samples: List[float]   # per-token seconds from the chunk marks
    vote_disagreements: int     # positions where the 3 copies differed
    timeline: LatencyTimeline


@dataclasses.dataclass
class _Active:
    req: Request
    pages: np.ndarray
    emitted: int
    timeline: LatencyTimeline


class PagedKVPool:
    """Page-granular KV storage for one `BatchSpec`, ECC-protectable.

    Layout: k/v arrays of shape (pool_pages + 1, L, page_tokens, KV, hd)
    in the model compute dtype — page 0 is reserved scratch — with a
    leading 3-copy axis when `copies` (TMR/Compose store three
    independent cache states, one per weight copy; they are never voted
    or parity-shared across copies — each copy's KV is legitimate state
    of *that* copy's generation).

    With `ecc`, the whole pool (all copies) packs into ONE block-aligned
    uint32 arena — the word code is block-local and every page spans a
    whole number of ECC blocks, so an uncorrectable block is attributable
    to exactly one page — and carries one parity table.  `scrub()` /
    `inject_scrub()` are each ONE fused launch over that arena, counters
    on device.
    """

    def __init__(self, cfg: ModelConfig, spec: BatchSpec, *,
                 copies: bool, ecc: Optional[ArenaEcc] = None):
        self.cfg, self.spec, self.ecc, self.copies = cfg, spec, ecc, copies
        L, KV, hd = cfg.n_layers, cfg.n_kv, cfg.head_dim
        self.page_shape = (L, spec.page_tokens, KV, hd)
        if ecc is not None:
            pw = arena.words_for(self.page_shape, cfg.cdtype)
            if pw % arena.BLOCK:
                raise ValueError(
                    f"ECC-protected pool needs pages spanning whole "
                    f"{arena.BLOCK}-word blocks; page {self.page_shape} "
                    f"{cfg.cdtype} = {pw} words — raise page_tokens")
        shape = (spec.pool_pages + 1,) + self.page_shape
        if copies:
            shape = (3,) + shape
        self.k = jnp.zeros(shape, cfg.cdtype)
        self.v = jnp.zeros(shape, cfg.cdtype)
        self.arena_spec = arena.arena_spec({"k": self.k, "v": self.v})
        self.parity = None
        if ecc is not None:
            self.parity = ecc.encode_arena(
                arena.pack({"k": self.k, "v": self.v})[0])
        self._free: List[int] = list(range(1, spec.pool_pages + 1))
        self._scrub_fn = None
        self._inject_fns: Dict[Any, Any] = {}

    # -- host-side page allocator -------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[np.ndarray]:
        """Reserve n pages (LIFO — freshly freed pages are reused first,
        which the reuse test relies on); None when short."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        return np.asarray(pages, np.int32)

    def free(self, pages: np.ndarray) -> None:
        for p in reversed(list(map(int, pages))):
            if p <= 0 or p > self.spec.pool_pages:
                raise ValueError(f"bad page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)

    # -- fused reliability ops over the packed pool arena ---------------------

    def scrub(self) -> jax.Array:
        """One fused scrub of the whole pool against its parity table;
        returns the on-device (3,) counts (corrected, parity_fixed,
        uncorrectable).  Call between ticks (parity is tick-fresh by the
        write-back discipline)."""
        if self.ecc is None:
            raise ValueError("pool has no ECC (scheme carries no parity)")
        if self._scrub_fn is None:
            ecc, aspec = self.ecc, self.arena_spec

            def run(k, v, parity):
                fixed, par2, counts = ecc.scrub_arena(
                    arena.pack({"k": k, "v": v})[0], parity)
                kv = arena.unpack(fixed, aspec)
                return kv["k"], kv["v"], par2, counts

            self._scrub_fn = jax.jit(run)
        self.k, self.v, self.parity, counts = \
            self._scrub_fn(self.k, self.v, self.parity)
        return counts

    def inject_scrub(self, key: jax.Array, fault, dt: float = 1.0
                     ) -> jax.Array:
        """One fused corrupt+repair launch over the pool arena: sample the
        fault model's XOR word mask, then the `inject_scrub` kernel.
        Returns on-device (4,) counts (injected, corrected, parity_fixed,
        uncorrectable)."""
        if self.ecc is None:
            raise ValueError("pool has no ECC (scheme carries no parity)")
        fkey = (fault, float(dt))
        if fkey not in self._inject_fns:
            ecc, aspec = self.ecc, self.arena_spec

            def run(k, v, parity, key):
                buf = arena.pack({"k": k, "v": v})[0]
                mask = fault.word_mask(key, buf, dt)
                # the scheme picks its fused path (diag parity routes to
                # the dedicated inject_scrub kernel; other codes XOR+scrub
                # inside the same jit region)
                fixed, par2, counts = ecc.inject_scrub_arena(buf, parity,
                                                             mask)
                kv = arena.unpack(fixed, aspec)
                return kv["k"], kv["v"], par2, counts

            self._inject_fns[fkey] = jax.jit(run)
        self.k, self.v, self.parity, counts = \
            self._inject_fns[fkey](self.k, self.v, self.parity, key)
        return counts

    def corrupt(self, key: jax.Array, fault, dt: float = 1.0) -> jax.Array:
        """Corrupt-only exposure: apply one fault-model interval to the
        pool data WITHOUT repairing it — parity stays untouched (it still
        describes the pre-fault bits, which is exactly what a later scrub
        or a write-back read needs to repair against).  Drives the
        write-back-on-read and adaptive-scrub benchmarks, where faults
        must accumulate between repair points.  Returns the on-device
        injected-flip count."""
        fkey = ("corrupt", fault, float(dt))
        if fkey not in self._inject_fns:
            aspec = self.arena_spec

            def run(k, v, key):
                buf = arena.pack({"k": k, "v": v})[0]
                mask = fault.word_mask(key, buf, dt)
                kv = arena.unpack(buf ^ mask, aspec)
                injected = jnp.sum(
                    jax.lax.population_count(mask).astype(jnp.int32))
                return kv["k"], kv["v"], injected

            self._inject_fns[fkey] = jax.jit(run)
        self.k, self.v, injected = self._inject_fns[fkey](self.k, self.v,
                                                          key)
        return injected

    def corrupt_page(self, page: int, *, bit: int = 7, word: int = 0,
                     copy: int = 0) -> None:
        """Test hook: flip one stored bit of one page's k-plane through
        the arena word view (so the flip is exactly what a scrub must
        repair)."""
        buf = arena.pack({"k": self.k, "v": self.v})[0]
        pw = arena.words_for(self.page_shape, self.cfg.cdtype)
        idx = (copy * (self.spec.pool_pages + 1) + page) * pw + word \
            if self.copies else page * pw + word
        buf = buf.at[idx].set(buf[idx] ^ jnp.uint32(1 << bit))
        kv = arena.unpack(buf, self.arena_spec)
        self.k, self.v = kv["k"], kv["v"]


class ContinuousBatcher:
    """Chunk-boundary scheduler over the paged pool (module doc)."""

    def __init__(self, cfg: ModelConfig, scheme: Optional[Scheme] = None,
                 spec: BatchSpec = BatchSpec(), *, mesh=None, rules=None,
                 scrub_every: int = 0, adaptive=None,
                 forced_scrub_ticks: Optional[Sequence[int]] = None,
                 registry: MetricsRegistry = DEFAULT_REGISTRY):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"continuous batching supports dense/moe decode caches; "
                f"{cfg.family!r} caches are not paged yet")
        self.cfg, self.spec = cfg, spec
        # the engine supplies prepare() (fault keys/scrubs bit-identical
        # to whole-batch serving), the exec mesh and the scheme plumbing;
        # its compiled generation paths are not used by the scheduler.
        self.engine = GenerationEngine(cfg, scheme, gen=spec.gen_cap,
                                       cache_len=spec.cache_tokens,
                                       mesh=mesh, rules=rules)
        self.scheme = self.engine.scheme
        self._copy = self.engine.copy_axis
        self._serial = self.engine._discipline() == "serial"
        self.ecc = self.scheme if isinstance(self.scheme, ArenaEcc) \
            else self.scheme.ecc if isinstance(self.scheme, Compose) else None
        self.pool = PagedKVPool(cfg, spec, copies=self._copy, ecc=self.ecc)
        S, cap = spec.slots, spec.out_cap
        lead = (3,) if self._copy else ()
        self._tok = jnp.zeros(lead + (S, 1), jnp.int32)
        self._out = jnp.zeros(lead + (S, cap), jnp.int32)
        self._pos = jnp.zeros((S,), jnp.int32)
        self.table = np.zeros((S, spec.max_pages), np.int32)
        self._slots: List[Optional[_Active]] = [None] * S
        self.queue: Deque[Tuple[Request, LatencyTimeline]] = deque()
        self.results: Dict[int, RequestResult] = {}
        self.store = None
        self.ticks = 0
        self.decode_slot_steps = 0
        self.scrub_every = int(scrub_every)
        #: optional runtime.AdaptiveScrub: pay-as-you-fault scrub cadence.
        #: Overrides scrub_every; each pool scrub's counts are fetched and
        #: fed back (`record`) — the ONE documented exception to the
        #: zero-sync contract, amortized away exactly when it matters
        #: (quiet stores back off to rare scrubs, hence rare fetches).
        self.adaptive = adaptive
        #: replay hook: scrub at exactly these tick indices (overrides
        #: both cadences) — lets a fixed-cadence run be replayed under a
        #: recorded adaptive schedule for bit-exactness tests
        self._forced_scrub = (None if forced_scrub_ticks is None
                              else frozenset(int(t)
                                             for t in forced_scrub_ticks))
        #: tick indices at which the pool was scrubbed (whatever cadence
        #: chose them) — feed back as forced_scrub_ticks to replay
        self.scrub_ticks: List[int] = []
        #: host callback fired at the top of every tick, before the launch
        #: (fault-injection hook for benchmarks/tests: e.g.
        #: ``b.on_tick = lambda b: b.pool.corrupt(next_key(), fault)``)
        self.on_tick = None
        self._registry = registry
        self._wb = self.ecc is not None and self.ecc.write_back
        self._telem = registry.zeros(
            ["ecc_corrected", "ecc_parity_fixed", "ecc_uncorrectable",
             "ecc_read_corrected", "ecc_read_parity_fixed",
             "ecc_read_uncorrectable"])
        self._tokens_emitted = 0
        self._vote_disagreements = 0
        self._prep: Dict[str, Any] = {}
        self._tick_fn = None
        self._admit_fns: Dict[int, Any] = {}

    # -- program builders -----------------------------------------------------

    def _gather(self, pool, table):
        """(pool_pages+1, L, P, KV, hd)[table (S, MP)] ->
        (L, S, S_cap, KV, hd): every slot's page-table view as a dense
        cache.  Pure value-copy — page identity cannot affect tokens."""
        S, MP = self.spec.slots, self.spec.max_pages
        g = pool[table]                                # (S, MP, L, P, KV, hd)
        g = jnp.transpose(g, (2, 0, 1, 3, 4, 5))       # (L, S, MP, P, ...)
        return g.reshape(g.shape[0], S, MP * self.spec.page_tokens,
                         *g.shape[4:])

    def _scatter(self, pool, table, cache):
        """Inverse of `_gather`: write the mutated views back.  Scratch
        page 0 appears once per unreserved table entry; the duplicate
        writes race, but nothing ever reads page 0 through a validity
        mask, so the winner is immaterial."""
        S, MP, P = self.spec.slots, self.spec.max_pages, self.spec.page_tokens
        L = cache.shape[0]
        c = cache.reshape(L, S, MP, P, *cache.shape[3:])
        c = jnp.transpose(c, (1, 2, 0, 3, 4, 5))       # (S, MP, L, P, ...)
        return pool.at[table].set(c.astype(pool.dtype))

    def _refresh_parity(self, pk, pv, parity, pages=None):
        """Write-back parity for the pool the program just mutated — in
        the same launch, so parity is never stale between launches.

        With `pages` (traced int32 page ids), only those pages' parity
        rows are re-encoded: the word code is block-local and every page
        spans whole blocks, so refreshed rows are bit-identical to a full
        re-encode, and untouched pages' rows are already fresh from the
        launch that last wrote them (the tick scatter rewrites every
        table page, but pages outside pos..pos+chunk-1 round-trip
        unchanged values).  Duplicate ids (scratch page 0 appears once
        per slot) write identical rows — the .at[].set race is benign.
        Pool-sized encode -> touched-pages encode is the difference
        between parity costing like a scrub and costing like the chunk's
        own KV writes."""
        if self.ecc is None:
            return parity
        if pages is None:
            return self.ecc.encode_arena(arena.pack({"k": pk, "v": pv})[0])
        # page-granular gather (never materialize the full packed pool):
        # pack just the touched pages, encode, scatter the parity rows
        kg = pk[:, pages] if self._copy else pk[pages]
        vg = pv[:, pages] if self._copy else pv[pages]
        rows = self.ecc.encode_arena(arena.pack({"k": kg, "v": vg})[0])
        pwb = arena.words_for(self.pool.page_shape, self.cfg.cdtype) \
            // arena.BLOCK
        nkb = arena.words_for(self.pool.k.shape, self.cfg.cdtype) \
            // arena.BLOCK
        npg = self.spec.pool_pages + 1
        copies = jnp.arange(3 if self._copy else 1, dtype=jnp.int32)
        # global parity-row base per (copy, page), in the gathered pack's
        # own (copy-major, then page) order for both planes
        kbase = (copies[:, None] * npg + pages[None, :]) * pwb
        j = jnp.arange(pwb, dtype=jnp.int32)
        at = jnp.concatenate([(kbase[..., None] + j).reshape(-1),
                              (nkb + kbase[..., None] + j).reshape(-1)])
        return parity.at[at].set(rows)

    def _correct_pages(self, pk, pv, parity, pages):
        """Write-back-on-read (DESIGN.md §18): repair exactly the pages
        this tick is about to read, persisting both the corrected bits
        and their healed parity rows — so hot pages never carry a fault
        into the decode and never wait for the periodic scrub.  Runs in
        the pool layout BEFORE the gather (the gathered cache view is
        transposed per slot, so it cannot pair with parity rows); the
        global parity-row arithmetic is `_refresh_parity`'s.  Duplicate
        ids (scratch page 0 appears once per unreserved table entry)
        correct identical bits to identical values — the scatter race is
        benign, though a fault on scratch page 0 counts once per
        duplicate in the returned (3,) counts (scratch never holds live
        data, so the over-count is cosmetic)."""
        kg = pk[:, pages] if self._copy else pk[pages]
        vg = pv[:, pages] if self._copy else pv[pages]
        buf, gspec = arena.pack({"k": kg, "v": vg})
        pwb = arena.words_for(self.pool.page_shape, self.cfg.cdtype) \
            // arena.BLOCK
        nkb = arena.words_for(self.pool.k.shape, self.cfg.cdtype) \
            // arena.BLOCK
        npg = self.spec.pool_pages + 1
        copies = jnp.arange(3 if self._copy else 1, dtype=jnp.int32)
        kbase = (copies[:, None] * npg + pages[None, :]) * pwb
        j = jnp.arange(pwb, dtype=jnp.int32)
        at = jnp.concatenate([(kbase[..., None] + j).reshape(-1),
                              (nkb + kbase[..., None] + j).reshape(-1)])
        fixed, rows2, counts = self.ecc.scrub_arena(buf, parity[at])
        kv = arena.unpack(fixed, gspec)
        if self._copy:
            pk = pk.at[:, pages].set(kv["k"])
            pv = pv.at[:, pages].set(kv["v"])
        else:
            pk = pk.at[pages].set(kv["k"])
            pv = pv.at[pages].set(kv["v"])
        return pk, pv, parity.at[at].set(rows2), counts

    def _tick_program(self):
        if self._tick_fn is not None:
            return self._tick_fn
        decode = make_decode_step(self.cfg)
        chunk = self.spec.chunk
        copy, serial = self._copy, self._serial
        wb = self.ecc is not None and self.ecc.write_back

        def one(params, tok, pk, pv, pos, table):
            cache = {"pos": pos, "k": self._gather(pk, table),
                     "v": self._gather(pv, table)}

            def body(carry, _):
                tok, cache = carry
                ntok, _, cache = decode(params, tok, cache)
                return (ntok, cache), ntok

            (tok, cache), toks = jax.lax.scan(body, (tok, cache), None,
                                              length=chunk)
            pk = self._scatter(pk, table, cache["k"])
            pv = self._scatter(pv, table, cache["v"])
            # toks (chunk, S, 1) -> (S, chunk)
            return tok, pk, pv, cache["pos"], toks[:, :, 0].T

        def write_out(ob, tk, off):
            return jax.lax.dynamic_update_slice(ob, tk, (off,))

        P, MP = self.spec.page_tokens, self.spec.max_pages
        span = (chunk + P - 2) // P + 1   # max pages a chunk's writes span

        def touched(table, pos):
            """Page ids written this tick: each slot's consecutive table
            entries from pos//P on (clipped — overgeneration past the
            reservation resolves to scratch page 0, as do empty slots'
            all-zero rows and stale pos values)."""
            first = pos // P
            idx = jnp.clip(first[:, None]
                           + jnp.arange(span, dtype=pos.dtype)[None, :],
                           0, MP - 1)
            return jnp.take_along_axis(table, idx, axis=1).reshape(-1)

        def tick(store, tok, out, pk, pv, pos, parity, table, off):
            if wb:
                # correct-on-read: the tick reads every table page through
                # the gather, so repair all of them first — in the SAME
                # launch, before the decode sees a single bit
                pk, pv, parity, rcounts = self._correct_pages(
                    pk, pv, parity, table.reshape(-1))
            else:
                rcounts = jnp.zeros((3,), jnp.int32)
            if copy:
                def f(args):
                    p, t, k, v = args
                    return one(p, t, k, v, pos, table)
                if serial:   # sequential copies: the 1x in-flight property
                    tok, pk, pv, pos3, toks = jax.lax.map(
                        f, (store, tok, pk, pv))
                else:        # one vmapped launch over the copy axis
                    tok, pk, pv, pos3, toks = jax.vmap(f)(
                        (store, tok, pk, pv))
                pos = pos3[0]
                out = jax.vmap(jax.vmap(write_out),
                               in_axes=(0, 0, None))(out, toks, off)
            else:
                tok, pk, pv, pos, toks = one(store, tok, pk, pv, pos, table)
                out = jax.vmap(write_out)(out, toks, off)
            par = self._refresh_parity(pk, pv, parity,
                                       touched(table, pos - chunk))
            return tok, out, pk, pv, pos, par, rcounts

        donate = (1, 2, 3, 4, 5, 6) if jax.default_backend() != "cpu" else ()
        self._tick_fn = jax.jit(tick, donate_argnums=donate)
        return self._tick_fn

    def _admit_program(self, plen: int):
        if plen in self._admit_fns:
            return self._admit_fns[plen]
        prefill = make_prefill_step(self.cfg, cache_len=self.spec.cache_tokens)
        MP, P = self.spec.max_pages, self.spec.page_tokens
        copy, serial = self._copy, self._serial

        def place(pool, table_row, cache_kv):
            # (L, 1, S_cap, KV, hd) -> (MP, L, P, KV, hd) at table_row
            L = cache_kv.shape[0]
            c = cache_kv[:, 0].reshape(L, MP, P, *cache_kv.shape[3:])
            c = jnp.transpose(c, (1, 0, 2, 3, 4))
            return pool.at[table_row].set(c.astype(pool.dtype))

        def admit(store, tok, out, pk, pv, pos, parity, tokens, table_row,
                  slot):
            def one(args):
                params, k, v = args
                t0, _, cache = prefill(params, {"tokens": tokens})
                return (t0[0, 0], place(k, table_row, cache["k"]),
                        place(v, table_row, cache["v"]))

            if copy:
                if serial:
                    t0, pk, pv = jax.lax.map(one, (store, pk, pv))
                else:
                    t0, pk, pv = jax.vmap(one)((store, pk, pv))
                tok = tok.at[:, slot, 0].set(t0)
                out = out.at[:, slot, 0].set(t0)
            else:
                t0, pk, pv = one((store, pk, pv))
                tok = tok.at[slot, 0].set(t0)
                out = out.at[slot, 0].set(t0)
            pos = pos.at[slot].set(plen)
            # place() rewrote the slot's whole table row (scratch included
            # for unreserved entries) — refresh exactly those pages
            par = self._refresh_parity(pk, pv, parity, table_row)
            return tok, out, pk, pv, pos, par

        donate = (1, 2, 3, 4, 5, 6) if jax.default_backend() != "cpu" else ()
        fn = jax.jit(admit, donate_argnums=donate)
        self._admit_fns[plen] = fn
        return fn

    # -- scheduler ------------------------------------------------------------

    def prepare(self, params: Any, key: Optional[jax.Array] = None,
                fault=None, dt: float = 1.0) -> Dict[str, Any]:
        """Build the protected serving store (engine.prepare: identical
        fault keys and scrubs as whole-batch serving) and attach it."""
        self.store, prep = self.engine.prepare(params, key=key, fault=fault,
                                               dt=dt)
        self._prep = dict(prep)
        return prep

    @property
    def active(self) -> int:
        return sum(a is not None for a in self._slots)

    def submit(self, req: Request) -> None:
        plen = len(req.prompt)
        if plen not in self.spec.prompt_buckets:
            raise ValueError(f"prompt length {plen} not in buckets "
                             f"{self.spec.prompt_buckets}")
        if not 1 <= req.gen <= self.spec.gen_cap:
            raise ValueError(f"gen={req.gen} outside 1..{self.spec.gen_cap}")
        tl = LatencyTimeline()
        tl.begin()                      # TTFT clock includes queue wait
        self.queue.append((req, tl))

    def admit(self) -> int:
        """Admit queued requests (FIFO, no overtaking) while a slot and a
        full upfront page reservation are available.  Returns the number
        admitted; each admission is one compiled launch."""
        if self.store is None:
            raise RuntimeError("call prepare() before serving")
        n = 0
        while self.queue:
            req, tl = self.queue[0]
            slot = next((i for i, a in enumerate(self._slots) if a is None),
                        None)
            if slot is None:
                break
            pages = self.pool.alloc(self.spec.pages_for(len(req.prompt),
                                                        req.gen))
            if pages is None:
                break
            self.queue.popleft()
            self._admit_one(req, tl, slot, pages)
            n += 1
        return n

    def _admit_one(self, req, tl, slot, pages):
        row = np.zeros(self.spec.max_pages, np.int32)
        row[:len(pages)] = pages
        self.table[slot] = row
        fn = self._admit_program(len(req.prompt))
        tokens = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        with use_mesh_and_rules(self.engine.exec_mesh, self.engine.rules):
            (self._tok, self._out, self.pool.k, self.pool.v, self._pos,
             self.pool.parity) = fn(
                self.store, self._tok, self._out, self.pool.k, self.pool.v,
                self._pos, self.pool.parity, tokens, jnp.asarray(row),
                jnp.int32(slot))
        jax.block_until_ready(self._tok)     # sync point, no data transfer
        tl.mark(1)                           # <- TTFT
        self._slots[slot] = _Active(req=req, pages=pages, emitted=1,
                                    timeline=tl)

    def tick(self) -> List[RequestResult]:
        """One scheduler tick: `chunk` decode steps for every slot in one
        launch (per copy when serial), then host-side completion
        bookkeeping.  The ONLY device->host transfer is one batched
        `device_get` of finished rows, and only on ticks where a request
        finishes."""
        spec = self.spec
        if self.on_tick is not None:
            self.on_tick(self)       # pre-launch hook (fault injection)
        active = [(i, a) for i, a in enumerate(self._slots) if a is not None]
        off = np.zeros(spec.slots, np.int32)
        for i, a in active:
            off[i] = a.emitted
        with use_mesh_and_rules(self.engine.exec_mesh, self.engine.rules):
            (self._tok, self._out, self.pool.k, self.pool.v, self._pos,
             self.pool.parity, rcounts) = self._tick_program()(
                self.store, self._tok, self._out, self.pool.k, self.pool.v,
                self._pos, self.pool.parity, jnp.asarray(self.table),
                jnp.asarray(off))
        jax.block_until_ready(self._tok)
        if self._wb:
            # read-path repairs land in their own counters (on device)
            self._telem = self._registry.accumulate(
                self._telem, {"ecc_read_corrected": rcounts[0],
                              "ecc_read_parity_fixed": rcounts[1],
                              "ecc_read_uncorrectable": rcounts[2]})
        self.ticks += 1
        self.decode_slot_steps += spec.chunk * spec.slots
        done: List[Tuple[int, _Active]] = []
        for i, a in active:
            fresh = min(spec.chunk, a.req.gen - a.emitted)
            if fresh > 0:
                a.timeline.mark(fresh)
            a.emitted = min(a.req.gen, a.emitted + spec.chunk)
            if a.emitted >= a.req.gen:
                done.append((i, a))
        finished: List[RequestResult] = []
        if done:
            # ONE batched transfer for every finished row this tick
            rows = jax.device_get([self._out[..., i, :] for i, _ in done])
            for (i, a), row in zip(done, rows):
                finished.append(self._finish(i, a, np.asarray(row)))
        if self.ecc is not None and self._scrub_due():
            counts = self.pool.scrub()       # counters stay on device
            self.scrub_ticks.append(self.ticks)
            if self.adaptive is not None and self._forced_scrub is None:
                # the documented zero-sync exception: the controller needs
                # the counts on host to reschedule; one (4,)-int fetch per
                # scrub, and scrubs get RARER as the controller backs off
                c = np.asarray(jax.device_get(counts))
                self.adaptive.record(self.ticks, int(c[0]), int(c[2]),
                                     int(c[1]))
            self._telem = self._registry.accumulate(
                self._telem, {"ecc_corrected": counts[0],
                              "ecc_parity_fixed": counts[1],
                              "ecc_uncorrectable": counts[2]})
        return finished

    def _scrub_due(self) -> bool:
        """Which cadence owns this tick: a forced replay schedule beats
        the adaptive controller beats the fixed interval."""
        if self._forced_scrub is not None:
            return self.ticks in self._forced_scrub
        if self.adaptive is not None:
            return self.adaptive.due(self.ticks)
        return bool(self.scrub_every) and self.ticks % self.scrub_every == 0

    def _finish(self, slot, a, row) -> RequestResult:
        gen = a.req.gen
        if self._copy:
            t = row[:, :gen].astype(np.int32)
            # bitwise 2-of-3 majority — per-bit identical to the tmr_vote
            # kernel, on host from the single already-fetched transfer
            tokens = (t[0] & t[1]) | (t[0] & t[2]) | (t[1] & t[2])
            dis = int(np.sum(~((t[0] == t[1]) & (t[0] == t[2]))))
        else:
            tokens, dis = row[:gen].astype(np.int32), 0
        res = RequestResult(rid=a.req.rid, tokens=tokens,
                            ttft_s=a.timeline.ttft_s,
                            tpot_samples=list(a.timeline.tpot_samples()),
                            vote_disagreements=dis, timeline=a.timeline)
        self.results[a.req.rid] = res
        self._tokens_emitted += gen
        self._vote_disagreements += dis
        self.pool.free(a.pages)
        self.table[slot] = 0
        self._slots[slot] = None
        return res

    def drain(self) -> None:
        """Tick until every queued and in-flight request has finished."""
        while self.queue or self.active:
            self.admit()
            if self.active:
                self.tick()
            elif self.queue:
                req, _ = self.queue[0]
                raise RuntimeError(
                    f"request {req.rid} needs "
                    f"{self.spec.pages_for(len(req.prompt), req.gen)} pages "
                    f"but the idle pool has {self.pool.free_pages} of "
                    f"{self.spec.pool_pages} — pool too small")

    def run(self, requests: Sequence[Request], *, realtime: bool = False
            ) -> List[RequestResult]:
        """Serve a trace to completion.  realtime=True paces submissions
        by `arrival_s` (open loop — arrivals never wait for service);
        False submits in arrival order immediately (deterministic, for
        tests)."""
        order = sorted(requests, key=lambda r: r.arrival_s)
        t0 = time.perf_counter()
        i, n = 0, len(order)
        while i < n or self.queue or self.active:
            now = time.perf_counter() - t0
            while i < n and (not realtime or order[i].arrival_s <= now):
                self.submit(order[i])
                i += 1
            self.admit()
            if self.active:
                self.tick()
            elif self.queue:
                self.drain()        # raises: pool too small for the head
            elif realtime and i < n:
                time.sleep(max(0.0, min(0.005,
                                        order[i].arrival_s - now)))
        return [self.results[r.rid] for r in requests]

    def telemetry(self) -> Dict[str, Any]:
        """Schema-valid telemetry dict — device counters plus host tallies;
        fetch once with `obs.fetch_telemetry` after timing stops.  The
        prepare-time scrub counters are folded into the totals, so the
        serve-driver merge idiom ``{**prep, **batcher.telemetry()}``
        yields grand totals rather than letting fresh zeros shadow the
        prepare counts."""
        out: Dict[str, Any] = dict(self._telem)
        for k, v in self._prep.items():
            out[k] = out[k] + v if k in out else v
        out["tokens_emitted"] = np.int32(self._tokens_emitted)
        if self._copy:
            out["tmr_final_disagreements"] = \
                np.int32(self._vote_disagreements)
        return out


# -- load generation and the whole-batch baseline ----------------------------

def poisson_trace(n: int, *, rate_rps: float, spec: BatchSpec, vocab: int,
                  seed: int = 0,
                  gen_choices: Optional[Sequence[int]] = None,
                  gen_weights: Optional[Sequence[float]] = None
                  ) -> List[Request]:
    """Open-loop Poisson trace: exponential inter-arrivals at `rate_rps`,
    prompt lengths drawn from the spec's buckets, generation lengths from
    `gen_choices` (default: a skewed short/long mix over gen_cap —
    the workload continuous batching exists for)."""
    rng = np.random.default_rng(seed)
    if gen_choices is None:
        gen_choices = [max(1, spec.gen_cap // 4), spec.gen_cap]
        gen_weights = [0.75, 0.25]
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n))
    out = []
    for i in range(n):
        plen = int(rng.choice(np.asarray(spec.prompt_buckets)))
        gen = int(rng.choice(np.asarray(gen_choices), p=gen_weights))
        out.append(Request(rid=i,
                           prompt=rng.integers(0, vocab, (plen,),
                                               dtype=np.int32),
                           gen=gen, arrival_s=float(arrivals[i])))
    return out


def sequential_slot_steps(requests: Sequence[Request], slots: int) -> int:
    """Decode slot-steps whole-batch serving spends on a trace: requests
    grouped `slots` at a time in arrival order, every row of a group
    padded to the group's longest generation (the engine's fixed-batch
    contract).  Compare with `ContinuousBatcher.decode_slot_steps` for
    the machine-independent goodput ratio."""
    order = sorted(requests, key=lambda r: r.arrival_s)
    total = 0
    for g in range(0, len(order), slots):
        grp = order[g:g + slots]
        total += slots * max(r.gen for r in grp)
    return total
