"""Production and test meshes.

Functions, not module constants — importing this module never touches jax
device state (device count is locked at first jax init, and only the dry-run
sets the 512-device host-platform flag).

`fold_copy_axis` is the sharded serving engine's replica-group trick
(DESIGN.md §14): a ("data", "model") mesh whose data axis is divisible by
the TMR copy count reshapes into ("copy", "data", "model") — the three TMR
copies land on three *disjoint replica groups* of existing data-parallel
devices, so parallel/semi TMR reuses replicas that are already there
instead of tripling any one device's work.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_test_mesh", "make_tmr_serving_mesh",
           "fold_copy_axis", "require_devices"]


def require_devices(n: int, what: str) -> None:
    """Fail with an actionable message when the host exposes fewer devices
    than a mesh needs (jax's own error is an opaque device-count mismatch
    that never mentions the forced-host-platform escape hatch)."""
    have = jax.device_count()
    if have < n:
        raise ValueError(
            f"{what} needs {n} devices but this host exposes only {have}. "
            f"On CPU, force virtual host devices BEFORE jax initializes: "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(or os.environ['XLA_FLAGS'] at the very top of the script).")


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips as
    ("data", "model"); two pods = 512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    require_devices(2 * 16 * 16 if multi_pod else 16 * 16,
                    f"production mesh {'x'.join(map(str, shape))}")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU sharding tests (requires forced host devices)."""
    require_devices(data * model, f"test mesh {data}x{model}")
    return jax.make_mesh((data, model), ("data", "model"))


def make_tmr_serving_mesh(copies: int = 3, data: int = 5, model: int = 16):
    """Dedicated TMR serving mesh: ("copy", "data", "model") with the copy
    axis sized to the TMR copy count — 3x5x16 = 240 of a 256-chip pod serve
    triple-redundant with 5-way data parallelism inside each replica group.
    Equivalent to `fold_copy_axis(make_test_mesh(copies*data, model))`."""
    require_devices(copies * data * model,
                    f"TMR serving mesh {copies}x{data}x{model}")
    return jax.make_mesh((copies, data, model), ("copy", "data", "model"))


def fold_copy_axis(mesh: Mesh, copies: int = 3) -> Optional[Mesh]:
    """Fold a leading TMR copy axis onto a mesh's data-axis replica groups.

    ("data", "model") with data % copies == 0 -> ("copy", "data", "model")
    over the SAME devices, data shrunk by the copy factor: each copy owns a
    disjoint replica group of data//copies devices.  Returns None when the
    data axis cannot host the copies (callers then keep the original mesh
    and replicate the copy axis instead — correct, just not free).
    A mesh that already has a "copy" axis is returned unchanged.
    """
    if "copy" in mesh.axis_names:
        return mesh
    if mesh.axis_names != ("data", "model"):
        return None
    d = mesh.shape["data"]
    if d % copies != 0:
        return None
    devices = mesh.devices.reshape(copies, d // copies,
                                   mesh.shape["model"])
    return Mesh(devices, ("copy", "data", "model"))
