"""Production meshes.

Functions, not module constants — importing this module never touches jax
device state (device count is locked at first jax init, and only the dry-run
sets the 512-device host-platform flag).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips as
    ("data", "model"); two pods = 512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU sharding tests (requires forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
