"""HLO-text statistics: collective traffic extraction for the roofline.

`cost_analysis()` gives FLOPs and bytes but not collective traffic, so we
parse the partitioned (per-device SPMD) HLO module: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes its result-shape bytes, with ring-traffic multipliers applied
when converting to link time.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

__all__ = ["CollectiveStats", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(?P<rows>\d+),(?P<cols>\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: Dict[str, int]           # op kind -> sum of result bytes
    per_op_count: Dict[str, int]
    per_op_group: Dict[str, float]         # op kind -> mean group size
    total_result_bytes: int

    def link_traffic_bytes(self) -> float:
        """Per-device bytes crossing ICI links, ring-algorithm model:
        all-reduce moves 2(n-1)/n x result bytes; all-gather and
        reduce-scatter (n-1)/n x the larger buffer; all-to-all (n-1)/n;
        collective-permute 1x."""
        total = 0.0
        for op, b in self.per_op_bytes.items():
            n = max(self.per_op_group.get(op, 2.0), 2.0)
            if op == "all-reduce":
                total += 2.0 * (n - 1) / n * b
            elif op in ("all-gather", "reduce-scatter", "all-to-all"):
                total += (n - 1) / n * b
            else:  # collective-permute
                total += b
        return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    per_bytes: Dict[str, int] = defaultdict(int)
    per_count: Dict[str, int] = defaultdict(int)
    group_sum: Dict[str, float] = defaultdict(float)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        # async pairs: count the -start, skip the -done
        if f"{m.group('op')}-done(" in line:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("rtype"))
        per_bytes[op] += b
        per_count[op] += 1
        g = _GROUPS_RE.search(line)
        if g:
            group_sum[op] += g.group(1).count(",") + 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                group_sum[op] += int(gi.group("cols"))
            else:
                group_sum[op] += 2.0
    per_group = {op: group_sum[op] / per_count[op] for op in per_count}
    return CollectiveStats(dict(per_bytes), dict(per_count), per_group,
                           sum(per_bytes.values()))
