"""Serving driver: batched prefill + decode under a composable protection
scheme (the paper's §IV/§V applied to model serving; DESIGN.md §12).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --batch 4 --prompt-len 64 --gen 32 --scheme tmr-serial --inject-p-bit 1e-4

`--scheme` accepts ``off | ecc | tmr-serial | tmr-parallel | tmr-semi |
ecc+tmr[-<discipline>]`` (repro.reliability.parse_scheme grammar):

* ``ecc``       — protect the weights with the diagonal-parity word code,
                  corrupt, scrub once, serve the corrected store;
* ``tmr-*``     — serve three independently corrupted copies and vote the
                  generated token ids per-bit, under the selected paper
                  discipline (serial / parallel / semi-parallel);
* ``ecc+tmr-*`` — the joint long-term configuration: per-copy ECC scrub of
                  the stores, then TMR voting over the three generations.

The deprecated ``--tmr {off,serial,parallel,semi}`` flag remains as an
alias for ``--scheme tmr-*``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs
from ..faults import (FaultModel, RetentionDrift, StuckAtFaults,
                      TransientBitFlips)
from ..models import params as P
from ..models import transformer as T
from ..models.steps import make_decode_step, make_prefill_step
from ..reliability import (Compose, DiagParityEcc, Tmr, Unprotected,
                           parse_scheme)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-14b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--scheme", default=None,
                    help="protection scheme spec: off | ecc | tmr-serial | "
                         "tmr-parallel | tmr-semi | ecc+tmr[-<discipline>]")
    ap.add_argument("--tmr", default=None,
                    choices=["off", "serial", "parallel", "semi",
                             "semi_parallel"],
                    help="DEPRECATED alias for --scheme tmr-<discipline>")
    ap.add_argument("--inject-p-bit", type=float, default=0.0,
                    help="corrupt each weight bit of each copy w.p. p")
    ap.add_argument("--fault", default="bitflip",
                    choices=["bitflip", "stuckat", "drift"],
                    help="fault model driving the per-copy corruption "
                         "(repro.faults taxonomy; rate = --inject-p-bit)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.scheme is not None and args.tmr is not None:
        ap.error("--tmr is a deprecated alias for --scheme tmr-<discipline>;"
                 " pass only one of them")
    spec = args.scheme
    if spec is None:
        if args.tmr not in (None, "off"):
            print(f"[serve] NOTE: --tmr {args.tmr} is deprecated; use "
                  f"--scheme tmr-{args.tmr.replace('_', '-')}")
            spec = f"tmr-{args.tmr.replace('_', '-')}"
        else:
            spec = "off"
    scheme = parse_scheme(spec)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = P.materialize(key, T.model_specs(cfg))
    cache_len = args.prompt_len + args.gen

    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_emb"] = jax.random.normal(key, (args.batch, cfg.vis_tokens,
                                                   cfg.vis_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(key, (args.batch, args.prompt_len,
                                                   cfg.d_model), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    def run_copy(p):
        tok, logits, cache = prefill(p, batch)
        toks = [tok]
        for _ in range(args.gen - 1):
            tok, logits, cache = decode(p, tok, cache)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    fault: FaultModel = {
        "bitflip": TransientBitFlips(args.inject_p_bit),
        "stuckat": StuckAtFaults(args.inject_p_bit / 2,
                                 args.inject_p_bit / 2),
        "drift": RetentionDrift(args.inject_p_bit),
    }[args.fault]

    def corrupt(i: int):
        """Copy i's stored weights after an exposure interval."""
        if not args.inject_p_bit:
            return params
        return fault.corrupt(params, jax.random.fold_in(key, 100 + i))

    t0 = time.time()
    if isinstance(scheme, Unprotected):
        # honest baseline for scheme sweeps: the unprotected store takes
        # the same exposure as every protected scheme's copy 0
        out = run_copy(corrupt(0))
    elif isinstance(scheme, DiagParityEcc):
        # short-term discipline: scrub the corrupted store, serve corrected
        prot = scheme.protect(params)
        prot, report = scheme.scrub(scheme.adopt(corrupt(0), prot.redundancy))
        print(f"[serve] ecc scrub: corrected={int(report.corrected)} "
              f"uncorrectable={int(report.uncorrectable)}")
        out = run_copy(prot.payload)
    elif isinstance(scheme, Tmr):
        # three copies with independently injected storage corruption;
        # per-bit majority voting on the generated token ids.  On this
        # single-host driver all disciplines execute sequentially (same
        # voted bits, no 3x peak memory from stacking full copies); on a
        # real mesh parallel/semi-parallel shard the replica axis
        out = scheme.wrap(run_copy, sequential=True)(
            corrupt(0), corrupt(1), corrupt(2))
    elif isinstance(scheme, Compose):
        # the joint long-term configuration: per-copy ECC scrub, then TMR
        # voting over the three generations
        prot = scheme.ecc.protect(params)
        copies, counts = [], [0, 0]
        for i in range(3):
            fixed, rep = scheme.ecc.scrub(
                scheme.ecc.adopt(corrupt(i), prot.redundancy))
            counts[0] += int(rep.corrected)
            counts[1] += int(rep.uncorrectable)
            copies.append(fixed.payload)
        print(f"[serve] ecc scrub (3 copies): corrected={counts[0]} "
              f"uncorrectable={counts[1]}")
        out = scheme.tmr.wrap(run_copy, sequential=True)(*copies)
    else:
        raise ValueError(f"unhandled scheme {scheme!r}")
    dt = time.time() - t0

    ref = run_copy(params) if args.inject_p_bit else out
    agree = float((out == ref).mean())
    tok_s = args.batch * args.gen / dt
    print(f"[serve] {cfg.name} scheme={scheme.name} "
          f"p_bit={args.inject_p_bit:g}: {args.batch}x{args.gen} tokens "
          f"in {dt:.1f}s ({tok_s:.1f} tok/s), "
          f"agreement with clean run: {agree:.3f}")
    print(f"[serve] cost model ({scheme.name}): {scheme.overhead().describe()}")
    print("[serve] sample:", np.asarray(out[0, :16]).tolist())


if __name__ == "__main__":
    main()
