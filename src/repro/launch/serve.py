"""Serving driver: compiled batched generation under a composable
protection scheme (the paper's §IV/§V applied to model serving;
DESIGN.md §12/§13).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --batch 4 --prompt-len 64 --gen 32 --scheme tmr-parallel \
      --inject-p-bit 1e-4 --vote-every 8

Generation runs through `launch.engine.GenerationEngine`: prefill +
``lax.scan`` over decode steps, so the whole ``--gen``-token generation is
one jitted launch (``--engine loop`` keeps the interpreted per-token
reference path for comparison).  ``--scheme`` accepts ``off | ecc |
tmr-serial | tmr-parallel | tmr-semi | ecc+tmr[-<discipline>]``
(repro.reliability.parse_scheme grammar):

* ``ecc``       — protect the weights with the diagonal-parity word code,
                  corrupt, scrub once (fused launch), serve corrected;
* ``tmr-*``     — three independently corrupted copies stacked on a
                  leading copy axis; 'parallel'/'semi' vmap the generation
                  over it, 'serial' sequences it (lax.map), with per-bit
                  voting of the generated token ids — in-scan every
                  ``--vote-every`` steps, and always on the final
                  sequences;
* ``ecc+tmr-*`` — the joint long-term configuration: one fused ECC scrub
                  over all three copies, then TMR voting.

All scrub/vote counters stay on device during the timed region and are
fetched once after timing stops (no host syncs in the hot path).

Observability (DESIGN.md §15): ``--trace out.json`` records launch spans
as Chrome-trace JSON (load in Perfetto / chrome://tracing), ``--metrics
out.jsonl`` appends structured telemetry records, and ``--chunk N`` runs
chunk-compiled generation with per-chunk latency marks, reporting
TTFT/TPOT p50/p95/p99 tails — all without adding a single device->host
sync to the timed region.

Hardware cost projection (DESIGN.md §17): ``--mmpu-cost`` compiles the
serve's scheme + batch geometry into an mMPU event stream and reports
projected crossbar-cycles and switching energy per token alongside the
wall-clock numbers; ``--mmpu-events out.jsonl`` dumps the stream for
offline analysis (CI uploads it next to trace.json); ``--mmpu-device``
picks a DeviceSpec from configs.mmpu_paper.

Server mode (DESIGN.md §16): ``--server`` serves an open-loop Poisson
trace through the continuous-batching scheduler (paged ECC-protected KV
pool, chunk-boundary admission):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --server --rate 8 --requests 32 --slots 4 --scheme ecc+tmr \
      --inject-p-bit 1e-4 --trace trace.json

Arrivals are paced in real time and never wait for service; per-request
TTFT (queue wait included) and TPOT flow through LatencyTimeline, and the
report gives p50/p95/p99 tails plus goodput (useful tokens / wall time).
``--gen`` becomes the per-request generation cap, ``--chunk`` the decode
chunk between scheduling points (default 8), ``--prompt-len`` the single
admission bucket.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, list_archs
from ..faults import (FaultModel, RetentionDrift, StuckAtFaults,
                      TransientBitFlips)
from ..models import params as P
from ..models import transformer as T
from ..obs import LatencyTimeline, Tracer
from ..reliability import ArenaEcc, Compose, Tmr, Unprotected, \
    parse_scheme, scheme_choices, scheme_help
from .batching import BatchSpec, ContinuousBatcher, Request, poisson_trace
from .engine import GenerationEngine, fetch_telemetry
from .mesh import make_test_mesh


def _run_server(args, cfg, key, params, scheme, fault, mesh) -> None:
    """Continuous-batching server: open-loop Poisson load through the
    chunk-boundary scheduler over the paged ECC-protected KV pool."""
    chunk = args.chunk or 8
    spec = BatchSpec(slots=args.slots, page_tokens=args.page_tokens,
                     chunk=chunk, prompt_buckets=(args.prompt_len,),
                     gen_cap=args.gen)
    tracer = Tracer(enabled=bool(args.trace or args.metrics))
    b = ContinuousBatcher(cfg, scheme, spec, mesh=mesh,
                          scrub_every=args.scrub_every)
    if getattr(args, "adaptive_scrub", False) and b.ecc is not None:
        from ..runtime import AdaptiveScrub
        # prior sized for the POOL the controller actually scrubs
        b.adaptive = AdaptiveScrub.from_prior(
            args.inject_p_bit, b.pool.arena_spec.n_blocks,
            interval0=max(1, args.scrub_every or 32))
    with tracer.trace("prepare", scheme=scheme.name):
        prep = b.prepare(params, key=key,
                         fault=fault if args.inject_p_bit else None)
    trace = poisson_trace(args.requests, rate_rps=args.rate, spec=spec,
                          vocab=cfg.vocab, seed=args.seed)
    # compile the admit bucket and the tick program before the open-loop
    # clock starts — arrivals never wait for service, so a cold compile
    # would show up as a queue spike rather than honest latency
    warm = [Request(10**6 + i, t.prompt, min(2, t.gen))
            for i, t in enumerate(trace[:spec.slots])]
    with tracer.trace("warmup"):
        b.run(warm)

    t0 = time.time()
    with tracer.trace("serve", requests=args.requests, rate=args.rate,
                      scheme=scheme.name):
        results = b.run(trace, realtime=True)
    dt = time.time() - t0
    with tracer.trace("fetch_telemetry"):
        stats = fetch_telemetry({**prep, **b.telemetry()})

    useful = sum(len(r.tokens) for r in results)
    goodput = useful / dt
    ttft = np.asarray([r.ttft_s for r in results])
    tpot = np.asarray([s for r in results for s in r.tpot_samples])
    mesh_desc = "single" if mesh is None else \
        "x".join(f"{a}={n}" for a, n in b.engine.exec_mesh.shape.items())
    q = lambda a, p: float(np.percentile(a, p)) if a.size else float("nan")
    print(f"[serve] {cfg.name} server scheme={scheme.name} mesh={mesh_desc} "
          f"p_bit={args.inject_p_bit:g}: {args.requests} reqs @ "
          f"{args.rate:g} rps, slots={spec.slots} chunk={chunk}: "
          f"{useful} tokens in {dt:.1f}s (goodput {goodput:.1f} tok/s, "
          f"{b.ticks} ticks, {b.decode_slot_steps} slot-steps)")
    print(f"[serve] ttft p50={q(ttft, 50) * 1e3:.1f}ms "
          f"p95={q(ttft, 95) * 1e3:.1f}ms p99={q(ttft, 99) * 1e3:.1f}ms; "
          f"tpot p50={q(tpot, 50) * 1e3:.2f}ms p95={q(tpot, 95) * 1e3:.2f}ms "
          f"p99={q(tpot, 99) * 1e3:.2f}ms")
    if stats:
        parts = []
        if "ecc_corrected" in stats:
            parts.append(f"ecc corrected={int(stats['ecc_corrected'])} "
                         f"uncorrectable={int(stats['ecc_uncorrectable'])}")
        if "tmr_final_disagreements" in stats:
            parts.append(f"vote disagreements="
                         f"{int(stats['tmr_final_disagreements'])}")
        print(f"[serve] reliability (fetched after timing): "
              f"{'; '.join(parts) or 'n/a'}")
    if args.trace or args.metrics:
        record = {"kind": "server", "arch": cfg.name, "scheme": scheme.name,
                  "mesh": mesh_desc, "p_bit": args.inject_p_bit,
                  "rate_rps": args.rate, "requests": args.requests,
                  "slots": spec.slots, "chunk": chunk, "gen_cap": args.gen,
                  "goodput_tok_s": goodput, "ticks": b.ticks,
                  "decode_slot_steps": b.decode_slot_steps,
                  "ttft_p50_s": q(ttft, 50), "ttft_p95_s": q(ttft, 95),
                  "ttft_p99_s": q(ttft, 99),
                  "tpot_p50_s": q(tpot, 50), "tpot_p95_s": q(tpot, 95),
                  "tpot_p99_s": q(tpot, 99),
                  **{k: (np.asarray(v).sum().item()
                         if hasattr(v, "shape") else v)
                     for k, v in stats.items()}}
        tracer.metrics(record, kind="server")
        if args.trace:
            tracer.write_chrome(args.trace)
            print(f"[serve] chrome trace -> {args.trace} "
                  f"(load in Perfetto / chrome://tracing)")
        if args.metrics:
            tracer.write_jsonl(args.metrics)
            print(f"[serve] metrics jsonl -> {args.metrics}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-14b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--scheme", default="off",
                    metavar="|".join(scheme_choices()),
                    help="protection scheme spec, from the scheme registry"
                         " (reliability.register_scheme) — "
                         + scheme_help())
    ap.add_argument("--engine", default="scan", choices=["scan", "loop"],
                    help="scan: one compiled prefill+scan launch (default);"
                         " loop: interpreted per-token reference path")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="shard the engine over a DATAxMODEL device mesh "
                         "(e.g. 2x2; DESIGN.md §14).  TMR copy axes fold "
                         "onto data replica groups when data %% 3 == 0.  "
                         "On CPU force devices first: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--vote-every", type=int, default=0,
                    help="TMR/Compose: vote token ids across copies every k "
                         "decode steps inside the scan (0 = only at the end)")
    ap.add_argument("--vote-cache", action="store_true",
                    help="also vote the KV caches at in-scan vote points")
    ap.add_argument("--inject-p-bit", type=float, default=0.0,
                    help="corrupt each weight bit of each copy w.p. p")
    ap.add_argument("--fault", default="bitflip",
                    choices=["bitflip", "stuckat", "drift"],
                    help="fault model driving the per-copy corruption "
                         "(repro.faults taxonomy; rate = --inject-p-bit)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write launch spans as Chrome-trace JSON "
                         "(Perfetto / chrome://tracing loadable)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="append structured telemetry records as JSONL")
    ap.add_argument("--chunk", type=int, default=0,
                    help="generate in compiled N-token chunk launches with "
                         "per-chunk latency marks: reports TTFT/TPOT "
                         "p50/p95/p99 tails (0 = one scan launch, no "
                         "tails; bit-exact either way)")
    ap.add_argument("--server", action="store_true",
                    help="continuous-batching server mode: serve an "
                         "open-loop Poisson trace through the "
                         "chunk-boundary scheduler over the paged "
                         "ECC-protected KV pool (DESIGN.md §16); --gen is "
                         "the per-request cap, --chunk the decode chunk "
                         "(default 8), --prompt-len the admission bucket")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="server mode: Poisson arrival rate, requests/s")
    ap.add_argument("--requests", type=int, default=32,
                    help="server mode: number of requests in the trace")
    ap.add_argument("--scrub-every", type=int, default=0, metavar="TICKS",
                    help="server: fixed pool-scrub cadence in scheduler "
                         "ticks (0 = no periodic scrub)")
    ap.add_argument("--adaptive-scrub", action="store_true",
                    help="server: pay-as-you-fault scrub cadence — the "
                         "runtime.AdaptiveScrub controller moves the "
                         "interval from observed correction rates "
                         "(--scrub-every seeds interval0; overrides the "
                         "fixed cadence)")
    ap.add_argument("--slots", type=int, default=4,
                    help="server mode: fixed batch slots (bounds the "
                         "compile cache; empty slots are masked)")
    ap.add_argument("--mmpu-cost", action="store_true",
                    help="project this serve onto the mMPU cost model "
                         "(costmodel/, DESIGN.md §17): report cycles/token "
                         "and energy/token for the chosen scheme and stamp "
                         "mmpu_* gauges into the telemetry")
    ap.add_argument("--mmpu-events", default=None, metavar="PATH",
                    help="dump the compiled MmpuEvent stream as JSONL "
                         "(implies --mmpu-cost)")
    ap.add_argument("--mmpu-device", default="paper",
                    help="DeviceSpec name from configs.mmpu_paper "
                         "(default: paper)")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="server mode: tokens per KV pool page")
    args = ap.parse_args()

    if args.engine == "loop" and (args.vote_every or args.vote_cache):
        ap.error("--vote-every/--vote-cache only apply to the scan engine "
                 "(the loop reference votes final sequences only); drop "
                 "the flags or use --engine scan")
    scheme = parse_scheme(args.scheme)
    if args.vote_every or args.vote_cache:
        tmr = scheme if isinstance(scheme, Tmr) \
            else scheme.tmr if isinstance(scheme, Compose) else None
        if tmr is None:
            ap.error(f"--vote-every/--vote-cache need a copy axis to vote "
                     f"over; scheme {scheme.name!r} has none (use --scheme "
                     f"tmr-* or ecc+tmr[-*])")
        if tmr.discipline == "serial":
            ap.error("in-scan voting needs concurrently executing copies; "
                     "the serial discipline re-runs them sequentially (use "
                     "tmr-parallel/tmr-semi, or drop the vote flags)")
    if args.vote_cache and not args.vote_every:
        ap.error("--vote-cache needs --vote-every K (cache votes happen at "
                 "the in-scan vote points)")
    if args.chunk and args.engine == "loop":
        ap.error("--chunk requires the scan engine (the loop reference is "
                 "already per-token)")
    if args.chunk < 0:
        ap.error(f"--chunk must be >= 0, got {args.chunk}")
    if args.server:
        if args.engine == "loop":
            ap.error("--server runs the compiled scheduler; --engine loop "
                     "does not apply")
        if args.vote_every or args.vote_cache:
            ap.error("--server votes each finished request's tokens from "
                     "the completion fetch; in-scan vote flags do not "
                     "apply")
        if args.rate <= 0 or args.requests < 1 or args.slots < 1:
            ap.error("--server needs --rate > 0, --requests >= 1 and "
                     "--slots >= 1")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = P.materialize(key, T.model_specs(cfg))

    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_emb"] = jax.random.normal(
            key, (args.batch, cfg.vis_tokens, cfg.vis_dim), np.float32)
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), np.float32)

    fault: FaultModel = {
        "bitflip": TransientBitFlips(args.inject_p_bit),
        "stuckat": StuckAtFaults(args.inject_p_bit / 2,
                                 args.inject_p_bit / 2),
        "drift": RetentionDrift(args.inject_p_bit),
    }[args.fault]

    mesh = None
    if args.mesh:
        try:
            data, model = (int(t) for t in args.mesh.lower().split("x"))
        except ValueError:
            ap.error(f"--mesh expects DATAxMODEL (e.g. 2x2), got "
                     f"{args.mesh!r}")
        mesh = make_test_mesh(data, model)

    if args.server:
        _run_server(args, cfg, key, params, scheme, fault, mesh)
        return

    tracer = Tracer(enabled=bool(args.trace or args.metrics))
    cost_spec = None
    if args.mmpu_cost or args.mmpu_events:
        from ..configs.mmpu_paper import get_device
        cost_spec = get_device(args.mmpu_device)
    engine = GenerationEngine(cfg, scheme, gen=args.gen,
                              vote_every=args.vote_every,
                              vote_cache=args.vote_cache,
                              execution=args.engine, mesh=mesh,
                              cost_spec=cost_spec)
    with tracer.trace("prepare", scheme=scheme.name):
        store, prep = engine.prepare(
            params, key=key, fault=fault if args.inject_p_bit else None)
    # keep compile and prepare's async corrupt/scrub launches out of the
    # timed region: one untimed warmup generation, then drain the store
    with tracer.trace("warmup"):
        if args.chunk:
            jax.block_until_ready(
                engine.generate_chunked(store, batch, chunk=args.chunk)[0])
        else:
            jax.block_until_ready(engine.generate(store, batch)[0])
        store = jax.block_until_ready(store)

    # timed region: no host syncs — telemetry stays on device until after
    timeline = None
    t0 = time.time()
    with tracer.trace("generate", scheme=scheme.name, gen=args.gen,
                      chunk=args.chunk):
        if args.chunk:
            out, telem, timeline = engine.generate_chunked(
                store, batch, chunk=args.chunk, tracer=tracer)
        else:
            out, telem = engine.generate(store, batch)
        out = jax.block_until_ready(out)
    dt = time.time() - t0

    with tracer.trace("fetch_telemetry"):
        stats = fetch_telemetry({**prep, **telem})   # the single fetch
    # off/ecc stores are plain params pytrees, so the timed engine's
    # compiled single-copy program serves the clean reference without a
    # recompile; copy-axis schemes need a fresh single-copy engine
    clean = engine if isinstance(scheme, (Unprotected, ArenaEcc)) \
        else GenerationEngine(cfg, gen=args.gen, execution=args.engine)
    ref = clean.generate(params, batch)[0] if args.inject_p_bit else out
    agree = float(np.asarray(out == ref).mean())
    tok_s = args.batch * args.gen / dt
    mesh_desc = "single" if mesh is None else \
        "x".join(f"{a}={n}" for a, n in engine.exec_mesh.shape.items())
    print(f"[serve] {cfg.name} scheme={scheme.name} engine={args.engine} "
          f"mesh={mesh_desc} "
          f"p_bit={args.inject_p_bit:g}: {args.batch}x{args.gen} tokens "
          f"in {dt:.1f}s ({tok_s:.1f} tok/s), "
          f"agreement with clean run: {agree:.3f}")
    if stats:
        parts = []
        if "ecc_corrected" in stats:
            parts.append(f"ecc corrected={int(stats['ecc_corrected'])} "
                         f"uncorrectable={int(stats['ecc_uncorrectable'])}")
        if "tmr_final_disagreements" in stats:
            parts.append("vote disagreements: final="
                         f"{int(stats['tmr_final_disagreements'])}")
        if "tmr_step_disagreements" in stats:
            steps = np.asarray(stats["tmr_step_disagreements"])
            parts.append(f"per-step={steps.sum()} over {steps.size} steps")
        print(f"[serve] reliability (fetched after timing): "
              f"{'; '.join(parts)}")
    print(f"[serve] cost model ({scheme.name}): {scheme.overhead().describe()}")
    if cost_spec is not None:
        stream, cost = engine.mmpu_projection(args.batch)
        print(f"[serve] mMPU projection ({cost_spec.name}): "
              f"{cost.describe()}")
        if args.mmpu_events:
            from ..costmodel import dump_jsonl
            n = dump_jsonl(stream, args.mmpu_events)
            print(f"[serve] mmpu event stream -> {args.mmpu_events} "
                  f"({n} events)")
    if timeline is not None:
        lat = timeline.summary()
        print(f"[serve] latency tails (chunk={args.chunk}): "
              f"ttft={lat['ttft_s'] * 1e3:.1f}ms "
              f"tpot p50={lat.get('tpot_p50', float('nan')) * 1e3:.2f}ms "
              f"p95={lat.get('tpot_p95', float('nan')) * 1e3:.2f}ms "
              f"p99={lat.get('tpot_p99', float('nan')) * 1e3:.2f}ms")
    if args.trace or args.metrics:
        record = {"kind": "serve", "arch": cfg.name, "scheme": scheme.name,
                  "engine": args.engine, "mesh": mesh_desc,
                  "p_bit": args.inject_p_bit, "batch": args.batch,
                  "gen": args.gen, "chunk": args.chunk, "tok_s": tok_s,
                  "agreement": agree,
                  **{k: (np.asarray(v).sum().item()
                         if hasattr(v, "shape") else v)
                     for k, v in stats.items()}}
        if timeline is not None:
            record.update({k: float(v)
                           for k, v in timeline.summary().items()})
        tracer.metrics(record, kind="serve")
        if args.trace:
            tracer.write_chrome(args.trace)
            print(f"[serve] chrome trace -> {args.trace} "
                  f"(load in Perfetto / chrome://tracing)")
        if args.metrics:
            tracer.write_jsonl(args.metrics)
            print(f"[serve] metrics jsonl -> {args.metrics}")
    print("[serve] sample:", np.asarray(out[0, :16]).tolist())


if __name__ == "__main__":
    main()
