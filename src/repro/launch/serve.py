"""Serving driver: batched prefill + decode with optional TMR voting and
soft-error injection (the paper's §V applied to model serving).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --batch 4 --prompt-len 64 --gen 32 --tmr serial --inject-p-bit 1e-4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs
from ..faults import (FaultModel, RetentionDrift, StuckAtFaults,
                      TransientBitFlips)
from ..kernels.tmr_vote import vote
from ..models import params as P
from ..models import transformer as T
from ..models.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-14b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--tmr", default="off", choices=["off", "serial", "parallel"])
    ap.add_argument("--inject-p-bit", type=float, default=0.0,
                    help="corrupt each weight bit of each TMR copy w.p. p")
    ap.add_argument("--fault", default="bitflip",
                    choices=["bitflip", "stuckat", "drift"],
                    help="fault model driving the per-copy corruption "
                         "(repro.faults taxonomy; rate = --inject-p-bit)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = P.materialize(key, T.model_specs(cfg))
    cache_len = args.prompt_len + args.gen

    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_emb"] = jax.random.normal(key, (args.batch, cfg.vis_tokens,
                                                   cfg.vis_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(key, (args.batch, args.prompt_len,
                                                   cfg.d_model), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    def run_copy(p):
        tok, logits, cache = prefill(p, batch)
        toks = [tok]
        for _ in range(args.gen - 1):
            tok, logits, cache = decode(p, tok, cache)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    t0 = time.time()
    if args.tmr == "off":
        out = run_copy(params)
    else:
        # three copies with independently injected storage corruption; per-bit
        # majority voting on the generated token ids through the Pallas
        # tmr_vote kernel (serial: sequential; parallel: 3 replica groups on
        # a real mesh — same result here)
        fault: FaultModel = {
            "bitflip": TransientBitFlips(args.inject_p_bit),
            "stuckat": StuckAtFaults(args.inject_p_bit / 2,
                                     args.inject_p_bit / 2),
            "drift": RetentionDrift(args.inject_p_bit),
        }[args.fault]
        copies = []
        for i in range(3):
            p = params
            if args.inject_p_bit:
                p = fault.corrupt(params, jax.random.fold_in(key, 100 + i))
            copies.append(run_copy(p))
        out = vote(*copies)
    dt = time.time() - t0

    ref = run_copy(params) if (args.tmr != "off" and args.inject_p_bit) else out
    agree = float((out == ref).mean())
    tok_s = args.batch * args.gen / dt
    print(f"[serve] {cfg.name} tmr={args.tmr} p_bit={args.inject_p_bit:g}: "
          f"{args.batch}x{args.gen} tokens in {dt:.1f}s ({tok_s:.1f} tok/s), "
          f"agreement with clean run: {agree:.3f}")
    print("[serve] sample:", np.asarray(out[0, :16]).tolist())


if __name__ == "__main__":
    main()
