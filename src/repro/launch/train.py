"""Training driver: composes configs, data, optimizer, checkpointing,
fault-tolerance monitoring and the reliability layer into a runnable loop.

On this CPU container it runs reduced (smoke) configs end-to-end; on a real
cluster the same driver runs the full config against the production mesh
(--mesh data,model sizes).  Examples:

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 50 --batch 8 --seq 256 --ecc-scrub-every 10
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config, get_train_policy, list_archs
from ..data.synthetic import SyntheticLM
from ..models import params as P
from ..models import transformer as T
from ..models.steps import init_train_state, make_train_step
from ..obs import NULL_TRACER, Tracer
from ..optim import AdamWConfig
from ..pshard import DEFAULT_RULES, use_mesh_and_rules
from ..reliability import SCHEME_CHOICES, Unprotected, parse_scheme
from ..runtime import LoopConfig, TrainLoop


def build(args, tracer: Tracer = NULL_TRACER):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = cfg.replace(compute_dtype=args.compute_dtype)

    key = jax.random.PRNGKey(args.seed)
    specs = T.model_specs(cfg)
    params = P.materialize(key, specs, jnp.dtype(args.param_dtype))
    n_params = sum(x.size for x in jax.tree.leaves(params))

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    train_step = jax.jit(make_train_step(
        cfg, opt_cfg, grad_compression=args.grad_compression,
        microbatches=args.microbatches))
    state = init_train_state(params, grad_compression=args.grad_compression)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       batch_per_rank=args.batch, seed=args.seed)

    def batch_at(step):
        b = {"tokens": jnp.asarray(data.batch_at(step))}
        if cfg.family == "vlm":
            b["vis_emb"] = jax.random.normal(
                jax.random.fold_in(key, step), (args.batch, cfg.vis_tokens, cfg.vis_dim),
                jnp.float32)
        if cfg.family == "encdec":
            b["enc_emb"] = jax.random.normal(
                jax.random.fold_in(key, step), (args.batch, args.seq, cfg.d_model),
                jnp.float32)
        return b

    ckpt = Checkpointer(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    loop_cfg = LoopConfig(total_steps=args.steps,
                          checkpoint_every=args.checkpoint_every,
                          scrub_every=args.ecc_scrub_every,
                          log_every=args.log_every,
                          inject_p_bit=args.inject_p_bit,
                          scheme=parse_scheme(args.scheme))
    loop = TrainLoop(train_step, state, batch_at, loop_cfg, ckpt=ckpt,
                     tracer=tracer)
    if args.ecc_scrub_every and not isinstance(loop_cfg.scheme, Unprotected):
        loop.attach_scheme()
    return cfg, loop, n_params


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-130m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--ecc-scrub-every", type=int, default=0)
    ap.add_argument("--scheme", default="ecc",
                    help="protection scheme armed when --ecc-scrub-every > 0 "
                         "(repro.reliability.parse_scheme grammar, e.g. "
                         + " | ".join(SCHEME_CHOICES)
                         + " | ecc+tmr-semi; DESIGN.md §12)")
    ap.add_argument("--inject-p-bit", type=float, default=0.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write loop spans (train_step/scrub/checkpoint/"
                         "eval) as Chrome-trace JSON (DESIGN.md §15)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="append heartbeat/scrub records as JSONL")
    args = ap.parse_args()

    tracer = Tracer(enabled=bool(args.trace or args.metrics))
    cfg, loop, n_params = build(args, tracer=tracer)
    print(f"[train] {cfg.name} ({cfg.family}) params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")
    if args.resume:
        loop.restore()
    t0 = time.time()
    summary = loop.run()
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"[train] done: {summary} | {dt:.1f}s, {tok_s:,.0f} tok/s")
    if loop.scrub_reports:
        tot = sum(int(r.corrected) for _, r in loop.scrub_reports)
        print(f"[reliability] scrubs={len(loop.scrub_reports)} corrected_bits={tot}")
    if args.trace:
        tracer.write_chrome(args.trace)
        print(f"[train] chrome trace -> {args.trace} "
              f"(load in Perfetto / chrome://tracing)")
    if args.metrics:
        tracer.metrics({"final_step": summary["final_step"],
                        "tok_s": tok_s, **summary["monitor"]},
                       kind="train_summary")
        tracer.write_jsonl(args.metrics)
        print(f"[train] metrics jsonl -> {args.metrics}")


if __name__ == "__main__":
    main()
