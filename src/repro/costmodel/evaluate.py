"""Cost evaluator: fold MmpuEvent streams into cycles / energy / per-token.

The fold is a weighted dot product over the packed event arrays:

* latency cycles    = sum(count * cycles[kind] * weight)
* occupancy cycles  = sum(count * cycles[kind] * xbars * weight)
* energy (pJ)       = sum(cells * pJ[kind]     * weight)

``cycles_per_token`` reports *occupancy* — device-normalized crossbar-
cycles — so a discipline that runs 1x as long on 3x the arrays
(tmr-parallel) costs exactly what one that runs 3x as long on 1x does
(tmr-serial): that matches ``CostReport.latency_x * area_x /
throughput_x`` from ``Scheme.overhead()`` and is the paper's
reliability-vs-throughput axis.  Wall-clock projections use latency.

:func:`evaluate_grid` vectorizes the fold with ``jax.vmap`` over a
padded scheme-grid stack so ``sweep_schemes``-style frontiers price a
whole grid in one device call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .device import DeviceSpec
from .events import EventArrays, MmpuEvent, stack_streams

__all__ = ["MmpuCost", "fold", "fold_arrays", "evaluate_grid",
           "project_macs"]


@dataclasses.dataclass(frozen=True)
class MmpuCost:
    """Folded cost of one event stream (per `tokens` emitted tokens)."""
    latency_cycles: float     # critical-path device cycles
    occupancy_cycles: float   # crossbar-cycles (latency x arrays occupied)
    energy_pj: float
    tokens: float
    clock_hz: float
    n_events: int

    @property
    def cycles_per_token(self) -> float:
        return self.occupancy_cycles / self.tokens

    @property
    def energy_pj_per_token(self) -> float:
        return self.energy_pj / self.tokens

    @property
    def latency_s(self) -> float:
        return self.latency_cycles / self.clock_hz

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / max(self.latency_s, 1e-30)

    def describe(self) -> str:
        return (f"cycles/token={self.cycles_per_token:.4g} "
                f"energy/token={self.energy_pj_per_token:.4g}pJ "
                f"latency={self.latency_s * 1e3:.4g}ms "
                f"({self.n_events} events)")


def _fold_terms(kind, count, cells, xbars, weight, cycle_vec, energy_vec):
    """The jit/vmap-safe core: three weighted dots over packed arrays."""
    cyc = cycle_vec[kind] * count * weight
    return (jnp.sum(cyc),
            jnp.sum(cyc * xbars),
            jnp.sum(energy_vec[kind] * cells * weight))


_fold_jit = jax.jit(_fold_terms)


def fold_arrays(arrays: EventArrays, spec: DeviceSpec, *,
                tokens: float = 1.0) -> MmpuCost:
    lat, occ, pj = _fold_jit(
        jnp.asarray(arrays.kind), jnp.asarray(arrays.count),
        jnp.asarray(arrays.cells), jnp.asarray(arrays.xbars),
        jnp.asarray(arrays.weight),
        jnp.asarray(spec.cycle_vector()), jnp.asarray(spec.energy_vector()))
    return MmpuCost(latency_cycles=float(lat), occupancy_cycles=float(occ),
                    energy_pj=float(pj), tokens=float(tokens),
                    clock_hz=spec.clock_hz, n_events=len(arrays))


def fold(events: Sequence[MmpuEvent], spec: DeviceSpec, *,
         tokens: float = 1.0) -> MmpuCost:
    """Fold a plain event stream (order-independent by construction)."""
    cost = fold_arrays(EventArrays.from_events(tuple(events)), spec,
                       tokens=tokens)
    return dataclasses.replace(cost, n_events=len(tuple(events)))


def evaluate_grid(schemes: Iterable, profile, spec: DeviceSpec
                  ) -> Dict[str, MmpuCost]:
    """Price every scheme's step stream with ONE vmapped fold.

    Streams are ragged, so they are zero-padded to a common width
    (padding events have count=cells=0 and contribute nothing); the
    batched fold runs as a single device call over the (S, N) stack.
    """
    from .compile import lower_step
    schemes = list(schemes)
    streams = [lower_step(s, profile, spec) for s in schemes]
    stacked = stack_streams(streams)
    batch = {f: jnp.asarray(np.stack([getattr(a, f) for a in stacked]))
             for f in ("kind", "count", "cells", "xbars", "weight")}
    lat, occ, pj = jax.vmap(
        _fold_terms, in_axes=(0, 0, 0, 0, 0, None, None))(
        batch["kind"], batch["count"], batch["cells"], batch["xbars"],
        batch["weight"], jnp.asarray(spec.cycle_vector()),
        jnp.asarray(spec.energy_vector()))
    out: Dict[str, MmpuCost] = {}
    for i, (s, stream) in enumerate(zip(schemes, streams)):
        out[s.name] = MmpuCost(
            latency_cycles=float(lat[i]), occupancy_cycles=float(occ[i]),
            energy_pj=float(pj[i]), tokens=float(profile.tokens),
            clock_hz=spec.clock_hz, n_events=len(stream))
    return out


def project_macs(macs: int, weight_words: int, spec: DeviceSpec, *,
                 tokens: int = 1, mac_bits: int = 8) -> MmpuCost:
    """Redundancy-free projection for roofline-style consumers: price a
    step of `macs` total MACs over `weight_words` resident words."""
    from .compile import StepProfile, base_step_events
    profile = StepProfile(weight_words=max(1, weight_words),
                          macs_per_token=max(1, macs), tokens=1,
                          mac_bits=mac_bits)
    cost = fold(base_step_events(profile, spec), spec, tokens=tokens)
    return cost
