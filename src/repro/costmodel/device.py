"""mMPU device timing/energy spec.

A :class:`DeviceSpec` pins the per-primitive cycle latencies and
per-cell switching energies of one memristive crossbar configuration.
The compiler (`costmodel.compile`) never touches these numbers — it
emits *counts* of primitive issues and touched cells — so the same
event stream can be re-priced under any device by swapping the spec.

Primitive kinds (`EVENT_KINDS`) follow the MAGIC/FELIX gate set the
repo's netlist layer already uses (`core/multpim.py`,
`core/scheduler.py`):

* ``init``  — output-cell initialization to RON before a stateful gate
  (MAGIC requires it; one cycle, Talati et al., TVLSI 2016).
* ``nor`` / ``not`` — MAGIC NOR / 1-input NOR, one cycle each.
* ``min3`` — FELIX 3-input minority, one cycle (Gupta et al.,
  ICCAD 2018); the majority vote used by TMR is Min3 + NOT.
* ``xor``  — FELIX 2-cycle in-memory XOR, the ECC syndrome primitive
  (Leitersdorf et al., arXiv:2105.04212 price their diagonal-parity
  check in exactly these).
* ``read`` / ``write`` — peripheral row read / row write.

All primitives are row-parallel: one issue applies the gate across up
to ``rows`` wordlines at once, each word ``cols``-bits wide, so a
level of W gates costs ``ceil(W / rows)`` issues regardless of W
(the paper's "single-row-operation" cost model, §III).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

# Order is load-bearing: events are encoded by index for the packed
# array form (events.EventArrays) and the JAX fold.
EVENT_KINDS: Tuple[str, ...] = (
    "init", "nor", "not", "min3", "xor", "read", "write")
KIND_INDEX: Dict[str, int] = {k: i for i, k in enumerate(EVENT_KINDS)}


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Timing/energy model of one mMPU crossbar array.

    Latencies are device cycles per primitive *issue*; energies are
    picojoules per touched *cell* (bit).  Defaults live in
    ``repro.configs.mmpu_paper`` — construct through
    :func:`repro.configs.mmpu_paper.get_device` or override fields
    with :meth:`replace`.
    """
    name: str
    rows: int            # wordlines per crossbar == row-parallel op width
    cols: int            # bitlines per crossbar == bits per word-row
    n_crossbars: int     # arrays usable in parallel by one workload
    clock_hz: float      # device cycle rate

    # -- cycles per primitive issue ------------------------------------
    init_cycles: int = 1
    nor_cycles: int = 1
    not_cycles: int = 1
    min3_cycles: int = 1
    xor_cycles: int = 2          # FELIX XOR = 2 stateful cycles
    read_cycles: int = 1
    write_cycles: int = 1

    # -- picojoules per touched cell -----------------------------------
    init_energy_pj: float = 0.0010
    nor_energy_pj: float = 0.0064
    not_energy_pj: float = 0.0032
    min3_energy_pj: float = 0.0096
    xor_energy_pj: float = 0.0128
    read_energy_pj: float = 0.0005
    write_energy_pj: float = 0.0250

    def __post_init__(self):
        if self.rows <= 0 or self.cols <= 0 or self.n_crossbars <= 0:
            raise ValueError(f"DeviceSpec dimensions must be positive: {self}")
        if self.clock_hz <= 0:
            raise ValueError("DeviceSpec.clock_hz must be positive")

    # -- lookups -------------------------------------------------------
    def cycles_for(self, kind: str) -> int:
        return getattr(self, f"{kind}_cycles")

    def energy_pj_for(self, kind: str) -> float:
        return getattr(self, f"{kind}_energy_pj")

    def cycle_vector(self) -> Tuple[float, ...]:
        """Per-kind cycle costs ordered by EVENT_KINDS (for array folds)."""
        return tuple(float(getattr(self, f"{k}_cycles"))
                     for k in EVENT_KINDS)

    def energy_vector(self) -> Tuple[float, ...]:
        """Per-kind pJ/cell ordered by EVENT_KINDS (for array folds)."""
        return tuple(float(getattr(self, f"{k}_energy_pj"))
                     for k in EVENT_KINDS)

    # -- geometry helpers ----------------------------------------------
    def row_issues(self, width: int) -> int:
        """Sequential issues to apply one row-parallel op to `width` rows."""
        return max(1, math.ceil(width / self.rows)) if width > 0 else 0

    def seconds(self, cycles: float) -> float:
        return float(cycles) / self.clock_hz

    def replace(self, **overrides) -> "DeviceSpec":
        return dataclasses.replace(self, **overrides)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def spec_from_dict(d: dict) -> DeviceSpec:
    return DeviceSpec(**d)
