"""Trace-driven mMPU cost model (DESIGN.md §17).

Compiles the repo's two workload IRs — levelized netlist schedules and
per-`Scheme` generation/train steps — into typed :class:`MmpuEvent`
streams, then folds them into MAGIC/FELIX cycle counts, switching
energy, and cycles/energy per token under a :class:`DeviceSpec`.

    from repro import costmodel
    from repro.configs.mmpu_paper import get_device

    spec = get_device("paper")
    profile = costmodel.StepProfile.from_model_config(cfg, batch=8)
    costs = costmodel.evaluate_grid(standard_grid(), profile, spec)
"""
from .device import DeviceSpec, EVENT_KINDS, KIND_INDEX, spec_from_dict
from .events import (EventArrays, MmpuEvent, dump_jsonl, load_jsonl,
                     scale_stream, stack_streams)
from .compile import (StepProfile, base_step_events, ecc_events,
                      lower_schedule, lower_step, mac_kernel_events,
                      secded_events, tmr_transform, vote_events)
from .evaluate import MmpuCost, evaluate_grid, fold, fold_arrays, project_macs

__all__ = [
    "DeviceSpec", "EVENT_KINDS", "KIND_INDEX", "spec_from_dict",
    "MmpuEvent", "EventArrays", "dump_jsonl", "load_jsonl", "scale_stream",
    "stack_streams",
    "StepProfile", "lower_schedule", "lower_step", "base_step_events",
    "ecc_events", "secded_events", "tmr_transform", "vote_events",
    "mac_kernel_events",
    "MmpuCost", "fold", "fold_arrays", "evaluate_grid", "project_macs",
]
