"""Typed mMPU event streams: the IR between compiler and evaluator.

An :class:`MmpuEvent` is one *bundle* of identical row-parallel
primitive issues:

* ``kind``   — primitive name from ``device.EVENT_KINDS``;
* ``count``  — sequential issues (multiplied by the spec's per-kind
  cycle latency to get device cycles);
* ``cells``  — total cells (bits) touched across all issues
  (multiplied by the spec's per-kind pJ/cell to get energy);
* ``xbars``  — crossbars concurrently occupied while the bundle runs
  (latency x xbars = occupancy, the device-normalized cost used for
  cycles/token — a scheme that runs 1x as long on 3x the arrays costs
  the mMPU exactly as much as one that runs 3x as long on 1x);
* ``weight`` — amortization factor: periodic work (scrub-interval ECC
  checks, TMR store votes) carries ``weight=1/interval`` so per-step
  streams stay integral while the fold charges the amortized share;
* ``tag``    — provenance string (``"netlist.level3"``, ``"ecc.syndrome"``,
  ``"tmr.vote"``) for offline analysis of JSONL dumps.

Streams are plain tuples of events — deterministic, order-preserving,
trivially JSONL-serializable — plus a packed struct-of-arrays form
(:class:`EventArrays`) the JAX evaluator folds over.
"""
from __future__ import annotations

import dataclasses
import json
from typing import IO, Iterable, List, Sequence, Tuple, Union

import numpy as np

from .device import EVENT_KINDS, KIND_INDEX


@dataclasses.dataclass(frozen=True)
class MmpuEvent:
    kind: str
    count: int
    cells: int
    xbars: int = 1
    weight: float = 1.0
    tag: str = ""

    def __post_init__(self):
        if self.kind not in KIND_INDEX:
            raise ValueError(
                f"unknown event kind {self.kind!r}; expected one of "
                f"{EVENT_KINDS}")
        if self.count < 0 or self.cells < 0 or self.xbars < 1:
            raise ValueError(f"malformed event: {self}")
        if self.weight <= 0:
            raise ValueError(f"event weight must be positive: {self}")

    def scaled(self, count_x: float = 1, cells_x: float = 1,
               xbars_x: int = 1, weight_x: float = 1.0,
               tag: str | None = None) -> "MmpuEvent":
        """A copy with multiplied fields (counts round up, never to 0)."""
        def _up(v, x):
            return int(np.ceil(v * x)) if v else 0
        return MmpuEvent(
            kind=self.kind,
            count=_up(self.count, count_x),
            cells=_up(self.cells, cells_x),
            xbars=self.xbars * xbars_x,
            weight=self.weight * weight_x,
            tag=self.tag if tag is None else tag)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


EventStream = Tuple[MmpuEvent, ...]


def scale_stream(events: Iterable[MmpuEvent], repeats: float,
                 tag: str | None = None) -> EventStream:
    """Repeat a whole stream `repeats` times (e.g. steps per generation)."""
    return tuple(e.scaled(count_x=repeats, cells_x=repeats, tag=tag)
                 for e in events)


# ---------------------------------------------------------------- JSONL

def dump_jsonl(events: Iterable[MmpuEvent],
               fp: Union[str, IO[str]]) -> int:
    """Write one JSON object per event; returns the event count."""
    own = isinstance(fp, (str, bytes))
    f = open(fp, "w") if own else fp
    n = 0
    try:
        for e in events:
            f.write(json.dumps(e.to_dict(), sort_keys=True) + "\n")
            n += 1
    finally:
        if own:
            f.close()
    return n


def load_jsonl(fp: Union[str, IO[str]]) -> EventStream:
    own = isinstance(fp, (str, bytes))
    f = open(fp) if own else fp
    try:
        return tuple(MmpuEvent(**json.loads(line))
                     for line in f if line.strip())
    finally:
        if own:
            f.close()


# ------------------------------------------------------- packed arrays

@dataclasses.dataclass(frozen=True)
class EventArrays:
    """Struct-of-arrays event stream for vectorized folds.

    Padding rows (for stacking ragged scheme grids) use count=cells=0,
    which contribute exactly nothing to any fold.
    """
    kind: np.ndarray     # int32 (N,), index into EVENT_KINDS
    count: np.ndarray    # float64 (N,)
    cells: np.ndarray    # float64 (N,)
    xbars: np.ndarray    # float64 (N,)
    weight: np.ndarray   # float64 (N,)

    def __len__(self) -> int:
        return int(self.kind.shape[0])

    @classmethod
    def from_events(cls, events: Sequence[MmpuEvent],
                    pad_to: int | None = None) -> "EventArrays":
        n = len(events)
        width = n if pad_to is None else max(pad_to, n)
        kind = np.zeros(width, np.int32)
        count, cells = np.zeros(width), np.zeros(width)
        xbars, weight = np.ones(width), np.ones(width)
        for i, e in enumerate(events):
            kind[i] = KIND_INDEX[e.kind]
            count[i] = e.count
            cells[i] = e.cells
            xbars[i] = e.xbars
            weight[i] = e.weight
        return cls(kind, count, cells, xbars, weight)


def stack_streams(streams: Sequence[Sequence[MmpuEvent]]) -> List[EventArrays]:
    """Pad a ragged list of streams to a common length for stacking/vmap."""
    width = max((len(s) for s in streams), default=0)
    return [EventArrays.from_events(tuple(s), pad_to=width) for s in streams]
