"""Event-stream compiler: lower the repo's two workload IRs to MmpuEvents.

Two entry points, one per IR:

* :func:`lower_schedule` — a levelized netlist ``Schedule``
  (core/scheduler.py) becomes one init+min3 bundle per level, each
  width-capped by the crossbar: level l with ``widths[l]`` gates costs
  ``ceil(widths[l] / spec.rows)`` row-parallel issues (HIPE-MAGIC's
  technology mapping, arXiv:2006.03269).  Trials beyond the crossbar's
  ``cols`` bitlines multiply the issue count, not the cells-per-issue.

* :func:`lower_step` — one generation/train step under a reliability
  ``Scheme`` becomes weight reads + MAC kernel cycles (the in-memory
  fixed-point multiplier netlist, re-used *as its own cost source* via
  ``lower_schedule``) + the scheme's redundancy traffic, attached by
  ``Scheme.cost_events``: diagonal-parity encode/syndrome/correct
  (Leitersdorf et al., arXiv:2105.04212), TMR 3x execution + Min3+NOT
  vote per discipline, all periodic work amortized by
  ``weight = 1/scrub_interval``.

Everything here is host-side integer arithmetic over static shapes —
no jax arrays — so streams are deterministic, hashable inputs for the
JAX evaluator and cheap enough to build inside a serving engine
(`launch/engine.py` builds one stream per batch geometry, never per
token).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Sequence, Tuple

from .device import DeviceSpec
from .events import EventStream, MmpuEvent

__all__ = ["lower_schedule", "mac_kernel_events", "StepProfile",
           "base_step_events", "lower_step", "ecc_events", "tmr_transform",
           "vote_events"]


# ------------------------------------------------- netlist schedule path

def lower_schedule(sch, spec: DeviceSpec, *, trials: int = 1,
                   n_outputs: int = 0, load_inputs: bool = True,
                   tag: str = "netlist") -> EventStream:
    """Lower a levelized ``Schedule`` into per-level row-parallel events.

    Each MAGIC/FELIX gate needs its output cell initialized (``init``)
    then the ``min3`` evaluation; both are row-parallel, so a level of W
    gates costs ``ceil(W / spec.rows)`` issues of each.  ``trials``
    independent input vectors occupy one column each; more than
    ``spec.cols`` trials wrap into extra column rounds (more issues,
    same per-issue width).
    """
    if trials < 1:
        raise ValueError(f"trials must be >= 1, got {trials}")
    col_rounds = math.ceil(trials / spec.cols)
    events: List[MmpuEvent] = []
    n_inputs = sch.base - 2          # remap rows [2, base) are the inputs
    if load_inputs and n_inputs > 0:
        events.append(MmpuEvent(
            kind="write", count=spec.row_issues(n_inputs) * col_rounds,
            cells=n_inputs * trials, tag=f"{tag}.load"))
    level_issues = sch.issue_counts(spec.rows)
    for lvl, w in enumerate(int(w) for w in sch.widths):
        if w <= 0:
            continue
        issues = int(level_issues[lvl]) * col_rounds
        cells = w * trials
        events.append(MmpuEvent(kind="init", count=issues, cells=cells,
                                tag=f"{tag}.level{lvl}"))
        events.append(MmpuEvent(kind="min3", count=issues, cells=cells,
                                tag=f"{tag}.level{lvl}"))
    if n_outputs > 0:
        events.append(MmpuEvent(
            kind="read", count=spec.row_issues(n_outputs) * col_rounds,
            cells=n_outputs * trials, tag=f"{tag}.readout"))
    return tuple(events)


@functools.lru_cache(maxsize=None)
def mac_kernel_events(n_bits: int, spec: DeviceSpec) -> EventStream:
    """Cost of ONE crossbar-wide MAC round: the n_bits fixed-point
    multiplier netlist executed column-parallel, `spec.cols` independent
    multiplications at once (one per bitline)."""
    from ..core.multpim import multiplier_netlist
    from ..core.scheduler import schedule
    sch = schedule(multiplier_netlist(n_bits))
    return lower_schedule(sch, spec, trials=spec.cols,
                          n_outputs=2 * n_bits, tag=f"mac{n_bits}")


# ------------------------------------------------------ model step path

@dataclasses.dataclass(frozen=True)
class StepProfile:
    """Static shape summary of one generation/train step.

    The compiler works from this — not from live arrays — so streams
    can be built for dryrun configs, abstract sweeps, or a serving
    engine's batch geometry alike.
    """
    weight_words: int          # packed arena words holding the weights
    macs_per_token: int        # multiply-accumulates per emitted token
    tokens: int = 1            # tokens emitted per step (batch size)
    mac_bits: int = 8          # fixed-point width of the in-memory MAC
    scrub_interval: int = 32   # steps between scrub/store-vote passes
    out_bits_per_token: int = 32

    def __post_init__(self):
        if min(self.weight_words, self.macs_per_token, self.tokens,
               self.mac_bits, self.scrub_interval) < 1:
            raise ValueError(f"StepProfile fields must be >= 1: {self}")

    @property
    def n_blocks(self) -> int:
        from ..core import arena
        return math.ceil(self.weight_words / arena.BLOCK)

    @classmethod
    def from_model_config(cls, cfg, *, batch: int = 1, mac_bits: int = 8,
                          scrub_interval: int = 32,
                          dtype="float32") -> "StepProfile":
        """Analytic profile from a ModelConfig: arena words via the same
        block-padded packing `core.arena` applies to real params, MACs
        as one multiply per matrix-weight entry per token."""
        import jax
        from ..core import arena
        from ..models.params import Spec
        from ..models.transformer import model_specs
        specs = jax.tree.leaves(model_specs(cfg),
                                is_leaf=lambda x: isinstance(x, Spec))
        abstract = [jax.ShapeDtypeStruct(s.shape, s.resolved_dtype(dtype))
                    for s in specs]
        words = arena.arena_spec(abstract).n_words
        macs = sum(math.prod(s.shape) for s in specs if len(s.shape) >= 2)
        return cls(weight_words=words, macs_per_token=max(1, macs),
                   tokens=batch, mac_bits=mac_bits,
                   scrub_interval=scrub_interval)


def base_step_events(profile: StepProfile, spec: DeviceSpec) -> EventStream:
    """Redundancy-free cost of one step: weight operand reads, MAC
    kernel rounds across the crossbar fleet, token write-out."""
    events: List[MmpuEvent] = []
    events.append(MmpuEvent(
        kind="read", count=spec.row_issues(profile.weight_words),
        cells=profile.weight_words * 32, tag="step.weights"))
    macs = profile.macs_per_token * profile.tokens
    # one MAC round = spec.cols multiplications on one crossbar; the
    # fleet runs n_crossbars rounds concurrently
    rounds_total = math.ceil(macs / spec.cols)
    xbars = max(1, min(spec.n_crossbars, rounds_total))
    rounds_seq = math.ceil(rounds_total / xbars)
    for ev in mac_kernel_events(profile.mac_bits, spec):
        events.append(MmpuEvent(
            kind=ev.kind, count=ev.count * rounds_seq,
            cells=int(math.ceil(ev.cells / spec.cols)) * macs,
            xbars=xbars, tag=f"step.{ev.tag}"))
    out_bits = profile.out_bits_per_token * profile.tokens
    events.append(MmpuEvent(
        kind="write", count=spec.row_issues(out_bits),
        cells=out_bits, tag="step.emit"))
    return tuple(events)


def ecc_events(profile: StepProfile, spec: DeviceSpec,
               slopes: Sequence[int], *, copies: int = 1,
               tag: str = "ecc") -> EventStream:
    """Diagonal-parity redundancy traffic, amortized over the scrub
    interval (arXiv:2105.04212 §IV: per block, each of the S slopes is
    a (BLOCK-1)-XOR reduction; blocks are row-parallel).

    Three phases per scrub pass over ``copies * n_blocks`` blocks:
    encode (parity recompute + parity write), syndrome (same reduction
    against the stored parity), correct (worst case one word rewrite
    per block).
    """
    from ..core import arena
    n_blocks = profile.n_blocks * copies
    n_slopes = len(slopes)
    if n_blocks < 1 or n_slopes < 1:
        return ()
    w = 1.0 / profile.scrub_interval
    block_rounds = spec.row_issues(n_blocks)
    red_cells = n_slopes * (arena.BLOCK - 1) * 32 * n_blocks
    reduction = lambda phase: MmpuEvent(       # noqa: E731
        kind="xor", count=(arena.BLOCK - 1) * n_slopes * block_rounds,
        cells=red_cells, weight=w, tag=f"{tag}.{phase}")
    return (
        reduction("encode"),
        MmpuEvent(kind="write", count=n_slopes * block_rounds,
                  cells=n_slopes * 32 * n_blocks, weight=w,
                  tag=f"{tag}.parity_write"),
        reduction("syndrome"),
        MmpuEvent(kind="write", count=block_rounds, cells=32 * n_blocks,
                  weight=w, tag=f"{tag}.correct"),
    )


def secded_events(profile: StepProfile, spec: DeviceSpec, *,
                  n_checks: int = 7, copies: int = 1,
                  tag: str = "hsiao") -> EventStream:
    """Hsiao SEC-DED redundancy traffic: the same four-phase structure as
    `ecc_events` (encode, parity write, syndrome, correct) with
    ``n_checks`` masked-parity families per word instead of the 3
    diagonal slopes — the denser H matrix is what buys per-word
    correction and double-error detection, so the code zoo's cost
    ordering (off < ecc < hsiao < tmr-*) falls out of the family count.
    """
    return ecc_events(profile, spec, tuple(range(n_checks)), copies=copies,
                      tag=tag)


def tmr_transform(events: Sequence[MmpuEvent], discipline: str,
                  tag: str = "tmr") -> EventStream:
    """Triplicate an execution stream per TMR discipline (paper §V).

    serial        — the three copies run back-to-back on the same
                    arrays: 3x issues, 3x cells, same xbars;
    parallel      — copies run concurrently on 3x the arrays: same
                    issue count, 3x cells, 3x xbars;
    semi_parallel — copies share the original arrays' rows, so the 3x
                    work serializes into 3x issues (1/3 throughput at
                    1x area): 3x issues, 3x cells, same xbars.
    """
    if discipline == "parallel":
        return tuple(e.scaled(cells_x=3, xbars_x=3, tag=f"{tag}.{e.tag}")
                     for e in events)
    if discipline in ("serial", "semi_parallel"):
        return tuple(e.scaled(count_x=3, cells_x=3, tag=f"{tag}.{e.tag}")
                     for e in events)
    raise ValueError(f"unknown TMR discipline: {discipline!r}")


def vote_events(profile: StepProfile, spec: DeviceSpec,
                tag: str = "tmr") -> EventStream:
    """Majority vote = Min3 + NOT per bit (core/tmr.py): per-step over
    the emitted token bits, plus a store-wide vote amortized at the
    scrub cadence."""
    out_bits = profile.out_bits_per_token * profile.tokens
    store_bits = profile.weight_words * 32
    w = 1.0 / profile.scrub_interval
    ev = []
    for kind in ("min3", "not"):
        ev.append(MmpuEvent(kind=kind, count=spec.row_issues(
            math.ceil(out_bits / spec.cols)), cells=out_bits,
            tag=f"{tag}.vote"))
        ev.append(MmpuEvent(kind=kind, count=spec.row_issues(
            profile.weight_words), cells=store_bits, weight=w,
            tag=f"{tag}.store_vote"))
    return tuple(ev)


def lower_step(scheme, profile: StepProfile, spec: DeviceSpec) -> EventStream:
    """One step under `scheme`: the base stream extended/transformed by
    the scheme's `cost_events` hookup (reliability/scheme.py)."""
    return tuple(scheme.cost_events(base_step_events(profile, spec),
                                    profile, spec))
