"""Oracle: the jnp levelized bit-packed executor (core/scheduler.py), which
is itself bit-exact against the lax.scan reference in core/netlist.py."""
from __future__ import annotations

from ...core.scheduler import execute_levelized as execute_packed_ref

__all__ = ["execute_packed_ref"]
