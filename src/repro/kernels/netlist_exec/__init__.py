from .ops import execute_packed
from .ref import execute_packed_ref

__all__ = ["execute_packed", "execute_packed_ref"]
