"""Levelized Min3 netlist executor — one launch, whole netlist, VMEM-resident.

The crossbar_nor kernel already bit-packs trials into uint32 lanes but still
walks the gate list one Min3 at a time (O(G) dynamic column loads).  This
kernel consumes the dense levelized schedule from core/scheduler.py instead:
a fori_loop over *levels* gathers each level's W input rows at once,
evaluates W Minority3 gates as three bitwise ops on a (W, tile_tw) tile,
applies the level's corruption masks, and commits the level with a single
contiguous dynamic_update_slice — the schedule renumbers wires so level l
owns rows [base + l*W, base + (l+1)*W) of the packed state.  O(depth) wide
steps instead of O(G) serial ones (HIPE-MAGIC's parallelism, DESIGN.md §11).

The packed wire state ((base + L*W) x tile_tw uint32) is the fori_loop
carry: it stays in VMEM/vector registers across ALL levels of a trial tile
and never round-trips through HBM between gates.  For the 32-bit MultPIM
multiplier that is ~41k rows x 8 words x 4B ~ 1.3 MB per tile — far under
the ~16 MB VMEM budget.  The grid tiles the packed-trial axis, so trial
tiles execute independently (the mMPU's row parallelism twice over: 32
trials per lane word, tile_tw words per grid step).

Fault injection is mask-based and sampled *outside* the kernel by the
faults.FaultModel packed-trial samplers (threefry, schedule-ordered by
core/scheduler.schedule_fault_masks): slot (l, s)'s fresh column corrupts
as (val & keep[l,s]) ^ flip[l,s], which keeps the kernel bit-exact against
the jnp levelized oracle and the lax.scan reference — fault streams
included.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the level evaluator is shared with the jnp oracle — the kernel == level
# bit-identity rests on literally the same expression
from ...core.scheduler import min3_level as _min3_level


def _kernel(rows_ref, state_in_ref, state_out_ref, *,
            n_levels: int, base: int, width: int):
    def body(l, state):
        val = _min3_level(state, rows_ref[l])
        return jax.lax.dynamic_update_slice(
            state, val, (base + l * width, jnp.int32(0)))

    state_out_ref[...] = jax.lax.fori_loop(0, n_levels, body,
                                           state_in_ref[...])


def _xor_kernel(rows_ref, flip_ref, state_in_ref, state_out_ref, *,
                n_levels: int, base: int, width: int):
    def body(l, state):
        val = _min3_level(state, rows_ref[l]) ^ flip_ref[l]
        return jax.lax.dynamic_update_slice(
            state, val, (base + l * width, jnp.int32(0)))

    state_out_ref[...] = jax.lax.fori_loop(0, n_levels, body,
                                           state_in_ref[...])


def _inject_kernel(rows_ref, keep_ref, flip_ref, state_in_ref,
                   state_out_ref, *, n_levels: int, base: int, width: int):
    def body(l, state):
        val = (_min3_level(state, rows_ref[l]) & keep_ref[l]) ^ flip_ref[l]
        return jax.lax.dynamic_update_slice(
            state, val, (base + l * width, jnp.int32(0)))

    state_out_ref[...] = jax.lax.fori_loop(0, n_levels, body,
                                           state_in_ref[...])


@functools.partial(jax.jit, static_argnames=("base", "tile_tw", "interpret"))
def netlist_exec_kernel(rows_in: jax.Array, state: jax.Array,
                        keep: Optional[jax.Array] = None,
                        flip: Optional[jax.Array] = None, *, base: int,
                        tile_tw: int = 8, interpret: bool = True) -> jax.Array:
    """rows_in: (L, W, 3) int32 remapped input rows per level; state:
    (base + L*W, tw) uint32 trial-packed wire state (tw divisible by
    tile_tw); keep/flip: optional (L, W, tw) uint32 corruption masks
    (flip without keep = pure-XOR injection, e.g. single-fault planes).
    Returns the final state.
    """
    L, W, _ = rows_in.shape
    n_rows, tw = state.shape
    tile = min(tile_tw, tw)
    assert tw % tile == 0, (tw, tile)
    grid = tw // tile
    state_spec = pl.BlockSpec((n_rows, tile), lambda i: (0, i))
    rows_spec = pl.BlockSpec((L, W, 3), lambda i: (0, 0, 0))
    mask_spec = pl.BlockSpec((L, W, tile), lambda i: (0, 0, i))
    out_shape = jax.ShapeDtypeStruct((n_rows, tw), jnp.uint32)
    if flip is None:
        return pl.pallas_call(
            functools.partial(_kernel, n_levels=L, base=base, width=W),
            grid=(grid,),
            in_specs=[rows_spec, state_spec],
            out_specs=state_spec, out_shape=out_shape, interpret=interpret,
        )(rows_in, state)
    if keep is None:
        return pl.pallas_call(
            functools.partial(_xor_kernel, n_levels=L, base=base, width=W),
            grid=(grid,),
            in_specs=[rows_spec, mask_spec, state_spec],
            out_specs=state_spec, out_shape=out_shape, interpret=interpret,
        )(rows_in, flip, state)
    return pl.pallas_call(
        functools.partial(_inject_kernel, n_levels=L, base=base, width=W),
        grid=(grid,),
        in_specs=[rows_spec, mask_spec, mask_spec, state_spec],
        out_specs=state_spec, out_shape=out_shape, interpret=interpret,
    )(rows_in, keep, flip, state)
