"""Public levelized netlist-execution op: schedule, pack, mask, dispatch.

Same contract as core/netlist.execute (iid p_gate or FaultModel via
fold_in(key, gid), single-fault planes, bool (trials, n_in) in / bool
(trials, n_out) out) — the whole netlist runs as ONE pallas_call instead of
an O(G) scan.  Scheduling and fault-mask construction are shared verbatim
with the jnp levelized path (core/scheduler.py), so the kernel is bit-exact
against it by construction and both are bit-exact against the scan
reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import use_interpret
from ...core import scheduler
from ...core.bitops import unpack_trials
from ...core.netlist import Netlist
from .kernel import netlist_exec_kernel


def execute_packed(nl: Netlist, inputs: jax.Array,
                   key: Optional[jax.Array] = None, p_gate=0.0,
                   fault_gate: Optional[jax.Array] = None,
                   max_width: Optional[int] = None, tile_tw: int = 8,
                   interpret: bool | None = None) -> jax.Array:
    """Execute `nl` on bool (trials, n_in) inputs in one kernel launch.

    tile_tw packed-trial words (32 trials each) form one grid step's VMEM
    tile; the trial axis is zero-padded up to a tile multiple (padding
    trials are discarded on unpack, and identity mask columns keep them
    corruption-free).
    """
    sch = scheduler.schedule(nl, max_width)
    trials = inputs.shape[0]
    state = scheduler.packed_initial_state(sch, inputs)
    masks = scheduler.schedule_fault_masks(sch, trials, key, p_gate, fault_gate)

    keep, flip = masks if masks is not None else (None, None)
    tw = state.shape[1]
    tile = min(tile_tw, tw)
    pad = (-tw) % tile
    if pad:
        state = jnp.pad(state, ((0, 0), (0, pad)))
        if flip is not None:
            flip = jnp.pad(flip, ((0, 0), (0, 0), (0, pad)))
        if keep is not None:
            keep = jnp.pad(keep, ((0, 0), (0, 0), (0, pad)),
                           constant_values=np.uint32(0xFFFFFFFF))
    out = netlist_exec_kernel(
        jnp.asarray(sch.rows_in), state, keep, flip, base=sch.base,
        tile_tw=tile,
        interpret=use_interpret() if interpret is None else interpret)
    out = out[jnp.asarray(sch.remap[np.asarray(nl.outputs)])]
    return unpack_trials(out.T, trials)
