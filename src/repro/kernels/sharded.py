"""shard_map wrapper for the arena-wide scrub ops (DESIGN.md §14).

The packed arena is a flat uint32 buffer of 32-word ECC blocks, and every
scrub op is *block-local*: block i's syndrome depends only on block i's
words and parity row.  So sharding the block axis across the whole mesh and
running the single-device op per shard is exactly the single-device result
— no halo, no re-tiling — and the (3,)/(4,) int32 stat vectors sum exactly
under `psum`.  `check_rep=False` is required because pallas_call has no
replication rule; correctness is carried by the block-locality argument
above, not by shard_map's rep checker.
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .diag_parity.kernel import BLOCK

__all__ = ["shard_scrub", "scrub_axes"]


def scrub_axes(mesh: Mesh, axes: Sequence[str] = ("copy", "data", "model"),
               ) -> Tuple[str, ...]:
    """Mesh axes the arena block dim shards over: every axis the mesh
    actually has, so the scrub uses the whole machine.  The copy axis is
    included because scrubbing is state maintenance, not computation — the
    three TMR copies hold *different* corrupted state, each scrubbed where
    it lives."""
    return tuple(a for a in axes if a in mesh.axis_names)


def shard_scrub(local_fn: Callable, mesh: Mesh, axes: Sequence[str],
                buf: jax.Array, parity: jax.Array, *flat_extra: jax.Array):
    """Run a block-local scrub op shard-wise over the arena block axis.

    local_fn(buf_shard, parity_shard, *extra_shards) -> (fixed, parity',
    counts) with counts a 1-D int32 vector; `flat_extra` are flat buffers
    sharded like `buf` (e.g. the inject mask).  Blocks are zero-padded to a
    multiple of the shard count — zero words with zero parity are
    syndrome-clean, so padding never perturbs the stats.
    """
    axes = scrub_axes(mesh, axes)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if n_shards <= 1:
        return local_fn(buf, parity, *flat_extra)
    nb = parity.shape[0]
    pad_b = (-nb) % n_shards
    if pad_b:
        buf = jnp.pad(buf, (0, pad_b * BLOCK))
        parity = jnp.pad(parity, ((0, pad_b), (0, 0)))
        flat_extra = tuple(jnp.pad(x, (0, pad_b * BLOCK)) for x in flat_extra)
    axspec = axes if len(axes) > 1 else axes[0]

    def local(b, p, *ex):
        fixed, par2, counts = local_fn(b, p, *ex)
        return fixed, par2, jax.lax.psum(counts, axes)

    fixed, par2, counts = shard_map(
        local, mesh=mesh,
        in_specs=(P(axspec), P(axspec)) + (P(axspec),) * len(flat_extra),
        out_specs=(P(axspec), P(axspec), P()),
        check_rep=False)(buf, parity, *flat_extra)
    return fixed[:nb * BLOCK], par2[:nb], counts
