"""Diagonal-parity encode kernel (paper §IV on TPU words).

A block is 32 consecutive uint32 words; the slope-s parity word is
XOR_i rotl32(w_i, s*i) — the 32-bit rotate IS the paper's barrel shifter.
The kernel tiles (n_blocks, 32) into VMEM with `bm` blocks per grid step and
unrolls the 32-word XOR tree; rotation amounts are compile-time constants so
each step is two shifts and an or on the VPU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32


def _rotl(w: jax.Array, r: int) -> jax.Array:
    if r % BLOCK == 0:
        return w
    r = r % BLOCK
    return (w << jnp.uint32(r)) | (w >> jnp.uint32(BLOCK - r))


def _kernel(words_ref, out_ref, *, slopes: Tuple[int, ...]):
    w = words_ref[...]                      # (bm, 32) uint32
    outs = []
    for s in slopes:
        acc = w[:, 0]
        for i in range(1, BLOCK):
            acc = acc ^ _rotl(w[:, i], (s * i) % BLOCK)
        outs.append(acc)
    out_ref[...] = jnp.stack(outs, axis=-1)  # (bm, F)


@functools.partial(jax.jit, static_argnames=("slopes", "block_m", "interpret"))
def encode_parity_kernel(words: jax.Array, slopes: Tuple[int, ...] = (1, 2, -1),
                         block_m: int = 256, interpret: bool = True) -> jax.Array:
    """words: (n_blocks, 32) uint32 -> parity (n_blocks, len(slopes)) uint32."""
    n_blocks = words.shape[0]
    bm = min(block_m, n_blocks)
    assert n_blocks % bm == 0, (n_blocks, bm)
    return pl.pallas_call(
        functools.partial(_kernel, slopes=slopes),
        grid=(n_blocks // bm,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, len(slopes)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, len(slopes)), jnp.uint32),
        interpret=interpret,
    )(words)
