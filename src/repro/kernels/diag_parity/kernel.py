"""Diagonal-parity kernels (paper §IV on TPU words).

A block is 32 consecutive uint32 words; the slope-s parity word is
XOR_i rotl32(w_i, s*i) — the 32-bit rotate IS the paper's barrel shifter.
Both kernels tile (n_blocks, 32) into VMEM with `bm` blocks per grid step
and unroll the 32-word XOR tree; rotation amounts are compile-time constants
so each step is two shifts and an or on the VPU.

`encode_parity_kernel` is the protect/refresh hot loop.  `scrub_kernel`
fuses the whole scrub pass — encode → syndrome → locate → correct for both
data and parity-word errors — into one launch over the packed arena
(DESIGN.md §9), emitting corrected words, corrected parity and per-tile
(corrected, parity_fixed, uncorrectable) counters.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitops import popcount32

BLOCK = 32


def _rotl(w: jax.Array, r: int) -> jax.Array:
    if r % BLOCK == 0:
        return w
    r = r % BLOCK
    return (w << jnp.uint32(r)) | (w >> jnp.uint32(BLOCK - r))


def _kernel(words_ref, out_ref, *, slopes: Tuple[int, ...]):
    w = words_ref[...]                      # (bm, 32) uint32
    outs = []
    for s in slopes:
        acc = w[:, 0]
        for i in range(1, BLOCK):
            acc = acc ^ _rotl(w[:, i], (s * i) % BLOCK)
        outs.append(acc)
    out_ref[...] = jnp.stack(outs, axis=-1)  # (bm, F)


@functools.partial(jax.jit, static_argnames=("slopes", "block_m", "interpret"))
def encode_parity_kernel(words: jax.Array, slopes: Tuple[int, ...] = (1, 2, -1),
                         block_m: int = 256, interpret: bool = True) -> jax.Array:
    """words: (n_blocks, 32) uint32 -> parity (n_blocks, len(slopes)) uint32."""
    n_blocks = words.shape[0]
    bm = min(block_m, n_blocks)
    assert n_blocks % bm == 0, (n_blocks, bm)
    return pl.pallas_call(
        functools.partial(_kernel, slopes=slopes),
        grid=(n_blocks // bm,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, len(slopes)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, len(slopes)), jnp.uint32),
        interpret=interpret,
    )(words)


def _onehot_position(x: jax.Array) -> jax.Array:
    """Bit index of a one-hot uint32: popcount(x - 1).  Gated by callers on
    popcount(x) == 1, so the x == 0 wrap is never observed."""
    return popcount32(x - jnp.uint32(1))


def scrub_body(w: jax.Array, p: jax.Array, slopes: Tuple[int, ...]):
    """The fused encode→syndrome→locate→correct tile body, shared by the
    scrub kernel and the fault-campaign inject+scrub kernel
    (kernels/inject_scrub) so the classification logic has one home.

    w: (bm, 32) data words, p: (bm, F) parity words (both uint32, already
    in VMEM).  Returns (corrected w, corrected p, data_err, parity_err,
    uncorrectable) with the last three bool (bm,) block classifications.
    """
    # encode + syndrome, one fused XOR tree per family
    syn = []
    for f, s in enumerate(slopes):
        acc = w[:, 0]
        for i in range(1, BLOCK):
            acc = acc ^ _rotl(w[:, i], (s * i) % BLOCK)
        syn.append(acc ^ p[:, f])
    syn = jnp.stack(syn, axis=-1)           # (bm, F)

    # classify: per-family popcount / one-hot position
    pop = popcount32(syn)                   # (bm, F) int32
    nonzero = pop > 0
    onehot = pop == 1
    n_nonzero = nonzero.astype(jnp.int32).sum(axis=-1)
    hot = _onehot_position(syn)             # (bm, F); valid where onehot

    # locate: slopes (1, 2) invert the diagonal system; the rest must agree
    ia, ib = slopes.index(1), slopes.index(2)
    i0 = (hot[:, ib] - hot[:, ia]) & (BLOCK - 1)
    j0 = (hot[:, ia] - i0) & (BLOCK - 1)
    consistent = jnp.ones(w.shape[:1], dtype=jnp.bool_)
    for f, s in enumerate(slopes):
        consistent &= hot[:, f] == ((j0 + s * i0) & (BLOCK - 1))

    data_err = (n_nonzero == len(slopes)) & onehot.all(-1) & consistent
    parity_err = (n_nonzero == 1) & (onehot | ~nonzero).all(-1)
    uncorrectable = (n_nonzero > 0) & ~data_err & ~parity_err

    # correct: flip bit j0 of word i0 in flagged blocks; heal parity words
    flip_word = jnp.where(data_err, jnp.uint32(1) << j0.astype(jnp.uint32),
                          jnp.uint32(0))
    row = jax.lax.broadcasted_iota(jnp.int32, w.shape, 1) == i0[:, None]
    out_w = w ^ (row.astype(jnp.uint32) * flip_word[:, None])
    out_p = p ^ jnp.where(parity_err[:, None] & nonzero, syn, jnp.uint32(0))
    return out_w, out_p, data_err, parity_err, uncorrectable


def _scrub_kernel(words_ref, parity_ref, out_w_ref, out_p_ref, stats_ref,
                  *, slopes: Tuple[int, ...]):
    out_w, out_p, data_err, parity_err, uncorrectable = scrub_body(
        words_ref[...], parity_ref[...], slopes)
    out_w_ref[...] = out_w
    out_p_ref[...] = out_p
    stats_ref[...] = jnp.stack([
        data_err.astype(jnp.int32).sum(),
        parity_err.astype(jnp.int32).sum(),
        uncorrectable.astype(jnp.int32).sum(),
    ]).reshape(1, 3)


@functools.partial(jax.jit, static_argnames=("slopes", "block_m", "interpret"))
def scrub_kernel(words: jax.Array, parity: jax.Array,
                 slopes: Tuple[int, ...] = (1, 2, -1),
                 block_m: int = 256, interpret: bool = True):
    """Fused scrub: words (n_blocks, 32) + parity (n_blocks, F) uint32 ->
    (corrected words, corrected parity, per-tile stats (grid, 3) int32).

    stats columns: corrected, parity_fixed, uncorrectable.  Requires slopes
    to contain the locating pair (1, 2).
    """
    assert 1 in slopes and 2 in slopes, slopes
    n_blocks, F = words.shape[0], len(slopes)
    bm = min(block_m, n_blocks)
    assert n_blocks % bm == 0, (n_blocks, bm)
    grid = n_blocks // bm
    return pl.pallas_call(
        functools.partial(_scrub_kernel, slopes=slopes),
        grid=(grid,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((bm, F), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((bm, F), lambda i: (i, 0)),
                   pl.BlockSpec((1, 3), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.uint32),
                   jax.ShapeDtypeStruct((n_blocks, F), jnp.uint32),
                   jax.ShapeDtypeStruct((grid, 3), jnp.int32)],
        interpret=interpret,
    )(words, parity)
