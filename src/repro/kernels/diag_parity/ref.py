"""Pure-jnp oracles: the reliability-layer encoder and scrubber."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.reliability import WordEccConfig, correct_words, encode_words


def encode_parity_ref(words: jax.Array,
                      slopes: Tuple[int, ...] = (1, 2, -1)) -> jax.Array:
    return encode_words(words.reshape(-1), WordEccConfig(slopes=slopes))


def scrub_ref(buf: jax.Array, parity: jax.Array,
              slopes: Tuple[int, ...] = (1, 2, -1)):
    """Oracle for the fused scrub kernel, built on correct_words.

    Same contract as ops.scrub: (buf', parity', counts (3,) int32).
    """
    cfg = WordEccConfig(slopes=slopes)
    fixed, par2, rep = correct_words(buf.reshape(-1), parity, cfg)
    counts = jnp.stack([rep.corrected, rep.parity_fixed, rep.uncorrectable])
    return fixed, par2, counts
