"""Pure-jnp oracle: the reliability-layer encoder."""
from __future__ import annotations

from typing import Tuple

import jax

from ...core.reliability import WordEccConfig, encode_words


def encode_parity_ref(words: jax.Array,
                      slopes: Tuple[int, ...] = (1, 2, -1)) -> jax.Array:
    return encode_words(words.reshape(-1), WordEccConfig(slopes=slopes))
