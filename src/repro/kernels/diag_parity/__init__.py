from .ops import encode_parity
from .ref import encode_parity_ref

__all__ = ["encode_parity", "encode_parity_ref"]
