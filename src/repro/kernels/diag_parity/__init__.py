from .ops import encode_parity, scrub, scrub_sharded
from .ref import encode_parity_ref, scrub_ref

__all__ = ["encode_parity", "encode_parity_ref", "scrub", "scrub_ref",
           "scrub_sharded"]
