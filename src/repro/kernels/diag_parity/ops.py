"""Public ECC ops: pad, tile and dispatch the Pallas kernels.

`encode_parity` is the protect/refresh path; `scrub` is the fused
encode->syndrome->locate->correct pass.  Both take a flat uint32 buffer
(the packed arena of core/arena.py) so the whole parameter pytree is one
launch.  Padding blocks are zero words with zero parity — their syndrome
is identically clean, so they never contribute to the stats.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import use_interpret
from .kernel import BLOCK, encode_parity_kernel, scrub_kernel


def encode_parity(buf: jax.Array, slopes: Tuple[int, ...] = (1, 2, -1),
                  block_m: int = 256, interpret: bool | None = None) -> jax.Array:
    """buf: flat uint32 buffer (length multiple of 32) ->
    (n_blocks, len(slopes)) parity words."""
    assert buf.ndim == 1 and buf.shape[0] % BLOCK == 0
    words = buf.reshape(-1, BLOCK)
    n = words.shape[0]
    if n == 0:
        return jnp.zeros((0, len(slopes)), jnp.uint32)
    bm = min(block_m, n)
    pad = (-n) % bm if n > bm else 0
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    out = encode_parity_kernel(words, slopes=tuple(slopes), block_m=bm,
                               interpret=use_interpret() if interpret is None else interpret)
    return out[:n]


def scrub(buf: jax.Array, parity: jax.Array,
          slopes: Tuple[int, ...] = (1, 2, -1), block_m: int = 256,
          interpret: bool | None = None):
    """Fused scrub of a flat uint32 buffer against its parity table.

    buf: (n_blocks * 32,) uint32; parity: (n_blocks, len(slopes)) uint32.
    Returns (corrected buf, corrected parity, counts) with counts a (3,)
    int32 vector: corrected, parity_fixed, uncorrectable.
    """
    assert buf.ndim == 1 and buf.shape[0] % BLOCK == 0
    words = buf.reshape(-1, BLOCK)
    n = words.shape[0]
    assert parity.shape == (n, len(slopes)), (parity.shape, n)
    if n == 0:
        return buf, parity, jnp.zeros((3,), jnp.int32)
    pad = (-n) % block_m if n > block_m else 0
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        parity = jnp.pad(parity, ((0, pad), (0, 0)))
    fixed, par2, stats = scrub_kernel(
        words, parity, slopes=tuple(slopes), block_m=block_m,
        interpret=use_interpret() if interpret is None else interpret)
    return fixed[:n].reshape(-1), par2[:n], stats.sum(axis=0)


def scrub_sharded(buf: jax.Array, parity: jax.Array,
                  slopes: Tuple[int, ...] = (1, 2, -1), block_m: int = 256,
                  interpret: bool | None = None, *, mesh=None,
                  axes: Sequence[str] = ("copy", "data", "model"),
                  local_scrub: Optional[Callable] = None):
    """`scrub` with the arena block axis shard_map'd across `mesh` and the
    (3,) counts psum-reduced (DESIGN.md §14).  Bit-exact vs `scrub` — the
    op is block-local, so per-shard launches compose exactly.  With
    mesh=None (or a 1-device mesh) this IS `scrub`.  `local_scrub`
    overrides the per-shard op (backend registry passes the jnp oracle)."""
    if local_scrub is None:
        def local_scrub(b, p):
            return scrub(b, p, slopes=tuple(slopes), block_m=block_m,
                         interpret=interpret)
    if mesh is None:
        return local_scrub(buf, parity)
    from ..sharded import shard_scrub
    return shard_scrub(local_scrub, mesh, axes, buf, parity)
