"""Public ECC-encode op: pads, tiles and dispatches the Pallas kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .. import use_interpret
from .kernel import BLOCK, encode_parity_kernel


def encode_parity(buf: jax.Array, slopes: Tuple[int, ...] = (1, 2, -1),
                  block_m: int = 256, interpret: bool | None = None) -> jax.Array:
    """buf: flat uint32 buffer (length multiple of 32) ->
    (n_blocks, len(slopes)) parity words."""
    assert buf.ndim == 1 and buf.shape[0] % BLOCK == 0
    words = buf.reshape(-1, BLOCK)
    n = words.shape[0]
    bm = block_m
    pad = (-n) % bm
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    out = encode_parity_kernel(words, slopes=tuple(slopes), block_m=bm,
                               interpret=use_interpret() if interpret is None else interpret)
    return out[:n]
