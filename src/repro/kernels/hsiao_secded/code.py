"""The (39,32) Hsiao SEC-DED code: H-matrix constants shared by the
Pallas kernel and the jnp oracle.

Hsiao's construction (odd-weight-column codes, IBM JRD 1970) picks every
data column of H with *odd* weight so single errors (odd syndrome
weight) and double errors (even, nonzero syndrome weight) are disjoint —
SEC-DED without the extra overall-parity row of extended Hamming.  For
32 data bits, 7 check bits suffice: C(7,3) = 35 weight-3 patterns cover
the 32 data columns, and the 7 unit vectors protect the check bits
themselves.

Of the 35 weight-3 columns we keep 32, dropping three greedily so the
row weights stay balanced (Hsiao's second criterion — balanced rows
equalize the XOR-tree depth per check bit).  The selection is a
deterministic function of nothing but this file, so the code words are
stable across runs/machines and safe to bake into checkpoints.

Layout over the packed arena (core/arena.py): a block is 32 consecutive
uint32 words; the redundancy row is 7 uint32 words where parity word j
packs check bit j of word i at bit position i.  This is the same
(n_blocks, F) table family as diagonal parity (F=7 instead of 3), so
arena sharding, copy concatenation and checkpointing all carry over.

Unlike the diagonal code — which locates one flipped bit per *block* —
Hsiao decodes each word independently: one flip in every one of the 32
words of a block is still corrected.  The price is 7 parity words per
block instead of 3 and a denser encode tree.
"""
from __future__ import annotations

from typing import Tuple

N_CHECKS = 7          # check bits per 32-bit data word
DATA_BITS = 32


def _select_columns() -> Tuple[int, ...]:
    cand = [c for c in range(1 << N_CHECKS) if bin(c).count("1") == 3]
    # drop 3 of the 35 candidates, each time the lexicographically first
    # column whose rows are currently the most loaded
    cols = list(cand)
    for _ in range(len(cand) - DATA_BITS):
        load = [sum((c >> j) & 1 for c in cols) for j in range(N_CHECKS)]
        worst = max(cols, key=lambda c: (sum(load[j] for j in range(N_CHECKS)
                                             if (c >> j) & 1), -c))
        cols.remove(worst)
    return tuple(cols)


#: syndrome value produced by a single flip of data bit k (32 entries,
#: all odd weight, pairwise distinct, none a unit vector)
DATA_COLUMNS: Tuple[int, ...] = _select_columns()

#: CHECK_MASKS[j] — the 32-bit data mask of check bit j: bit k set iff
#: data bit k participates in check j (row j of H restricted to data)
CHECK_MASKS: Tuple[int, ...] = tuple(
    sum(((col >> j) & 1) << k for k, col in enumerate(DATA_COLUMNS))
    for j in range(N_CHECKS))

assert len(set(DATA_COLUMNS)) == DATA_BITS
assert all(bin(c).count("1") == 3 for c in DATA_COLUMNS)
