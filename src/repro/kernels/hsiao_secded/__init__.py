"""(39,32) Hsiao SEC-DED word code over the packed arena.

The second arena code of the zoo (DESIGN.md §18): per-word
single-error-correct / double-error-detect with 7 check bits per 32-bit
word, versus diagonal parity's per-block correction with 3 parity words
per 32-word block.  Storage 1+7/32 vs 1+3/32; in exchange every word of
a block corrects independently and double errors are *detected* instead
of silently miscorrected.
"""
from .code import CHECK_MASKS, DATA_COLUMNS, N_CHECKS  # noqa: F401
from .ops import encode_hsiao, scrub, scrub_sharded    # noqa: F401
