"""Pure-jnp oracle for the (39,32) Hsiao SEC-DED code.

Deliberately a *different* construction from the Pallas kernel: the
oracle expands every word to its 32 bits, multiplies by the H matrix
mod 2, and classifies syndromes with gathers — none of which the kernel
can afford — so a shared-bug failure mode between the two is unlikely.

Contract (mirrors kernels/diag_parity): flat uint32 buffers, parity
tables of shape (n_blocks, 7), counts as a (3,) int32 vector
(corrected, parity_fixed, uncorrectable).  Counter semantics are
per-WORD (each word decodes independently), unlike the per-block
diagonal counters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .code import DATA_COLUMNS, N_CHECKS

BLOCK = 32

#: H restricted to the data bits: (N_CHECKS, 32) 0/1 matrix
_H = jnp.array([[(col >> j) & 1 for col in DATA_COLUMNS]
                for j in range(N_CHECKS)], jnp.int32)
_COLS = jnp.array(DATA_COLUMNS, jnp.uint32)
_UNITS = (jnp.uint32(1) << jnp.arange(N_CHECKS, dtype=jnp.uint32))


def _bits(w: jax.Array) -> jax.Array:
    """(..., ) uint32 -> (..., 32) int32 bit planes, LSB first."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return ((w[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)


def _check_bits(words: jax.Array) -> jax.Array:
    """words (n_blocks, 32) uint32 -> check bits (n_blocks, 32, 7) int32."""
    return (_bits(words) @ _H.T) % 2


def _pack_checks(chk: jax.Array) -> jax.Array:
    """check bits (n_blocks, 32, 7) -> parity table (n_blocks, 7) uint32
    with check bit j of word i at bit position i of parity word j."""
    lane = jnp.uint32(1) << jnp.arange(BLOCK, dtype=jnp.uint32)
    return (chk.astype(jnp.uint32) * lane[None, :, None]).sum(axis=1)


def encode_hsiao_ref(buf: jax.Array) -> jax.Array:
    """buf: flat uint32 (length multiple of 32) -> (n_blocks, 7) parity."""
    words = buf.reshape(-1, BLOCK)
    return _pack_checks(_check_bits(words))


def scrub_hsiao_ref(buf: jax.Array, parity: jax.Array):
    """Oracle scrub: (buf', parity', counts (3,) int32).

    Per word: syndrome 0 -> clean; syndrome == a data column -> flip that
    data bit (corrected); syndrome == a unit vector -> heal the stored
    check bit (parity_fixed); any other nonzero syndrome (even weight) ->
    detected-but-uncorrectable double error, data left untouched.
    """
    words = buf.reshape(-1, BLOCK)
    chk = _check_bits(words)                               # (n, 32, 7)
    lane = jnp.arange(BLOCK, dtype=jnp.uint32)
    stored = ((parity[:, None, :] >> lane[None, :, None])
              & jnp.uint32(1)).astype(jnp.int32)           # (n, 32, 7)
    syn_bits = chk ^ stored
    weights = (jnp.uint32(1) << jnp.arange(N_CHECKS, dtype=jnp.uint32))
    s = (syn_bits.astype(jnp.uint32) * weights).sum(-1)    # (n, 32)

    eq = s[..., None] == _COLS                             # (n, 32, 32)
    is_data = eq.any(-1)
    pos = jnp.argmax(eq, axis=-1).astype(jnp.uint32)
    unit = s[..., None] == _UNITS                          # (n, 32, 7)
    is_check = unit.any(-1)
    uncorr = (s != 0) & ~is_data & ~is_check

    fixed = words ^ jnp.where(is_data, jnp.uint32(1) << pos, jnp.uint32(0))
    par2 = _pack_checks(stored ^ unit.astype(jnp.int32))
    counts = jnp.stack([is_data.sum(dtype=jnp.int32),
                        is_check.sum(dtype=jnp.int32),
                        uncorr.sum(dtype=jnp.int32)])
    return fixed.reshape(-1), par2, counts
