"""Hsiao SEC-DED kernels: fused encode → syndrome → classify → correct.

Same tiling family as kernels/diag_parity: (n_blocks, 32) uint32 word
tiles with `bm` blocks per grid step, parity tiles (bm, 7).  The encode
is 7 masked-popcount parities per word, packed over the 32 words of a
block into one uint32 per check bit; the scrub recomputes them, XORs
against the stored table, reassembles a per-word 7-bit syndrome and
classifies it against the 39 compile-time column constants — 32 data
columns and 7 unit vectors — with unrolled equality compares, so the
whole decode is branch- and gather-free on the VPU.

Unlike the diagonal code (one correction per block), every word of a
block decodes independently: the per-tile stats count words, not
blocks.  A syndrome that is nonzero but matches no column is a detected
double error; the word is left untouched and reported uncorrectable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitops import popcount32
from .code import CHECK_MASKS, DATA_COLUMNS, N_CHECKS

BLOCK = 32


def _encode_checks(w: jax.Array) -> list:
    """w (bm, 32) uint32 -> 7 packed check words, each (bm,) uint32 with
    check bit j of word i at bit position i."""
    lane = jax.lax.broadcasted_iota(jnp.uint32, w.shape, 1)
    out = []
    for m in CHECK_MASKS:
        bit = (popcount32(w & jnp.uint32(m)) & 1).astype(jnp.uint32)
        out.append((bit << lane).sum(axis=-1, dtype=jnp.uint32))
    return out


def _encode_kernel(words_ref, out_ref):
    out_ref[...] = jnp.stack(_encode_checks(words_ref[...]), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def encode_hsiao_kernel(words: jax.Array, block_m: int = 256,
                        interpret: bool = True) -> jax.Array:
    """words: (n_blocks, 32) uint32 -> parity (n_blocks, 7) uint32."""
    n_blocks = words.shape[0]
    bm = min(block_m, n_blocks)
    assert n_blocks % bm == 0, (n_blocks, bm)
    return pl.pallas_call(
        _encode_kernel,
        grid=(n_blocks // bm,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, N_CHECKS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, N_CHECKS), jnp.uint32),
        interpret=interpret,
    )(words)


def hsiao_body(w: jax.Array, p: jax.Array):
    """The fused tile body: w (bm, 32) data words, p (bm, 7) parity words.

    Returns (corrected w, corrected p, data_err, check_err, uncorrectable)
    with the last three bool (bm, 32) per-WORD classifications.
    """
    lane = jax.lax.broadcasted_iota(jnp.uint32, w.shape, 1)
    enc = _encode_checks(w)

    # per-word syndrome: bit j of s[b, i] = bit i of (enc_j ^ p[:, j])
    s = jnp.zeros_like(w)
    for j in range(N_CHECKS):
        syn_j = enc[j] ^ p[:, j]                     # (bm,) packed over i
        s = s | ((((syn_j[:, None] >> lane) & jnp.uint32(1))) << jnp.uint32(j))

    # classify against the 39 compile-time columns (unrolled compares)
    data_err = jnp.zeros(w.shape, jnp.bool_)
    flip = jnp.zeros_like(w)
    for k, col in enumerate(DATA_COLUMNS):
        eq = s == jnp.uint32(col)
        data_err |= eq
        flip = flip | (eq.astype(jnp.uint32) << jnp.uint32(k))

    check_err = jnp.zeros(w.shape, jnp.bool_)
    out_p = []
    for j in range(N_CHECKS):
        eq = s == jnp.uint32(1 << j)                 # check bit j flipped
        check_err |= eq
        out_p.append(p[:, j] ^ (eq.astype(jnp.uint32) << lane)
                     .sum(axis=-1, dtype=jnp.uint32))
    uncorrectable = (s != 0) & ~data_err & ~check_err

    return (w ^ flip, jnp.stack(out_p, axis=-1),
            data_err, check_err, uncorrectable)


def _scrub_kernel(words_ref, parity_ref, out_w_ref, out_p_ref, stats_ref):
    out_w, out_p, data_err, check_err, uncorr = hsiao_body(
        words_ref[...], parity_ref[...])
    out_w_ref[...] = out_w
    out_p_ref[...] = out_p
    stats_ref[...] = jnp.stack([
        data_err.astype(jnp.int32).sum(),
        check_err.astype(jnp.int32).sum(),
        uncorr.astype(jnp.int32).sum(),
    ]).reshape(1, 3)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def scrub_hsiao_kernel(words: jax.Array, parity: jax.Array,
                       block_m: int = 256, interpret: bool = True):
    """Fused scrub: words (n_blocks, 32) + parity (n_blocks, 7) uint32 ->
    (corrected words, corrected parity, per-tile stats (grid, 3) int32).

    stats columns: corrected, parity_fixed, uncorrectable — per word.
    """
    n_blocks = words.shape[0]
    bm = min(block_m, n_blocks)
    assert n_blocks % bm == 0, (n_blocks, bm)
    grid = n_blocks // bm
    return pl.pallas_call(
        _scrub_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((bm, N_CHECKS), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((bm, N_CHECKS), lambda i: (i, 0)),
                   pl.BlockSpec((1, 3), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.uint32),
                   jax.ShapeDtypeStruct((n_blocks, N_CHECKS), jnp.uint32),
                   jax.ShapeDtypeStruct((grid, 3), jnp.int32)],
        interpret=interpret,
    )(words, parity)
