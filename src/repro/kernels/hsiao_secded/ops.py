"""Public Hsiao SEC-DED ops: pad, tile and dispatch the Pallas kernels.

Same contract as kernels/diag_parity/ops.py over the packed arena —
flat uint32 buffers, (n_blocks, 7) parity tables, zero padding blocks
are syndrome-clean — so the scheme layer, sharding helper and backend
registry treat the two codes uniformly.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .. import use_interpret
from .code import N_CHECKS
from .kernel import BLOCK, encode_hsiao_kernel, scrub_hsiao_kernel


def encode_hsiao(buf: jax.Array, block_m: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    """buf: flat uint32 buffer (length multiple of 32) ->
    (n_blocks, 7) parity words."""
    assert buf.ndim == 1 and buf.shape[0] % BLOCK == 0
    words = buf.reshape(-1, BLOCK)
    n = words.shape[0]
    if n == 0:
        return jnp.zeros((0, N_CHECKS), jnp.uint32)
    bm = min(block_m, n)
    pad = (-n) % bm if n > bm else 0
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    out = encode_hsiao_kernel(
        words, block_m=bm,
        interpret=use_interpret() if interpret is None else interpret)
    return out[:n]


def scrub(buf: jax.Array, parity: jax.Array, block_m: int = 256,
          interpret: bool | None = None):
    """Fused scrub of a flat uint32 buffer against its Hsiao table.

    buf: (n_blocks * 32,) uint32; parity: (n_blocks, 7) uint32.
    Returns (corrected buf, corrected parity, counts) with counts a (3,)
    int32 vector: corrected, parity_fixed, uncorrectable — per word.
    """
    assert buf.ndim == 1 and buf.shape[0] % BLOCK == 0
    words = buf.reshape(-1, BLOCK)
    n = words.shape[0]
    assert parity.shape == (n, N_CHECKS), (parity.shape, n)
    if n == 0:
        return buf, parity, jnp.zeros((3,), jnp.int32)
    pad = (-n) % block_m if n > block_m else 0
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        parity = jnp.pad(parity, ((0, pad), (0, 0)))
    fixed, par2, stats = scrub_hsiao_kernel(
        words, parity, block_m=block_m,
        interpret=use_interpret() if interpret is None else interpret)
    return fixed[:n].reshape(-1), par2[:n], stats.sum(axis=0)


def scrub_sharded(buf: jax.Array, parity: jax.Array, block_m: int = 256,
                  interpret: bool | None = None, *, mesh=None,
                  axes: Sequence[str] = ("copy", "data", "model"),
                  local_scrub: Optional[Callable] = None):
    """`scrub` with the arena block axis shard_map'd across `mesh` and the
    (3,) counts psum-reduced — the op is word-local, so per-shard launches
    compose exactly.  With mesh=None this IS `scrub`."""
    if local_scrub is None:
        def local_scrub(b, p):
            return scrub(b, p, block_m=block_m, interpret=interpret)
    if mesh is None:
        return local_scrub(buf, parity)
    from ..sharded import shard_scrub
    return shard_scrub(local_scrub, mesh, axes, buf, parity)
