"""Oracle: naive full-matrix attention from the model stack."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.attention import naive_attention


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    out = naive_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
