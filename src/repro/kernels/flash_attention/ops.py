"""Public flash-attention op matching the model stack's (B, S, H, hd)
convention; transposes to head-major, dispatches the kernel."""
from __future__ import annotations

import jax

from .. import use_interpret
from .kernel import flash_attention_kernel


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    q_block=512, kv_block=512, interpret: bool | None = None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) -> (B, Sq, H, hd).

    q_offset must be 0 in the kernel path (full-sequence prefill/training)."""
    assert q_offset == 0, "kernel path covers full-sequence attention"
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(
        qh, kh, vh, causal=causal, window=window,
        block_q=q_block, block_k=kv_block,
        interpret=use_interpret() if interpret is None else interpret)
    return out.transpose(0, 2, 1, 3)
