"""Flash attention forward kernel (online softmax, GQA-aware).

Layout: q (B, H, Sq, hd), k/v (B, KV, Sk, hd) — head-major so each grid
step streams contiguous (block, hd) tiles into VMEM.  Grid is
(B, H, nq, nk) with nk innermost: TPU grids execute sequentially, so the
fp32 VMEM scratch (acc, m, l) carries the online-softmax state across kv
blocks of one q block; the final kv step writes acc/l to the output tile.

Causality is handled at two levels: whole (iq, ik) tiles with no unmasked
entry are skipped with pl.when (the MXU never sees them — triangular work),
and the diagonal tile applies an iota mask.  A sliding window adds the
symmetric lower bound.  The m/l statistics live in (block_q, 128) VMEM
tiles (lane-replicated) to stay layout-friendly on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_STAT_LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    run = jnp.bool_(True)
    if causal:
        run &= k_start < q_start + block_q
    if window:
        run &= (k_start + block_k) > (q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                  # (bq, 1)
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                                 # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                         # (bq, 1)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal=True, window=0,
                           block_q=512, block_k=512, interpret=True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (q.shape, k.shape, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, block_q=bq, block_k=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, _STAT_LANES), jnp.float32),
            pltpu.VMEM((bq, _STAT_LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
