"""Fused fault-injection + scrub kernel (Monte-Carlo campaign hot loop).

One Pallas launch executes a whole trial interval over the packed arena:

    inject (XOR the fault mask) → encode → syndrome → locate → correct

The tile body is diag_parity's shared `scrub_body` (DESIGN.md §9) with the
corruption folded in front of the XOR trees: the corrupted words exist only
in VMEM — they are never round-tripped through HBM between injection and
scrub, which is exactly the memory traffic a campaign of thousands of
trials cares about.  The fault mask is sampled *outside* the kernel by a
faults.models.FaultModel (threefry — deterministic and identical to the jnp
oracle), so the kernel stays bit-exact testable against ref.py.

Per-tile stats gain a 4th counter, `injected` (popcount of the mask), so a
campaign reads (injected, corrected, parity_fixed, uncorrectable) for the
batch from one launch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitops import popcount32
from ..diag_parity.kernel import BLOCK, scrub_body


def _inject_scrub_kernel(words_ref, parity_ref, mask_ref,
                         out_w_ref, out_p_ref, stats_ref,
                         *, slopes: Tuple[int, ...]):
    w = words_ref[...] ^ mask_ref[...]      # (bm, 32) uint32 — the injection
    out_w, out_p, data_err, parity_err, uncorrectable = scrub_body(
        w, parity_ref[...], slopes)
    out_w_ref[...] = out_w
    out_p_ref[...] = out_p
    stats_ref[...] = jnp.stack([
        popcount32(mask_ref[...]).sum(),
        data_err.astype(jnp.int32).sum(),
        parity_err.astype(jnp.int32).sum(),
        uncorrectable.astype(jnp.int32).sum(),
    ]).reshape(1, 4)


@functools.partial(jax.jit, static_argnames=("slopes", "block_m", "interpret"))
def inject_scrub_kernel(words: jax.Array, parity: jax.Array, mask: jax.Array,
                        slopes: Tuple[int, ...] = (1, 2, -1),
                        block_m: int = 256, interpret: bool = True):
    """Fused inject+scrub: words/mask (n_blocks, 32) + parity (n_blocks, F)
    uint32 -> (corrected words, corrected parity, per-tile stats (grid, 4)).

    stats columns: injected, corrected, parity_fixed, uncorrectable.
    Requires slopes to contain the locating pair (1, 2).
    """
    assert 1 in slopes and 2 in slopes, slopes
    n_blocks, F = words.shape[0], len(slopes)
    bm = min(block_m, n_blocks)
    assert n_blocks % bm == 0, (n_blocks, bm)
    grid = n_blocks // bm
    return pl.pallas_call(
        functools.partial(_inject_scrub_kernel, slopes=slopes),
        grid=(grid,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((bm, F), lambda i: (i, 0)),
                  pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((bm, F), lambda i: (i, 0)),
                   pl.BlockSpec((1, 4), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.uint32),
                   jax.ShapeDtypeStruct((n_blocks, F), jnp.uint32),
                   jax.ShapeDtypeStruct((grid, 4), jnp.int32)],
        interpret=interpret,
    )(words, parity, mask)
