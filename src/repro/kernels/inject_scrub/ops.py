"""Public fused inject+scrub op: pad, tile and dispatch the Pallas kernel.

Takes the flat uint32 arena (core/arena.py), its parity table and an XOR
fault mask of the same length (sampled by a faults.models.FaultModel), so a
whole trial interval — corrupt every block, then scrub every block — is ONE
launch.  Padding blocks carry zero words, zero parity and zero mask: their
syndrome is identically clean and they contribute nothing to the stats.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import use_interpret
from .kernel import BLOCK, inject_scrub_kernel


def inject_scrub(buf: jax.Array, parity: jax.Array, mask: jax.Array,
                 slopes: Tuple[int, ...] = (1, 2, -1), block_m: int = 256,
                 interpret: bool | None = None):
    """Fused corrupt+scrub of a flat uint32 buffer against its parity table.

    buf, mask: (n_blocks * 32,) uint32; parity: (n_blocks, len(slopes)).
    Returns (corrected buf, corrected parity, counts) with counts a (4,)
    int32 vector: injected, corrected, parity_fixed, uncorrectable.
    """
    assert buf.ndim == 1 and buf.shape[0] % BLOCK == 0
    assert mask.shape == buf.shape, (mask.shape, buf.shape)
    words = buf.reshape(-1, BLOCK)
    mwords = mask.reshape(-1, BLOCK)
    n = words.shape[0]
    assert parity.shape == (n, len(slopes)), (parity.shape, n)
    if n == 0:
        return buf, parity, jnp.zeros((4,), jnp.int32)
    pad = (-n) % block_m if n > block_m else 0
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        mwords = jnp.pad(mwords, ((0, pad), (0, 0)))
        parity = jnp.pad(parity, ((0, pad), (0, 0)))
    fixed, par2, stats = inject_scrub_kernel(
        words, parity, mwords, slopes=tuple(slopes), block_m=block_m,
        interpret=use_interpret() if interpret is None else interpret)
    return fixed[:n].reshape(-1), par2[:n], stats.sum(axis=0)


def inject_scrub_sharded(buf: jax.Array, parity: jax.Array, mask: jax.Array,
                         slopes: Tuple[int, ...] = (1, 2, -1),
                         block_m: int = 256, interpret: bool | None = None,
                         *, mesh=None,
                         axes: Sequence[str] = ("copy", "data", "model"),
                         local_op: Optional[Callable] = None):
    """`inject_scrub` with the arena block axis shard_map'd across `mesh`
    and the (4,) counts psum-reduced (DESIGN.md §14).  The mask shards with
    the buffer, so each shard corrupts and repairs only the blocks it owns;
    bit-exact vs `inject_scrub`.  With mesh=None this IS `inject_scrub`."""
    if local_op is None:
        def local_op(b, p, m):
            return inject_scrub(b, p, m, slopes=tuple(slopes),
                                block_m=block_m, interpret=interpret)
    if mesh is None:
        return local_op(buf, parity, mask)
    from ..sharded import shard_scrub
    return shard_scrub(local_op, mesh, axes, buf, parity, mask)
