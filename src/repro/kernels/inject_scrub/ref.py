"""Pure-jnp oracle: inject via XOR, then the reliability-layer scrubber."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.bitops import popcount32
from ...core.reliability import WordEccConfig, correct_words


def inject_scrub_ref(buf: jax.Array, parity: jax.Array, mask: jax.Array,
                     slopes: Tuple[int, ...] = (1, 2, -1)):
    """Oracle for the fused inject+scrub kernel, built on correct_words.

    Same contract as ops.inject_scrub: (buf', parity', counts (4,) int32)
    with counts = injected, corrected, parity_fixed, uncorrectable.
    """
    cfg = WordEccConfig(slopes=slopes)
    corrupted = buf.reshape(-1) ^ mask.reshape(-1)
    fixed, par2, rep = correct_words(corrupted, parity, cfg)
    counts = jnp.stack([popcount32(mask.reshape(-1)).sum(),
                        rep.corrected, rep.parity_fixed, rep.uncorrectable])
    return fixed, par2, counts
