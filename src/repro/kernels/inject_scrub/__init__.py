from .ops import inject_scrub, inject_scrub_sharded
from .ref import inject_scrub_ref

__all__ = ["inject_scrub", "inject_scrub_ref", "inject_scrub_sharded"]
