from .ops import inject_scrub
from .ref import inject_scrub_ref

__all__ = ["inject_scrub", "inject_scrub_ref"]
