"""Public TMR-vote op: accepts arbitrary-shape float/int arrays, views them
as packed words, votes per-bit in the Pallas kernel, restores shape/dtype."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import use_interpret
from ...core.bitops import float_view_u32, u32_view_float
from .kernel import vote_kernel

_LANES = 512


def vote(a: jax.Array, b: jax.Array, c: jax.Array,
         interpret: bool | None = None) -> jax.Array:
    """Per-bit 2-of-3 majority of three same-shape arrays."""
    dtype, shape = a.dtype, a.shape
    av, bv, cv = (float_view_u32(x).reshape(-1) for x in (a, b, c))
    n = av.shape[0]
    pad = (-n) % _LANES
    bm = min(256, max(1, (n + pad) // _LANES))
    # pad the row axis to a multiple of the block too (row counts above 256
    # are not otherwise guaranteed divisible by it)
    pad += (-((n + pad) // _LANES)) % bm * _LANES
    if pad:
        av, bv, cv = (jnp.pad(x, (0, pad)) for x in (av, bv, cv))
    m = av.shape[0] // _LANES
    out = vote_kernel(av.reshape(m, _LANES).astype(jnp.uint32),
                      bv.reshape(m, _LANES).astype(jnp.uint32),
                      cv.reshape(m, _LANES).astype(jnp.uint32),
                      block_m=bm, block_n=_LANES,
                      interpret=use_interpret() if interpret is None else interpret)
    flat = out.reshape(-1)[:n]
    if dtype == jnp.bfloat16:
        flat = flat.astype(jnp.uint16)
    return u32_view_float(flat, dtype).reshape(shape)
