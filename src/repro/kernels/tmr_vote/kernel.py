"""Per-bit 2-of-3 majority vote kernel (paper §V).

The Minority3 stateful gate voting, as bitwise ops on packed words:
out = (a & b) | (b & c) | (a & c) corrects any single corrupted copy per
bit.  Tiled (block_m, 128)-aligned for the VPU; one fused pass, three
streams in, one out — the kernel is purely memory-bound, which is exactly
the paper's point: voting at the full bandwidth of the substrate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, c_ref, o_ref):
    a, b, c = a_ref[...], b_ref[...], c_ref[...]
    o_ref[...] = (a & b) | (b & c) | (a & c)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def vote_kernel(a: jax.Array, b: jax.Array, c: jax.Array,
                block_m: int = 256, block_n: int = 512,
                interpret: bool = True) -> jax.Array:
    """a/b/c: (M, N) uint32 -> per-bit majority (M, N)."""
    M, N = a.shape
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0, (a.shape, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )(a, b, c)
