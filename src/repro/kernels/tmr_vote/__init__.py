from .ops import vote
from .ref import vote_ref

__all__ = ["vote", "vote_ref"]
