"""Oracle: core.tmr per-bit voter."""
from ...core.tmr import vote_array as vote_ref  # noqa: F401
