"""Public netlist-execution op: packs boolean trials into uint32 lanes,
initializes constant/input wires, runs the VMEM interpreter kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import use_interpret
from ...core.netlist import Netlist
from .kernel import netlist_kernel

PACK = 32


def _pack_bits(x: jax.Array) -> jax.Array:
    """(trials, n) bool -> (ceil(trials/32), n) uint32, trial t in bit t%32."""
    t, n = x.shape
    pad = (-t) % PACK
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    x = x.reshape(-1, PACK, n).astype(jnp.uint32)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)[None, :, None]
    return (x << shifts).sum(axis=1, dtype=jnp.uint32)


def _unpack_bits(w: jax.Array, trials: int) -> jax.Array:
    tw, n = w.shape
    shifts = jnp.arange(PACK, dtype=jnp.uint32)[None, :, None]
    bits = ((w[:, None, :] >> shifts) & 1).astype(jnp.bool_)
    return bits.reshape(tw * PACK, n)[:trials]


def execute_netlist(nl: Netlist, inputs: jax.Array,
                    interpret: bool | None = None) -> jax.Array:
    """inputs: bool (trials, n_in) -> bool (trials, n_out), fault-free
    (fault-injection experiments use the core lax.scan executor)."""
    trials = inputs.shape[0]
    tw = (trials + PACK - 1) // PACK
    state = jnp.zeros((tw, nl.n_wires), jnp.uint32)
    state = state.at[:, 1].set(jnp.uint32(0xFFFFFFFF))       # const ONE wire
    state = state.at[:, jnp.asarray(nl.inputs)].set(_pack_bits(inputs))
    out = netlist_kernel(jnp.asarray(nl.gates), state,
                         interpret=use_interpret() if interpret is None else interpret)
    return _unpack_bits(out[:, jnp.asarray(nl.outputs)], trials)
