"""Public netlist-execution op: packs boolean trials into uint32 lanes
(core/bitops.pack_trials layout), initializes constant/input wires, runs
the VMEM interpreter kernel.  Gate-serial and fault-free — the levelized
kernels/netlist_exec engine supersedes it for the experiment hot loops."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import use_interpret
from ...core.bitops import PACK, pack_trials, unpack_trials
from ...core.netlist import Netlist
from .kernel import netlist_kernel


def execute_netlist(nl: Netlist, inputs: jax.Array,
                    interpret: bool | None = None) -> jax.Array:
    """inputs: bool (trials, n_in) -> bool (trials, n_out), fault-free
    (fault-injection experiments use the levelized or lax.scan executors)."""
    trials = inputs.shape[0]
    tw = (trials + PACK - 1) // PACK
    state = jnp.zeros((tw, nl.n_wires), jnp.uint32)
    state = state.at[:, 1].set(jnp.uint32(0xFFFFFFFF))       # const ONE wire
    state = state.at[:, jnp.asarray(nl.inputs)].set(pack_trials(inputs))
    out = netlist_kernel(jnp.asarray(nl.gates), state,
                         interpret=use_interpret() if interpret is None else interpret)
    return unpack_trials(out[:, jnp.asarray(nl.outputs)], trials)
