"""In-VMEM Min3 netlist interpreter — the mMPU stateful-logic hot loop.

The crossbar's row parallelism maps to *bit-packing*: 32 independent trials
(crossbar rows) live in the bit lanes of one uint32, and a tile of
`tw` packed words executes the same gate simultaneously — exactly the
"same gate, every row, one cycle" semantics of MAGIC/FELIX (paper §II-A).

The whole wire state (tw x n_wires uint32) stays resident in VMEM while a
fori_loop walks the gate list (dynamic column loads/stores); for a 32-bit
MultPIM multiplier that is 8 x ~14k x 4B ~ 0.5 MB — far under the ~16 MB
VMEM budget, so the interpreter never touches HBM between gates.  On real
TPU the gate list would be scalar-prefetched into SMEM; in this repo it is
a VMEM operand (works in both interpret and compiled modes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(gates_ref, state_in_ref, state_ref, *, n_gates: int):
    state_ref[...] = state_in_ref[...]

    def body(g, carry):
        row = gates_ref[g]                     # (4,) int32: in1, in2, in3, out
        a = pl.load(state_ref, (slice(None), pl.dslice(row[0], 1)))
        b = pl.load(state_ref, (slice(None), pl.dslice(row[1], 1)))
        c = pl.load(state_ref, (slice(None), pl.dslice(row[2], 1)))
        maj = (a & b) | (b & c) | (a & c)
        pl.store(state_ref, (slice(None), pl.dslice(row[3], 1)), ~maj)
        return carry

    jax.lax.fori_loop(0, n_gates, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def netlist_kernel(gates: jax.Array, state: jax.Array,
                   interpret: bool = True) -> jax.Array:
    """gates: (G, 4) int32 Min3 netlist; state: (tw, n_wires) uint32 packed
    trials.  Returns the final wire state."""
    G = gates.shape[0]
    tw, n_wires = state.shape
    return pl.pallas_call(
        functools.partial(_kernel, n_gates=G),
        grid=(1,),
        in_specs=[pl.BlockSpec((G, 4), lambda i: (0, 0)),
                  pl.BlockSpec((tw, n_wires), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tw, n_wires), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((tw, n_wires), jnp.uint32),
        interpret=interpret,
    )(gates, state)
