"""Oracle: the lax.scan netlist executor from the core library."""
from __future__ import annotations

import jax

from ...core.netlist import Netlist, execute


def execute_netlist_ref(nl: Netlist, inputs: jax.Array) -> jax.Array:
    """inputs: bool (trials, n_in) -> bool (trials, n_out), fault-free."""
    return execute(nl, inputs)
