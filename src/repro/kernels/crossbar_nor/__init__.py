from .ops import execute_netlist
from .ref import execute_netlist_ref

__all__ = ["execute_netlist", "execute_netlist_ref"]
