"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (handles layout/padding, interpret flag)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

This container is CPU-only: kernels are validated with interpret=True
(the kernel body executes on CPU); on a real TPU set
REPRO_PALLAS_INTERPRET=0.  The flag is owned by the backend registry
(reliability/backend.py, DESIGN.md §12); `use_interpret` here is a shim.

Kernels:
  diag_parity     — rotate-XOR diagonal-parity encode (ECC hot loop, §IV)
  inject_scrub    — fused fault-inject → encode → syndrome → correct over
                    the packed arena (Monte-Carlo campaign hot loop, §VI)
  tmr_vote        — per-bit 2-of-3 majority voting (TMR hot loop, §V)
  crossbar_nor    — in-VMEM Min3 netlist interpreter, trials bit-packed in
                    uint32 lanes (the mMPU row-parallelism, §III); serial
                    in the gate dimension, fault-free only
  netlist_exec    — levelized netlist executor over the (L, W, 4) schedule
                    of core/scheduler.py: O(depth) wide steps, packed wire
                    state VMEM-resident across all levels, mask-based fault
                    injection bit-exact vs the scan reference (§VI-A)
  flash_attention — online-softmax blocked attention (model hot loop)
"""
from ..reliability.backend import use_interpret  # noqa: F401
