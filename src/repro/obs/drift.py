"""Error-rate drift detection: observed correction stream vs the closed
forms (DESIGN.md §15).

The closed-form model (`core.analytics.expected_scrub_rates`) predicts how
many corrections and uncorrectable blocks each scrub interval should see
for a given per-bit fault rate.  The drift detector compares the *observed*
stream against that prior over a rolling window: a store whose correction
rate runs persistently hot signals device degradation (retention drift,
developing stuck-ats — the "threats and solutions" survey's escalation
path) long before an uncorrectable block forces a restore; a rate
persistently cold signals the injection/fault plumbing silently broke.

This is the *sensor* for ROADMAP item 2's adaptive scrub controller: the
controller will shorten the scrub interval when `DriftStatus.hot` and
relax it when cold.  Here it feeds the `HeartbeatMonitor` as a health
signal (a flag + a `drift` block in `summary()`), never a hard decision —
uncorrectable blocks keep their own RESTART path.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Tuple

from ..core.analytics import expected_scrub_rates

__all__ = ["DriftDetector", "DriftStatus"]


@dataclasses.dataclass(frozen=True)
class DriftStatus:
    """Observed-vs-expected verdict over the detector's window."""

    observed_per_scrub: float
    expected_per_scrub: float
    ratio: float            # observed / expected (1.0 = on-model)
    n_scrubs: int
    drifting: bool          # outside [1/tol, tol] with enough evidence
    hot: bool               # drifting above the model (degradation signal)

    def as_dict(self) -> Dict[str, float]:
        return {"drift_observed_per_scrub": self.observed_per_scrub,
                "drift_expected_per_scrub": self.expected_per_scrub,
                "drift_ratio": self.ratio,
                "drift_n_scrubs": self.n_scrubs,
                "drifting": self.drifting,
                "drift_hot": self.hot}


class DriftDetector:
    """Rolling-window comparison of observed correction events against
    `expected_scrub_rates(p_bit, n_blocks)`.

    An *event* is one corrected word or (weighted double) one
    uncorrectable block — the same flips-observed accounting as
    `ScrubTrajectory.observed_flip_rate`.  The verdict needs
    `min_events` expected-or-observed events in the window before it can
    flag, so sparse-fault runs (expectation ~0.01 events/scrub) never
    fire spuriously.
    """

    def __init__(self, p_bit: float, n_blocks: int, *,
                 window: int = 32, tol_factor: float = 4.0,
                 min_events: float = 8.0):
        if p_bit < 0:
            raise ValueError("p_bit must be >= 0")
        self.p_bit = float(p_bit)
        self.n_blocks = int(n_blocks)
        self.window = int(window)
        self.tol_factor = float(tol_factor)
        self.min_events = float(min_events)
        exp = expected_scrub_rates(p_bit, n_blocks) if p_bit > 0 else None
        #: expected correction events per scrub under the closed form
        self.expected_per_scrub = (
            exp["corrected_per_scrub"] + 2 * exp["uncorrectable_per_scrub"]
            if exp else 0.0)
        self._events: Deque[float] = deque(maxlen=self.window)

    def observe(self, corrected: int, uncorrectable: int = 0) -> DriftStatus:
        """Ingest one scrub interval's counts and return the verdict."""
        self._events.append(float(corrected) + 2.0 * float(uncorrectable))
        return self.status()

    def evidence(self) -> float:
        """The evidence mass behind the current verdict: the larger of the
        observed and expected per-scrub event rates times the window
        occupancy — the exact quantity `status()` holds against
        ``min_events`` before it may flag.  Exposed so consumers (the
        adaptive scrub controller) can distinguish "on-model" from "too
        early to tell" without re-deriving the floor."""
        n = len(self._events)
        observed = sum(self._events) / n if n else 0.0
        return max(observed, self.expected_per_scrub) * n

    @property
    def confident(self) -> bool:
        """Has the window accumulated enough evidence for `status()` to be
        meaningful?  False during cold start (few scrubs ingested) and for
        sparse-fault runs whose expectation never clears the floor — in
        both cases ``drifting`` is structurally False, and callers making
        *decisions* (not just reading flags) must treat the verdict as
        "unknown", not "healthy"."""
        return self.evidence() >= self.min_events

    def status(self) -> DriftStatus:
        n = len(self._events)
        observed = sum(self._events) / n if n else 0.0
        expected = self.expected_per_scrub
        evidence = max(observed, expected) * n
        if expected > 0:
            ratio = observed / expected
        else:
            # no model prior: any observed corrections are unexplained
            ratio = float("inf") if observed > 0 else 1.0
        drifting = (evidence >= self.min_events
                    and not (1.0 / self.tol_factor <= ratio
                             <= self.tol_factor))
        return DriftStatus(observed_per_scrub=observed,
                           expected_per_scrub=expected,
                           ratio=ratio, n_scrubs=n, drifting=drifting,
                           hot=drifting and ratio > 1.0)

    @classmethod
    def from_trajectory(cls, trajectory, p_bit: float,
                        **kw) -> Tuple["DriftDetector", DriftStatus]:
        """Replay a `core.analytics.ScrubTrajectory` through a fresh
        detector (offline analysis of a finished run)."""
        det = cls(p_bit, trajectory.n_blocks, **kw)
        status = det.status()
        for c, u in zip(trajectory.corrected, trajectory.uncorrectable):
            status = det.observe(c, u)
        return det, status
