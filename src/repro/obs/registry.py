"""The metrics registry: one fixed, named schema for every on-device
reliability counter the system emits (DESIGN.md §15).

The paper's reliability argument is quantitative — correction rates, vote
outcomes, corruption probabilities over time — so the counters backing it
cannot stay loose dicts of ad-hoc keys.  `MetricsRegistry` pins the schema:
every metric has a name, a kind (``counter`` | ``series`` | ``gauge``) and
a docstring, and `fetch` refuses unknown names, so a telemetry dict that
reaches the host is guaranteed to be interpretable.

Device-side discipline (the PR-5 invariant, now enforced by the
transfer-guard test in tests/test_obs.py): metrics *accumulate on device*
— `zeros()` builds the int32 accumulator dict, `accumulate()` adds counter
updates / stacks series updates as device ops (jit/vmap/shard_map safe),
and `fetch()` performs ONE schema-validated `jax.device_get` over the whole
dict after timing stops.  Nothing in this module syncs implicitly.

`ScrubMetrics` is the *host-side* structured record a fetched scrub
interval condenses to — the argument `HeartbeatMonitor.record_scrub` takes
(replacing the bare-int triple) and the sample `obs.drift.DriftDetector`
consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["MetricSpec", "MetricsRegistry", "ScrubMetrics", "SCHEMA",
           "DEFAULT_REGISTRY", "fetch_telemetry"]

KINDS = ("counter", "series", "gauge")


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One named metric: ``counter`` accumulates by integer addition,
    ``series`` stacks per-step samples along axis 0, ``gauge`` holds the
    last written value."""

    name: str
    kind: str = "counter"
    doc: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"metric kind must be one of {KINDS}, "
                             f"got {self.kind!r}")


#: The fixed schema.  Names match the telemetry keys the engine and the
#: schemes have emitted since PR 5, so fetched dicts stay grep-compatible.
SCHEMA: Tuple[MetricSpec, ...] = (
    MetricSpec("ecc_corrected", "counter",
               "arena words corrected by the diagonal-parity code"),
    MetricSpec("ecc_parity_fixed", "counter",
               "parity-word (check-row) flips repaired during scrub"),
    MetricSpec("ecc_uncorrectable", "counter",
               "blocks with >= 2 flips — beyond the single-error code"),
    MetricSpec("ecc_injected", "counter",
               "bit flips injected by the fused inject+scrub kernel"),
    # write-back-on-read serving discipline (DESIGN.md §18): corrections
    # performed on the read path — pages repaired *before* the tick reads
    # them, instead of waiting for the periodic scrub — kept separate from
    # the scrub counters so the two disciplines stay attributable
    MetricSpec("ecc_read_corrected", "counter",
               "arena words corrected by write-back-on-read page repair"),
    MetricSpec("ecc_read_parity_fixed", "counter",
               "parity rows healed on the write-back-on-read path"),
    MetricSpec("ecc_read_uncorrectable", "counter",
               "uncorrectable blocks encountered on the read path"),
    MetricSpec("tmr_step_disagreements", "series",
               "per-decode-step token positions where the 3 copies differ"),
    MetricSpec("tmr_final_disagreements", "counter",
               "token positions voted on in the final sequences"),
    MetricSpec("faults_injected", "counter",
               "fault-model corruption events applied to held data copies"),
    MetricSpec("tokens_emitted", "counter",
               "tokens produced by the generation engine"),
    # mMPU cost-model projections (costmodel/, DESIGN.md §17): host-side
    # analytic gauges the engine stamps when built with cost_spec= —
    # device-normalized crossbar-cycles and switching energy per token,
    # plus the compiled event-stream length.  Gauges, not counters: they
    # describe the batch geometry, not accumulated work.
    MetricSpec("mmpu_cycles_per_token", "gauge",
               "projected mMPU occupancy cycles per emitted token"),
    MetricSpec("mmpu_energy_pj_per_token", "gauge",
               "projected mMPU switching energy (pJ) per emitted token"),
    MetricSpec("mmpu_events", "gauge",
               "compiled MmpuEvent bundles in the step's event stream"),
)


class MetricsRegistry:
    """Schema-validated registry of on-device metrics (see module doc)."""

    def __init__(self, schema: Iterable[MetricSpec] = SCHEMA):
        self._by_name: Dict[str, MetricSpec] = {}
        for spec in schema:
            if spec.name in self._by_name:
                raise ValueError(f"duplicate metric name {spec.name!r}")
            self._by_name[spec.name] = spec

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._by_name)

    def spec(self, name: str) -> MetricSpec:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(
                f"unknown metric {name!r}; the schema defines "
                f"{sorted(self._by_name)} (extend obs.registry.SCHEMA to "
                f"add metrics — ad-hoc telemetry keys are rejected)"
            ) from None

    def validate(self, telemetry: Mapping[str, Any]) -> None:
        for name in telemetry:
            self.spec(name)

    # -- device-side accumulation (jit/vmap/shard_map safe) ----------------

    def zeros(self, names: Optional[Iterable[str]] = None
              ) -> Dict[str, jax.Array]:
        """Fresh accumulator dict: int32 zero scalars for counters/gauges,
        empty (0,) int32 arrays for series."""
        out: Dict[str, jax.Array] = {}
        for name in (names if names is not None else self.names):
            spec = self.spec(name)
            out[name] = (jnp.zeros((0,), jnp.int32) if spec.kind == "series"
                         else jnp.zeros((), jnp.int32))
        return out

    def accumulate(self, metrics: Mapping[str, jax.Array],
                   updates: Mapping[str, Any]) -> Dict[str, jax.Array]:
        """Functionally fold `updates` into `metrics` — counter adds,
        series concatenation, gauge overwrite — all device ops."""
        self.validate(updates)
        out = dict(metrics)
        for name, val in updates.items():
            kind = self.spec(name).kind
            val = jnp.asarray(val)
            if kind == "series":
                val = jnp.atleast_1d(val)
                out[name] = (jnp.concatenate([out[name], val])
                             if name in out else val)
            elif kind == "gauge" or name not in out:
                out[name] = val
            else:
                out[name] = out[name] + val
        return out

    def from_report(self, report: Any,
                    injected: Optional[jax.Array] = None
                    ) -> Dict[str, jax.Array]:
        """Map a `core.reliability.ScrubReport` (device counters) onto the
        schema names; `injected` adds the inject_scrub kernel's 4th
        counter when available."""
        out = {"ecc_corrected": report.corrected,
               "ecc_parity_fixed": report.parity_fixed,
               "ecc_uncorrectable": report.uncorrectable}
        if injected is not None:
            out["ecc_injected"] = injected
        return out

    def psum(self, metrics: Mapping[str, jax.Array],
             axis_name: Any) -> Dict[str, jax.Array]:
        """Cross-shard reduce inside a `shard_map` body: counters are plain
        integer sums, so psum'd totals equal the single-device counts bit
        for bit (DESIGN.md §14)."""
        return {k: jax.lax.psum(v, axis_name) for k, v in metrics.items()}

    # -- the single host sync ----------------------------------------------

    def fetch(self, telemetry: Mapping[str, jax.Array]) -> Dict[str, Any]:
        """THE device->host transfer: schema-validate, then fetch every
        counter in one `jax.device_get` (after timing stops)."""
        self.validate(telemetry)
        return dict(zip(telemetry,
                        jax.device_get(list(telemetry.values()))))


DEFAULT_REGISTRY = MetricsRegistry()


def fetch_telemetry(telemetry: Mapping[str, jax.Array]) -> Dict[str, Any]:
    """Schema-validated single-transfer fetch against the default registry
    (the function `launch.engine` has re-exported since PR 5)."""
    return DEFAULT_REGISTRY.fetch(telemetry)


@dataclasses.dataclass(frozen=True)
class ScrubMetrics:
    """Host-side structured record of one scrub interval — what the
    monitor ingests (replacing `record_scrub`'s bare-int triple) and what
    the drift detector samples."""

    corrected: int
    parity_fixed: int = 0
    uncorrectable: int = 0
    injected: int = 0
    vote_disagreements: int = 0

    @classmethod
    def from_fetched(cls, stats: Mapping[str, Any]) -> "ScrubMetrics":
        """Build from an already-fetched telemetry dict (schema names)."""
        def get(name):
            v = stats.get(name, 0)
            return int(jnp.asarray(v).sum()) if hasattr(v, "shape") \
                else int(v)
        return cls(corrected=get("ecc_corrected"),
                   parity_fixed=get("ecc_parity_fixed"),
                   uncorrectable=get("ecc_uncorrectable"),
                   injected=get("ecc_injected"),
                   vote_disagreements=get("tmr_final_disagreements")
                   + get("tmr_step_disagreements"))
