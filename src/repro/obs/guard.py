"""The transfer guard: count device->host syncs to *enforce* the
single-transfer telemetry invariant (DESIGN.md §15).

PR 5 promised that the engine's timed generation region performs no host
syncs and `fetch_telemetry` exactly one; until now that was a convention.
`count_host_transfers()` turns it into a testable property: inside the
context every explicit host-read API is intercepted and tallied —

* ``jax.device_get`` — the telemetry fetch path (one call over N arrays
  counts as ONE sync: it is a single synchronization point, however many
  leaves it gathers);
* ``ArrayImpl.__array__`` / ``.item()`` / ``.tolist()`` — the implicit
  conversion surfaces (``np.asarray``, ``int()``/``float()`` funnels) the
  repo's host code uses.

``jax.block_until_ready`` is deliberately NOT counted: it synchronizes
with the device but moves no data — chunked generation relies on it for
latency timestamps without breaking the zero-transfer property.

Platform note: `jax.transfer_guard_device_to_host` does not fire on the
CPU backend (host-resident buffers are zero-copy views), so this ledger
hooks the Python entry points instead; on accelerator backends the two
compose (`strict=True` additionally arms jax's own guard, a no-op on
CPU).  The hook is process-global and not reentrant — test-only.
"""
from __future__ import annotations

import contextlib
import dataclasses
import traceback
from typing import Iterator, List

import jax

__all__ = ["TransferLedger", "count_host_transfers"]


@dataclasses.dataclass
class TransferLedger:
    """Tally of host syncs observed inside a `count_host_transfers` region."""

    syncs: int = 0
    sites: List[str] = dataclasses.field(default_factory=list)

    def _hit(self, api: str, keep_site: bool = True) -> None:
        self.syncs += 1
        if keep_site and len(self.sites) < 32:
            # the caller two frames up (skip the wrapper) — enough to name
            # the offender in the assertion message
            stack = traceback.extract_stack(limit=8)[:-2]
            frame = next((f for f in reversed(stack)
                          if "obs/guard" not in f.filename), None)
            self.sites.append(
                f"{api} @ {frame.filename}:{frame.lineno}" if frame else api)


@contextlib.contextmanager
def count_host_transfers(strict: bool = True) -> Iterator[TransferLedger]:
    """Context manager yielding a `TransferLedger`; every explicit host
    read inside increments it.  See module doc for what counts."""
    ledger = TransferLedger()
    arr_t = type(jax.numpy.zeros(()))     # jaxlib ArrayImpl
    orig_device_get = jax.device_get
    orig = {name: getattr(arr_t, name)
            for name in ("__array__", "item", "tolist")}
    in_device_get = [False]               # leaf reads inside one device_get
                                          # are part of that single sync

    def device_get(x):
        if not in_device_get[0]:
            ledger._hit("jax.device_get")
        in_device_get[0] = True
        try:
            # counted syncs are allowed through jax's own guard (armed on
            # accelerator backends when strict) — we tally, not forbid
            with jax.transfer_guard_device_to_host("allow"):
                return orig_device_get(x)
        finally:
            in_device_get[0] = False

    def make_wrapper(name, fn):
        def wrapper(self, *args, **kw):
            if not in_device_get[0]:
                ledger._hit(f"ArrayImpl.{name}")
            with jax.transfer_guard_device_to_host("allow"):
                return fn(self, *args, **kw)
        return wrapper

    jax.device_get = device_get
    for name, fn in orig.items():
        setattr(arr_t, name, make_wrapper(name, fn))
    guard = (jax.transfer_guard_device_to_host("disallow") if strict
             else contextlib.nullcontext())
    try:
        with guard:
            yield ledger
    finally:
        jax.device_get = orig_device_get
        for name, fn in orig.items():
            setattr(arr_t, name, fn)
