"""Span-based launch tracing: a Chrome-trace (Perfetto-loadable) timeline
plus a JSONL metrics log, with zero device syncs (DESIGN.md §15).

Spans are *host wall-time* brackets around launches — prefill, scan
chunks, scrub, vote, checkpoint, restore — recorded with
`time.perf_counter()` and a list append.  Nothing here touches a device
array, so tracing never adds a host sync to a timed region; the
transfer-guard test runs with tracing on to prove it.

    tracer = Tracer()
    with tracer.trace("prefill", batch=4):
        tok = fns["prefill"](store, batch)
        jax.block_until_ready(tok)          # sync point, not a transfer
    tracer.write_chrome("trace.json")        # load in Perfetto / chrome://tracing
    tracer.write_jsonl("metrics.jsonl")

A disabled tracer (``Tracer(enabled=False)``, or the shared `NULL_TRACER`)
makes every call a no-op so instrumented code paths cost ~nothing when
observability is off — the `obs_overhead` bench holds the difference
under 5%.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["Tracer", "NULL_TRACER"]


class Tracer:
    """Collects Chrome-trace events (complete spans, instants, counters)
    and JSONL metric records.  Thread-safe appends; write once at exit."""

    def __init__(self, enabled: bool = True, pid: int = 0):
        self.enabled = enabled
        self.pid = pid if pid else os.getpid()
        self.events: List[Dict[str, Any]] = []
        self.records: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self) -> int:
        return threading.get_ident() % 2 ** 31

    # -- event emission ----------------------------------------------------

    @contextlib.contextmanager
    def trace(self, name: str, **args: Any):
        """Span a region: emits one Chrome complete ('ph': 'X') event."""
        if not self.enabled:
            yield self
            return
        ts = self._now_us()
        try:
            yield self
        finally:
            dur = self._now_us() - ts
            with self._lock:
                self.events.append(
                    {"name": name, "ph": "X", "ts": ts, "dur": dur,
                     "pid": self.pid, "tid": self._tid(),
                     **({"args": args} if args else {})})

    def instant(self, name: str, **args: Any) -> None:
        """A zero-duration marker (heartbeats, decisions, restores)."""
        if not self.enabled:
            return
        with self._lock:
            self.events.append(
                {"name": name, "ph": "i", "s": "t", "ts": self._now_us(),
                 "pid": self.pid, "tid": self._tid(),
                 **({"args": args} if args else {})})

    def counter(self, name: str, value: float) -> None:
        """A Chrome counter track sample (step times, correction counts)."""
        if not self.enabled:
            return
        with self._lock:
            self.events.append(
                {"name": name, "ph": "C", "ts": self._now_us(),
                 "pid": self.pid, "tid": 0, "args": {name: float(value)}})

    def metrics(self, record: Dict[str, Any], kind: str = "metrics") -> None:
        """Append one structured record to the JSONL metrics log (fetched
        telemetry snapshots, latency summaries, bench rows)."""
        if not self.enabled:
            return
        with self._lock:
            self.records.append({"t_us": self._now_us(), "kind": kind,
                                 **_jsonable(record)})

    # -- output ------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace document: valid for Perfetto and
        chrome://tracing (``traceEvents`` array of phase events)."""
        with self._lock:
            return {"traceEvents": list(self.events),
                    "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def write_jsonl(self, path: str,
                    extra: Optional[Iterable[Dict[str, Any]]] = None) -> None:
        with self._lock:
            records = list(self.records)
        if extra:
            records += [_jsonable(r) for r in extra]
        with open(path, "w") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")


def _jsonable(record: Dict[str, Any]) -> Dict[str, Any]:
    """Coerce numpy/jax scalars and arrays (already fetched!) to plain
    JSON types; leaves everything else alone."""
    out = {}
    for k, v in record.items():
        if hasattr(v, "tolist"):
            v = v.tolist()
        elif hasattr(v, "item"):
            v = v.item()
        out[k] = v
    return out


#: Shared disabled tracer: instrumented code paths default to this so the
#: no-observability configuration pays only a truthiness check.
NULL_TRACER = Tracer(enabled=False)
