"""On-device telemetry subsystem (DESIGN.md §15).

One observability layer threaded through kernels, schemes, the generation
engine and the runtime:

* `MetricsRegistry` / `SCHEMA` — named, schema-validated on-device
  counters; `fetch_telemetry` is the single device->host sync.
* `Tracer` — span-based launch tracing: Chrome-trace (Perfetto) JSON plus
  a JSONL metrics log, zero device syncs.
* `LatencyTimeline` / `Histogram` — TTFT/TPOT latency tails from
  per-chunk host timestamps.
* `DriftDetector` — observed correction rates vs the closed-form model,
  the health signal feeding `HeartbeatMonitor`.
* `count_host_transfers` — the transfer guard that *enforces* the
  single-sync invariant in tests.
"""
from .drift import DriftDetector, DriftStatus
from .guard import TransferLedger, count_host_transfers
from .latency import Histogram, LatencyTimeline
from .registry import (DEFAULT_REGISTRY, SCHEMA, MetricSpec, MetricsRegistry,
                       ScrubMetrics, fetch_telemetry)
from .trace import NULL_TRACER, Tracer

__all__ = [
    "DEFAULT_REGISTRY", "SCHEMA", "MetricSpec", "MetricsRegistry",
    "ScrubMetrics", "fetch_telemetry",
    "Tracer", "NULL_TRACER",
    "Histogram", "LatencyTimeline",
    "DriftDetector", "DriftStatus",
    "TransferLedger", "count_host_transfers",
]
