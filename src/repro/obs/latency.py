"""Latency tails: TTFT/TPOT histograms from per-chunk host timestamps
(DESIGN.md §15).

The scan engine runs a whole generation as one launch, which is optimal
for throughput but leaves the host blind between prefill and the last
token.  `GenerationEngine.generate_chunked` splits the scan into compiled
chunk launches and marks a `LatencyTimeline` after each one completes —
a `block_until_ready` (a sync point, NOT a device->host data transfer;
the transfer-guard test counts it as zero) followed by a
`time.perf_counter()` read.  From the marks:

* **TTFT** — the first mark (prefill + first token available);
* **TPOT** — per-token-position deltas from the remaining marks, one
  sample per token position so chunk sizes weight correctly;
* `Histogram` — p50/p95/p99 tails over any sample stream, shared by
  `serve_bench`'s latency rows and `serve --chunk`'s report.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Histogram", "LatencyTimeline"]


class Histogram:
    """A sample accumulator with percentile tails.  Keeps raw samples
    (serving horizons are small — thousands of tokens, not billions); the
    summary reports p50/p95/p99, mean, and extremes."""

    def __init__(self, samples: Optional[Sequence[float]] = None):
        self._samples: List[float] = (
            [float(v) for v in samples] if samples is not None else [])

    def record(self, value: float) -> None:
        self._samples.append(float(value))

    def extend(self, values: Sequence[float]) -> None:
        self._samples.extend(float(v) for v in values)

    def merge(self, other: "Histogram") -> "Histogram":
        return Histogram(self._samples + other._samples)

    def __len__(self) -> int:
        return len(self._samples)

    @property
    def samples(self) -> np.ndarray:
        return np.asarray(self._samples, dtype=np.float64)

    def percentile(self, q: float) -> float:
        if not self._samples:
            return float("nan")
        return float(np.percentile(self.samples, q))

    def summary(self) -> Dict[str, float]:
        if not self._samples:
            return {"count": 0}
        s = self.samples
        return {"count": len(s), "mean": float(s.mean()),
                "min": float(s.min()), "max": float(s.max()),
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


@dataclasses.dataclass
class LatencyTimeline:
    """Per-chunk completion timestamps for one generation.

    `begin()` starts the clock, `mark(tokens)` records that `tokens` more
    token positions became available (host wall time, no transfers).
    """

    start: Optional[float] = None
    marks: List[tuple] = dataclasses.field(default_factory=list)

    def begin(self) -> None:
        self.start = time.perf_counter()
        self.marks = []

    def mark(self, tokens: int) -> None:
        if self.start is None:
            raise RuntimeError("LatencyTimeline.mark() before begin()")
        self.marks.append((time.perf_counter(), int(tokens)))

    # -- derived tails -----------------------------------------------------

    @property
    def ttft_s(self) -> float:
        """Time to first token: start -> first mark."""
        if self.start is None or not self.marks:
            return float("nan")
        return self.marks[0][0] - self.start

    def tpot_samples(self) -> np.ndarray:
        """Per-token-position seconds after the first mark: each chunk of
        n tokens taking dt contributes n samples of dt/n, so percentiles
        weight by tokens, not by launches."""
        out: List[float] = []
        for (t_prev, _), (t, n) in zip(self.marks, self.marks[1:]):
            if n > 0:
                out.extend([(t - t_prev) / n] * n)
        return np.asarray(out, dtype=np.float64)

    def total_s(self) -> float:
        if self.start is None or not self.marks:
            return float("nan")
        return self.marks[-1][0] - self.start

    def tokens(self) -> int:
        return sum(n for _, n in self.marks)

    def histograms(self) -> Dict[str, Histogram]:
        return {"ttft_s": Histogram([self.ttft_s]),
                "tpot_s": Histogram(self.tpot_samples())}

    def summary(self) -> Dict[str, float]:
        tpot = Histogram(self.tpot_samples())
        out = {"ttft_s": self.ttft_s, "total_s": self.total_s(),
               "tokens": self.tokens()}
        for k, v in tpot.summary().items():
            out[f"tpot_{k}" if not k.startswith("tpot") else k] = v
        return out
