"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32 == MHA) d_ff=8192 vocab=32064.
kv == heads, so the KV cache shards over heads (not sequence).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32064,
    act="swiglu",
    tie_embeddings=False,
)

# 32 kv heads divide the model axis: prefer head-sharded decode caches.
RULES_OVERRIDES = {"kv_seq": (), "kv_heads": ("model",)}
