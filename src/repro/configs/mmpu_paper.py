"""Paper-default mMPU device specs for the cost model (DESIGN.md §17).

Not a model architecture — this module holds the :class:`DeviceSpec`
values the cost model defaults to, with one citation per number:

* 1024x1024 crossbar, 64 arrays — the source paper's evaluation
  configuration (arXiv:2109.09687 §III uses 1024-row arrays; fleet
  size matches the companion ECC paper's multi-array setup).
* 1 GHz device cycle — MAGIC NOR switching completes in ~1.1 ns with
  the standard TEAM-model fitting (Talati et al., TVLSI 2016); the
  canonical mMPU literature rounds to a 1 ns cycle.
* 1-cycle init/NOR/NOT, 1-cycle Min3, 2-cycle XOR — MAGIC executes
  NOR (and the 1-input NOT case) in one cycle after a one-cycle output
  init; FELIX adds single-cycle Min3 and a 2-cycle XOR (Gupta et al.,
  ICCAD 2018) — the exact primitive set the repo's netlists and the
  diagonal-parity ECC of Leitersdorf et al. (arXiv:2105.04212) price
  against.
* energies — per-cell switching energy: ~6.4 fJ per MAGIC NOR
  evaluation (Talati et al.), scaled for the 1-input (NOT) and
  3-input (Min3) cases, 2x NOR for the 2-cycle XOR, ~0.5 fJ sensing
  per read, ~25 fJ SET/RESET per written cell, ~1 fJ init RESET —
  fJ-scale numbers standard across the memristive-logic literature.

Override any field per experiment:

    get_device("paper").replace(rows=512, clock_hz=5e8)
"""
from __future__ import annotations

from typing import Dict

from ..costmodel.device import DeviceSpec

PAPER_MMPU = DeviceSpec(
    name="paper-mmpu",
    rows=1024, cols=1024, n_crossbars=64,
    clock_hz=1.0e9,
    init_cycles=1, nor_cycles=1, not_cycles=1, min3_cycles=1,
    xor_cycles=2, read_cycles=1, write_cycles=1,
    init_energy_pj=0.0010, nor_energy_pj=0.0064, not_energy_pj=0.0032,
    min3_energy_pj=0.0096, xor_energy_pj=0.0128,
    read_energy_pj=0.0005, write_energy_pj=0.0250,
)

#: MAGIC-only device (no FELIX extension): Min3 falls back to the
#: 4-gate NOR decomposition and XOR to a 5-cycle NOR tree — the
#: counterfactual the ECC paper's latency claims are measured against.
MAGIC_NOR_ONLY = PAPER_MMPU.replace(
    name="magic-nor-only", min3_cycles=4, xor_cycles=5,
    min3_energy_pj=4 * PAPER_MMPU.nor_energy_pj,
    xor_energy_pj=5 * PAPER_MMPU.nor_energy_pj)

DEVICES: Dict[str, DeviceSpec] = {
    "paper": PAPER_MMPU,
    "magic-nor-only": MAGIC_NOR_ONLY,
}


def get_device(name: str = "paper") -> DeviceSpec:
    try:
        return DEVICES[name]
    except KeyError:
        raise KeyError(f"unknown mMPU device {name!r}; "
                       f"available: {sorted(DEVICES)}") from None
