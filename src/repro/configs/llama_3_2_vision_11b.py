"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; one gated
cross-attention layer per 5 layers (8 blocks).  The vision tower is a STUB:
input_specs() provides precomputed patch embeddings (B, 1600, 4096).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=128256,
    act="swiglu",
    rope_theta=500000.0,
    cross_attn_every=5,
    vis_tokens=1600,
    vis_dim=4096,
    tie_embeddings=False,
)
