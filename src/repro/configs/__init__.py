"""Architecture registry: one module per assigned architecture.

Each module defines CONFIG (exact published shape) and optionally
RULES_OVERRIDES (per-arch sharding-rule tweaks) and SHAPES (supported
dry-run shapes).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "deepseek_67b",
    "phi3_mini_3p8b",
    "nemotron_4_15b",
    "qwen2_5_14b",
    "llama4_maverick_400b_a17b",
    "phi3_5_moe_42b_a6p6b",
    "mamba2_130m",
    "llama_3_2_vision_11b",
    "recurrentgemma_2b",
    "seamless_m4t_medium",
]

#: canonical external ids (``--arch <id>``)
ALIASES: Dict[str, str] = {
    "deepseek-67b": "deepseek_67b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6p6b",
    "mamba2-130m": "mamba2_130m",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def get_config(name: str) -> ModelConfig:
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    m = importlib.import_module(f".{mod}", __package__)
    return m.CONFIG


def get_rules_overrides(name: str, serve: bool = False) -> dict:
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    m = importlib.import_module(f".{mod}", __package__)
    out = dict(getattr(m, "RULES_OVERRIDES", {}))
    if serve:
        out.update(getattr(m, "SERVE_RULES_OVERRIDES", {}))
    return out


#: defaults for training cells; config modules override via TRAIN_POLICY
DEFAULT_TRAIN_POLICY = {
    "microbatches": 16,        # gradient accumulation slices of the global batch
    "param_dtype": "float32",
    "opt_dtype": "float32",
    "grad_dtype": "float32",   # gradient-accumulator dtype
}


def get_train_policy(name: str) -> dict:
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    m = importlib.import_module(f".{mod}", __package__)
    return {**DEFAULT_TRAIN_POLICY, **getattr(m, "TRAIN_POLICY", {})}


def list_archs() -> List[str]:
    return list(ALIASES.keys())
