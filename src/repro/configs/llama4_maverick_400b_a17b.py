"""llama4-maverick-400b-a17b [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 routing + a shared expert, MoE on every second layer (interleaved,
the Llama-4 design — 24 x 128 x 126M expert params ~ 386B + dense ~ 400B
total, 17B active).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=8192,
    vocab=202048,
    act="swiglu",
    moe_experts=128,
    moe_topk=1,
    moe_every=2,
    moe_dff=8192,
    moe_shared_expert=True,
    tie_embeddings=False,
)

# 400B params cannot hold fp32 Adam state in one 4TB pod: train with bf16
# parameters and bf16 moments (stochastic-rounding-style recipe).
TRAIN_POLICY = {"microbatches": 16, "param_dtype": "bfloat16",
                "opt_dtype": "bfloat16", "grad_dtype": "bfloat16"}

# Serving layout (§Perf hillclimb): stationary expert weights — experts
# sharded over the DATA axis, expert FFN over MODEL, d_model replicated.
# The default FSDP layout all-gathers 4.1 GiB/dev of expert weights per
# decoded token; this layout moves only the (tiny) token dispatch buffers:
# link traffic 4.08 -> 1.16 GB/dev per step (3.5x).
SERVE_RULES_OVERRIDES = {"model_dim": (), "expert": ("data",), "ff": ("model",)}
