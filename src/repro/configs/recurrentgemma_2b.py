"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2
recurrent [arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 (GeGLU), vocab=256000,
lru_width=2560, local window 2048.  Pattern (R, R, A) tiled; remainder RR.
Sub-quadratic (window-bounded attention): runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256000,
    act="geglu",
    layer_pattern=("R", "R", "A"),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    tie_embeddings=True,
)
