"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=6400,
    vocab=32064,
    act="swiglu",
    moe_experts=16,
    moe_topk=2,
    moe_dff=6400,
    moe_shared_expert=False,
    tie_embeddings=False,
)
