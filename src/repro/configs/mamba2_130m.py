"""mamba2-130m [ssm] — SSD state-space duality [arXiv:2405.21060].

24L d_model=768 (attention-free) vocab=50280, ssm_state=128,
headdim 64, expand 2 (d_inner 1536, 24 SSD heads).
Sub-quadratic: runs the long_500k shape.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,      # attention-free; SSD heads derived from d_inner/headdim
    n_kv=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)

# 130M params: every weight fits replicated (0.26 GB bf16).  The default
# TP/FSDP rules only generate resharding traffic here because the fused
# in_proj width (3352) does not divide the model axis while the conv dim
# does — mixed sharded/replicated layouts cost all-gathers with zero
# compute win.  Pure data parallelism: zero forward collectives.
RULES_OVERRIDES = {"ff": (), "model_dim": ()}
