"""seamless-m4t-medium [audio] — encoder-decoder, multimodal
[arXiv:2308.11596; hf].

12L encoder + 12L decoder, d_model=1024 16H (kv=16 == MHA) d_ff=4096,
vocab=256206.  The audio frontend (fbank -> conformer features) is a STUB:
input_specs() provides precomputed frame embeddings (B, T_frames, 1024).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,       # decoder layers
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=256206,
    act="gelu",
    audio_frontend=True,
    tie_embeddings=False,
)

# 16 kv heads divide the model axis: prefer head-sharded decode caches.
RULES_OVERRIDES = {"kv_seq": (), "kv_heads": ("model",)}
