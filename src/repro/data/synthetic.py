"""Deterministic synthetic token pipeline.

A hash-based stream (splitmix-style counter hashing) so that (a) every data-
parallel rank reads a disjoint deterministic shard without coordination,
(b) restarts resume exactly from the step counter (fault tolerance without a
data-state checkpoint), and (c) the stream has enough structure for the loss
to fall (a learnable n-gram-ish mixture rather than pure noise).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ModelConfig
from ..models.params import Spec

__all__ = ["SyntheticLM", "make_batch_specs"]


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic LM batches: tokens[t+1] depends on tokens[t] (Markov
    structure a model can learn), seeded per (step, rank)."""

    vocab: int
    seq_len: int
    batch_per_rank: int
    rank: int = 0
    world: int = 1
    seed: int = 1234

    def batch_at(self, step: int) -> np.ndarray:
        B, S = self.batch_per_rank, self.seq_len
        ctr = (np.uint64(self.seed) + np.uint64(step) * np.uint64(self.world)
               + np.uint64(self.rank))
        base = np.arange(B * S, dtype=np.uint64).reshape(B, S)
        h = _splitmix64(base + ctr * np.uint64(0x51ED2701))
        noise = (h % np.uint64(self.vocab)).astype(np.int64)
        # Markov backbone: x[t+1] = (a * x[t] + c) mod V with rare resets
        out = np.empty((B, S), np.int64)
        out[:, 0] = noise[:, 0]
        a, c = 31, 17
        reset = (h % np.uint64(13)) == 0
        for t in range(1, S):
            nxt = (a * out[:, t - 1] + c) % self.vocab
            out[:, t] = np.where(reset[:, t], noise[:, t], nxt)
        return out.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int,
                     mem_len: int = 0) -> Dict[str, Spec]:
    """Spec tree for one training batch (used by dry-run input_specs)."""
    specs = {"tokens": Spec((global_batch, seq_len), ("batch", "seq"), dtype="int32")}
    if cfg.family == "vlm":
        specs["vis_emb"] = Spec((global_batch, mem_len or cfg.vis_tokens,
                                 cfg.vis_dim), ("batch", None, None))
    if cfg.family == "encdec":
        specs["enc_emb"] = Spec((global_batch, mem_len or seq_len,
                                 cfg.d_model), ("batch", None, "model_dim"))
    return specs
