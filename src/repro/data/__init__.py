from .synthetic import SyntheticLM, make_batch_specs
from .loader import Prefetcher, ShardedLoader

__all__ = ["SyntheticLM", "make_batch_specs", "Prefetcher", "ShardedLoader"]
