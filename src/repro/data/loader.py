"""Background prefetch + per-rank sharded loading."""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

__all__ = ["Prefetcher", "ShardedLoader"]


class Prefetcher:
    """Prefetch batches on a background thread (overlaps host data work with
    device compute — the CPU-side analogue of compute/comm overlap)."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()

        def run():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:  # surfaced on next()
                self._err = e
            finally:
                self._q.put(None)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class ShardedLoader:
    """Wraps a per-rank batch source into globally-consistent device arrays.

    In multi-host production each process feeds its addressable shard
    (jax.make_array_from_process_local_data); in this single-process harness
    it simply stacks the per-rank shards."""

    def __init__(self, make_source: Callable[[int, int], Any], world: int,
                 to_device: bool = True):
        self.sources = [make_source(r, world) for r in range(world)]
        self.world = world
        self.to_device = to_device

    def batch_at(self, step: int) -> np.ndarray:
        shards = [s.batch_at(step) for s in self.sources]
        return np.concatenate(shards, axis=0)
