"""Hardware cost projections: per-scheme mMPU cycles/energy per token
(costmodel/, DESIGN.md §17) plus the coverage-vs-cycle-overhead frontier.

For every `standard_grid()` scheme, compile one generation step into an
MmpuEvent stream (weight reads + in-memory MAC kernel + the scheme's
redundancy traffic) and fold it under the paper-default DeviceSpec:

* ``cycles_per_token`` — device-normalized occupancy crossbar-cycles;
  machine-INDEPENDENT (pure arithmetic over static shapes), guarded
  directly by check_regression (kind 'model', lower is better);
* ``energy_pj_per_token`` — switching energy, same guarantee;
* ``overhead_x`` — cycles relative to `unprotected`; the bench *asserts*
  the acceptance ordering off < ecc < tmr-* < ecc+tmr and that it agrees
  with each scheme's analytical `overhead()` CostReport;
* ``coverage`` — 1 - p_corrupt(scheme)/p_corrupt(off) from the
  `core.analytics` closed forms at a reference exposure: the frontier's
  reliability axis.

The netlist rows price the fixed-point multiplier schedule itself
(`lower_schedule`), and the vmap row times the vectorized grid fold.
"""
from __future__ import annotations

import os
import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import numpy as np

from repro import costmodel as cm
from repro.configs import get_config
from repro.configs.mmpu_paper import get_device
from repro.core import analytics, multpim, scheduler
from repro.reliability.scheme import standard_grid

#: reference exposure for the coverage axis (per-bit access corruption
#: probability and batches of exposure — Fig. 5's regime)
P_INPUT, T_BATCHES = 1e-5, 100.0


def _coverage(name: str) -> float:
    """1 - p_corrupt(scheme)/p_corrupt(off) from the closed forms."""
    p_off = float(analytics.weight_corruption_baseline(P_INPUT, T_BATCHES))
    p_ecc = float(analytics.weight_corruption_ecc(P_INPUT, T_BATCHES))
    # Hsiao SEC-DED corrects per WORD: a double flip in a 32-word block
    # (diag parity's failure mode, prob ~p_ecc) only defeats it when both
    # flips land in the same 32-bit word — 31/1023 of uniform pairs —
    # and even those are *detected* (restore path), never silent
    p_hsiao = p_ecc * 31.0 / 1023.0

    def vote(p):       # voted copy fails when >= 2 of 3 copies fail
        return 3 * p * p * (1 - p) + p ** 3

    p = {"unprotected": p_off, "ecc": p_ecc, "hsiao": p_hsiao}.get(name)
    if p is None:
        if name.startswith("hsiao+"):
            p = vote(p_hsiao)
        elif name.startswith("ecc+"):
            p = vote(p_ecc)
        else:
            p = vote(p_off)
    return 1.0 - p / p_off


def run() -> list:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    spec = get_device("paper")
    cfg = get_config("phi3-mini-3.8b")
    if smoke:
        cfg = cfg.smoke()
    mac_bits = 8 if smoke else 32
    profile = cm.StepProfile.from_model_config(cfg, batch=4,
                                               mac_bits=mac_bits)

    rows = []
    t0 = time.time()
    grid = standard_grid(include_hsiao=True)
    costs = cm.evaluate_grid(grid, profile, spec)
    grid_us = (time.time() - t0) * 1e6

    # determinism: a second compile+fold must be bit-identical
    again = cm.evaluate_grid(standard_grid(include_hsiao=True), profile,
                             spec)
    for name, c in costs.items():
        assert (c.occupancy_cycles, c.energy_pj) == \
            (again[name].occupancy_cycles, again[name].energy_pj), \
            f"non-deterministic cost for {name}"

    off = costs["unprotected"].cycles_per_token
    for name, c in costs.items():
        over = c.cycles_per_token / off
        rows.append((f"mmpu_cost.{name}", 0.0,
                     f"cycles_per_token={c.cycles_per_token:.6g} "
                     f"energy_pj_per_token={c.energy_pj_per_token:.6g} "
                     f"overhead_x={over:.4f} coverage={_coverage(name):.6f} "
                     f"events={c.n_events}"))

    # acceptance ordering: off < every arena code < every tmr-* < every
    # joint config, and the event streams must agree with the analytical
    # overhead() ordering (the code zoo slots between off and TMR)
    cyc = {n: c.cycles_per_token for n, c in costs.items()}
    eccs = [cyc["ecc"], cyc["hsiao"]]
    tmrs = [v for n, v in cyc.items()
            if n.startswith("tmr-")]
    joint = [v for n, v in cyc.items() if "+" in n]
    ok = (cyc["unprotected"] < min(eccs) <= max(eccs) < min(tmrs)
          and max(tmrs) < min(joint))
    assert ok, f"scheme cost ordering violated: {cyc}"
    occ = {s.name: s.overhead().latency_x * s.overhead().area_x
           / s.overhead().throughput_x
           for s in standard_grid(include_hsiao=True)}
    order_events = sorted(cyc, key=cyc.get)
    order_closed = sorted(occ, key=lambda n: (occ[n], cyc[n]))
    assert order_events == order_closed, (order_events, order_closed)
    rows.append(("mmpu_cost.ordering", 0.0,
                 "ok=" + ">".join(sorted(cyc, key=cyc.get, reverse=True))))

    # netlist path: price the multiplier schedule itself (one crossbar,
    # column-parallel trials), cross-checking levels vs issue counts
    sch = scheduler.schedule(multpim.multiplier_netlist(mac_bits))
    stream = cm.lower_schedule(sch, spec, trials=spec.cols,
                               n_outputs=2 * mac_bits)
    c = cm.fold(stream, spec, tokens=spec.cols)
    issues = int(sch.issue_counts(spec.rows).sum())
    rows.append((f"mmpu_cost.netlist_mult{mac_bits}", 0.0,
                 f"cycles_per_token={c.cycles_per_token:.6g} "
                 f"energy_pj_per_token={c.energy_pj_per_token:.6g} "
                 f"levels={sch.n_levels} gates={sch.n_gates} "
                 f"issues={issues} events={c.n_events}"))

    rows.append(("mmpu_cost.grid_fold", grid_us,
                 f"schemes={len(costs)} vmapped_fold=1"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
