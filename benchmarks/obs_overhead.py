"""Observability overhead: generation with telemetry/tracing ON vs OFF
(DESIGN.md §15).

The subsystem's contract is that observing a run must not slow it down:
metric counters ride inside the compiled launches (no extra device->host
syncs — tests/test_obs.py proves the count), spans and latency marks are
a few host-side ``perf_counter`` calls per *chunk* launch, not per token.
This bench measures the end-to-end cost of that contract on the
chunk-compiled engine:

* ``t_off`` — ``generate_chunked`` with the NULL_TRACER (spans compile to
  no-ops, only the timeline's per-chunk marks remain);
* ``t_on``  — the same call under an enabled ``Tracer`` that records a
  span per launch plus a metrics record per run.

``telemetry_efficiency = t_off / t_on`` is a machine-independent
higher-better ratio guarded by check_regression (~1.0 expected; the
acceptance bar is <= 5% overhead, i.e. >= 0.95).  Both sides are
min-over-repeats on the same engine/store so contention noise cancels.

Run: PYTHONPATH=src python -m benchmarks.run --only obs_overhead --smoke
"""
from __future__ import annotations

import os
import time

try:
    from . import _path  # noqa: F401
except ImportError:
    import _path  # noqa: F401

import jax

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _bench_pair(fn_a, fn_b, repeats: int):
    """(min_a, min_b) seconds per call, measured INTERLEAVED: a, b, a, b…
    after one warmup each.  The two sides of the efficiency ratio see the
    same machine-load drift, so it cancels from their minima — two
    back-to-back independent mins would fold the drift into the ratio."""
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def run():
    from repro.configs import get_config
    from repro.launch.engine import GenerationEngine
    from repro.models import params as P
    from repro.models import transformer as T
    from repro.obs import NULL_TRACER, Tracer
    from repro.reliability import parse_scheme

    key = jax.random.PRNGKey(0)
    repeats = 9 if SMOKE else 11
    cfg = get_config("phi3-mini-3.8b").smoke()
    params = P.materialize(key, T.model_specs(cfg))
    B, PROMPT, GEN, CHUNK = (2, 16, 16, 4) if SMOKE else (4, 32, 48, 8)
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)}
    n_tok = B * GEN

    rows = []
    for spec in ("off", "ecc+tmr-parallel"):
        eng = GenerationEngine(cfg, parse_scheme(spec), gen=GEN,
                               execution="scan")
        store, _ = eng.prepare(params, key=key)
        # a fresh enabled tracer per call: the recording path, including
        # the event-list appends, is what we are pricing
        t_off, t_on = _bench_pair(
            lambda: eng.generate_chunked(store, batch, chunk=CHUNK,
                                         tracer=NULL_TRACER)[0],
            lambda: eng.generate_chunked(store, batch, chunk=CHUNK,
                                         tracer=Tracer(enabled=True))[0],
            repeats)
        name = spec.replace("ecc+tmr-parallel", "compose").replace("-", "_")
        rows.append((
            f"obs.overhead_{name}_b{B}_g{GEN}", t_on / n_tok * 1e6,
            f"tok_s={n_tok / t_on:.5g} "
            f"telemetry_efficiency={t_off / t_on:.3f}x "
            f"overhead_pct={(t_on / t_off - 1.0) * 100:.2f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
