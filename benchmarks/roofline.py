"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Terms per (arch x shape x mesh) cell:
  compute_t    = FLOPs_per_device / peak_flops
  memory_t     = HLO_bytes_per_device / hbm_bw
  collective_t = ring-model link traffic per device / link_bw

FLOPs source: XLA's HloCostAnalysis visits while-loop bodies ONCE, so
cost_analysis() *undercounts* scanned programs by the trip count (layers x
microbatches) — measured 500x low on deepseek-67b train.  We therefore
report BOTH the raw HLO FLOPs and an analytic MODEL_FLOPS (6*N*D for
training, 2*N*D for prefill, 2*N_active per token for decode, + attention
terms), use the analytic number for the compute term, and report the ratio
as required.  Bytes and collectives come from the compiled per-device
artifact directly (bytes_accessed has the same while-body caveat; for
scanned programs we scale the dominant stream analytically where noted).
"""
from __future__ import annotations

import json
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s/link

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

from repro import costmodel as cm  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.mmpu_paper import get_device  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402

#: mMPU projection device (DESIGN.md §17) — the roofline's second axis:
#: the same step priced in crossbar cycles/energy instead of TPU seconds
MMPU_DEV = get_device("paper")


def param_count(cfg: ModelConfig) -> Dict[str, float]:
    """Total and active parameter counts (analytic, matches model_specs)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    gated = cfg.act in ("swiglu", "geglu")
    attn = d * H * hd + d * 2 * KV * hd + H * hd * d
    mlp = d * cfg.d_ff * (2 if gated else 1) + cfg.d_ff * d
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "dense":
        total = active = L * (attn + mlp)
    elif cfg.family == "moe":
        f = cfg.moe_dff or cfg.d_ff
        expert = d * f * (2 if gated else 1) + f * d
        n_moe = L // cfg.moe_every
        n_dense = L - n_moe
        moe_per = cfg.moe_experts * expert + d * cfg.moe_experts \
            + (mlp if cfg.moe_shared_expert else 0)
        act_per = cfg.moe_topk * expert + d * cfg.moe_experts \
            + (mlp if cfg.moe_shared_expert else 0)
        total = L * attn + n_dense * mlp + n_moe * moe_per
        active = L * attn + n_dense * mlp + n_moe * act_per
    elif cfg.family == "ssm":
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        blk = d * (2 * di + 2 * n + h) + cfg.conv_width * (di + 2 * n) + di * d
        total = active = L * blk
    elif cfg.family == "hybrid":
        w = cfg.lru_width or d
        rg = 2 * d * w + 2 * w * w + w * d + cfg.conv_width * w
        pat = cfg.layer_pattern
        tiles = L // len(pat)
        rem = pat[: L % len(pat)]
        n_r = tiles * pat.count("R") + rem.count("R")
        n_a = tiles * pat.count("A") + rem.count("A")
        total = active = n_r * (rg + mlp) + n_a * (attn + mlp)
    elif cfg.family == "vlm":
        nb = L // cfg.cross_attn_every
        xattn = d * H * hd + cfg.vis_dim * 2 * KV * hd + H * hd * d
        total = active = (L - nb) * (attn + mlp) + nb * (xattn + mlp)
    elif cfg.family == "encdec":
        xattn = d * H * hd + d * 2 * KV * hd + H * hd * d
        total = active = cfg.enc_layers * (attn + mlp) + L * (attn + xattn + mlp)
    else:
        raise ValueError(cfg.family)
    return {"total": total + emb, "active": active + emb,
            "body": total, "active_body": active}


def model_flops(cfg: ModelConfig, kind: str, seq: int, batch: int) -> float:
    """Analytic MODEL_FLOPS for the whole step (all devices).

    train: 6 * N_active_body * tokens (+ attention 12*L*S^2*d_attn_head_dim
    factor); prefill: 2 * N_active; decode: 2 * N_active per token +
    attention score/value reads."""
    pc = param_count(cfg)
    tokens = batch * seq
    H, hd = cfg.n_heads, cfg.head_dim
    # attention pair FLOPs (qk + pv), causal ~ S^2/2 pairs, fwd only
    n_attn_layers = {
        "dense": cfg.n_layers, "moe": cfg.n_layers, "ssm": 0,
        "hybrid": cfg.n_layers // 3, "vlm": cfg.n_layers,
        "encdec": cfg.enc_layers + 2 * cfg.n_layers,
    }[cfg.family]
    if kind == "train":
        body = 6.0 * pc["active"] * tokens
        attn = 3 * 2.0 * batch * (seq * seq / 2) * H * hd * 2 * n_attn_layers
        return body + attn
    if kind == "prefill":
        body = 2.0 * pc["active"] * tokens
        attn = 2.0 * batch * (seq * seq / 2) * H * hd * 2 * n_attn_layers
        return body + attn
    # decode: one token with a seq-length cache
    window = cfg.local_window or seq
    eff = min(seq, window) if cfg.family == "hybrid" else seq
    if cfg.family == "ssm":
        eff = 0
    body = 2.0 * pc["active"] * batch
    attn = 2.0 * batch * eff * cfg.n_kv * hd * 2 * n_attn_layers
    return body + attn


def analyze(rec: dict) -> Optional[dict]:
    if "skipped" in rec or "error" in rec:
        return None
    cfg = get_config(rec["arch"])
    dev = rec["devices"]
    mf = model_flops(cfg, rec["kind"], rec["seq"], rec["batch"]) / dev
    hlo_f = rec["flops"]
    compute_t = mf / PEAK_FLOPS
    # bytes: per-device HLO bytes; for scanned programs the dominant streams
    # (weights + cache) are re-derived analytically below for the decode
    # kind, where bytes ~ params + cache per token.
    memory_t = rec["bytes_accessed"] / HBM_BW
    if rec["kind"] == "decode":
        pc = param_count(cfg)
        cache_bytes = rec["arg_bytes"]  # donated cache + params per device
        memory_t = max(memory_t, cache_bytes / HBM_BW)
    coll = rec["collectives"]["link_traffic_bytes"]
    collective_t = coll / LINK_BW
    dominant = max(("compute", compute_t), ("memory", memory_t),
                   ("collective", collective_t), key=lambda kv: kv[1])[0]
    total_overlap = max(compute_t, memory_t, collective_t)
    total_serial = compute_t + memory_t + collective_t
    # mMPU projection: whole-step MACs (= total FLOPs / 2) over the
    # active weights, priced under the paper-default DeviceSpec — the
    # hardware-real counterpart of the TPU terms above
    pc_all = param_count(cfg)
    tokens = rec["batch"] * (1 if rec["kind"] == "decode" else rec["seq"])
    mmpu = cm.project_macs(int(mf * dev / 2), int(pc_all["active"]),
                           MMPU_DEV, tokens=max(1, tokens))
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "devices": dev,
        "compute_t": compute_t, "memory_t": memory_t,
        "collective_t": collective_t, "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": hlo_f,
        "flops_ratio": (mf / hlo_f) if hlo_f else float("inf"),
        # fraction of the compute roofline achieved assuming perfect overlap
        # (step = max of terms) / no overlap (step = sum) — the score band
        "roofline_frac_overlap": compute_t / total_overlap if total_overlap else 0.0,
        "roofline_frac_serial": compute_t / total_serial if total_serial else 0.0,
        "peak_gib": rec["peak_bytes"] / 2**30,
        "collective_bytes_dev": coll,
        "step_time_est_s": total_overlap,
        "mmpu_cycles_per_token": mmpu.cycles_per_token,
        "mmpu_energy_pj_per_token": mmpu.energy_pj_per_token,
        "mmpu_step_t": mmpu.latency_s,
        "mmpu_vs_tpu": (mmpu.latency_s / total_overlap
                        if total_overlap else float("inf")),
    }


def load(path: str) -> Dict[tuple, dict]:
    latest = {}
    for line in open(path):
        r = json.loads(line)
        latest[(r["arch"], r["shape"], r.get("multi_pod"))] = r
    return latest


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.jsonl"
    rows = []
    for rec in load(path).values():
        a = analyze(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = (f"{'arch':26s} {'shape':11s} {'mesh':8s} {'comp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'dom':>10s} {'MF/HLO':>8s} {'rf_ser%':>8s} {'GiB':>6s} "
           f"{'mmpu_ms':>9s} {'mmpu_uJ/tok':>11s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:11s} {r['mesh']:8s} "
              f"{r['compute_t']*1e3:8.2f} {r['memory_t']*1e3:8.2f} "
              f"{r['collective_t']*1e3:8.2f} {r['dominant']:>10s} "
              f"{r['flops_ratio']:8.1f} {100*r['roofline_frac_serial']:7.1f}% "
              f"{r['peak_gib']:6.2f} "
              f"{r['mmpu_step_t']*1e3:9.1f} "
              f"{r['mmpu_energy_pj_per_token']*1e-6:11.2f}")
    out = path.replace(".jsonl", "_roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
