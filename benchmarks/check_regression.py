"""CI bench regression guard: compare a fresh smoke `bench.json` against
the committed `benchmarks/baseline.json`.

Rows from the guarded modules (netlist_bench, campaign_mc, serve_bench,
serve_load, obs_overhead, mmpu_cost) are compared by name on their
throughput signals:

* ratio signals from `derived` (``speedup_vs_scan=`` for the netlist
  engines, ``speedup_vs_loop=`` / ``tmr_amortization=`` for the serving
  engine, ``goodput_gain=`` for the continuous-batching scheduler,
  ``telemetry_efficiency=`` for the observability overhead) are
  machine-INDEPENDENT and compared directly — they catch
  engine-relative regressions regardless of how fast the CI runner is;
* model signals (``cycles_per_token=`` / ``energy_pj_per_token=`` from
  the mMPU cost projections) are machine-independent too but LOWER is
  better: they guard the hardware-grounded cost axis directly;
* absolute signals (``gate_evals_per_s=`` / ``tok_s=`` rates,
  ``ttft_p50/p99=`` / ``tpot_p50/p99=`` latency tails,
  ``us_per_call`` timings >= 10µs, ``*.total_wall_s`` seconds) are first
  normalized by the *median* worse-than-baseline factor across all
  absolute rows — the machine-speed factor between the baseline box and
  the CI runner — so a uniformly slower runner passes while a single row
  that regressed on top of the machine factor fails.

A row regresses when it is worse than (normalized) baseline by more than
``--tolerance`` (default 2.0 — the guard fails on >2x throughput
regressions).  Rows missing on either side are reported but never fail
the guard (benches evolve).  The blind spot by construction: a change
that slows *every* absolute row uniformly looks like a slow machine —
that case is covered by the ratio rows and by re-baselining locally.

    python -m benchmarks.check_regression bench.json            # guard
    python -m benchmarks.check_regression bench.json --update   # re-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, Tuple

GUARDED_MODULES = ("netlist_bench", "campaign_mc", "serve_bench",
                   "serve_load", "obs_overhead", "mmpu_cost",
                   "ecc_frontier")
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
_RATE_RE = re.compile(r"(gate_evals_per_s|tok_s)=([0-9.eE+-]+)")
_RATIO_RE = re.compile(
    r"(speedup_vs_scan|speedup_vs_loop|tmr_amortization"
    r"|goodput_gain|telemetry_efficiency|adaptive_speedup)=([0-9.eE+-]+)x")
# mMPU cost-model projections (benchmarks.mmpu_cost): machine-INDEPENDENT
# analytic numbers — pure shape arithmetic, identical on any runner — so
# they are compared directly (no machine normalization) and lower is
# better: a cost-model change that inflates a scheme's projected
# cycles/energy per token beyond tolerance fails the guard.
_MODEL_RE = re.compile(
    r"(cycles_per_token|energy_pj_per_token)=([0-9.eE+-]+)")
# latency-tail metrics from serve_bench's chunked rows: lower-better
# times, machine-normalized like any other absolute timing.  Guarding
# p99 alongside p50 catches tail-only regressions (a fatter distribution
# with an unchanged median).
_LAT_RE = re.compile(
    r"(ttft_p50|ttft_p99|tpot_p50|tpot_p99)=([0-9.eE+-]+)us")
MIN_US = 10.0   # ignore sub-10µs timings: pure dispatch noise


def extract_metrics(rows) -> Dict[str, Tuple[str, float]]:
    """row list -> {metric key: (kind, value)}; kind is 'ratio' (machine-
    independent, higher better), 'model' (machine-independent, lower
    better — the mMPU cost projections), 'rate' (higher better) or 'time'
    (lower better).  Wall-clock totals arrive as ``{"kind": "time", "seconds"}``
    rows (benchmarks.run) and are kept in seconds."""
    out: Dict[str, Tuple[str, float]] = {}  # kinds: ratio|model|rate|time
    for r in rows:
        if r.get("module") not in GUARDED_MODULES:
            continue
        name, us = r["name"], float(r.get("us_per_call", 0.0))
        derived = r.get("derived", "")
        for label, val in _RATIO_RE.findall(derived):
            out[f"{name}:{label}"] = ("ratio", float(val))
        for label, val in _MODEL_RE.findall(derived):
            out[f"{name}:{label}"] = ("model", float(val))
        for label, val in _LAT_RE.findall(derived):
            if float(val) >= MIN_US:
                out[f"{name}:{label}"] = ("time", float(val))
        rate = _RATE_RE.search(derived)
        if rate:
            out[f"{name}:{rate.group(1)}"] = ("rate", float(rate.group(2)))
        elif "seconds" in r:
            out[f"{name}:seconds"] = ("time", float(r["seconds"]))
        elif us >= MIN_US:
            out[f"{name}:us_per_call"] = ("time", us)
    return out


def _median(xs):
    xs = sorted(xs)
    n = len(xs)
    if not n:
        return 1.0
    return xs[n // 2] if n % 2 else (xs[n // 2 - 1] + xs[n // 2]) / 2.0


def compare(baseline: Dict[str, Tuple[str, float]],
            fresh: Dict[str, Tuple[str, float]],
            tolerance: float) -> Tuple[list, list]:
    regressions, notes = [], []
    # worse_x > 1 means the fresh run is worse than baseline on that row
    worse: Dict[str, Tuple[str, float]] = {}
    for key in sorted(baseline):
        if key not in fresh:
            notes.append(f"missing in fresh run: {key}")
            continue
        kind, base = baseline[key]
        _, new = fresh[key]
        if base <= 0 or new <= 0:
            continue
        worse[key] = (kind, base / new if kind in ("rate", "ratio")
                      else new / base)
    # machine-speed factor: median worse_x over the absolute rows only.
    # Clamped at 1.0 — a FASTER machine must not inflate rows that merely
    # failed to speed up as much as the median (heterogeneous per-row
    # speedups between boxes would otherwise fail spuriously); only a
    # slower machine gets its uniform factor divided out.
    machine = max(1.0, _median([w for kind, w in worse.values()
                                if kind not in ("ratio", "model")]))
    notes.append(f"machine-speed factor (median absolute worse_x, "
                 f"clamped >= 1): {machine:.2f}")
    for key, (kind, w) in sorted(worse.items()):
        eff = w if kind in ("ratio", "model") else w / machine
        line = (f"{key}: baseline={baseline[key][1]:.4g} "
                f"fresh={fresh[key][1]:.4g} worse_x={w:.2f}"
                + ("" if kind in ("ratio", "model")
                   else f" normalized={eff:.2f}"))
        (regressions if eff > tolerance else notes).append(line)
    for key in sorted(set(fresh) - set(baseline)):
        notes.append(f"new row (not in baseline): {key}")
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json", help="fresh bench.json from benchmarks.run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="fail when a row is worse than (machine-"
                         "normalized) baseline by more than this factor")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh run and exit")
    args = ap.parse_args()

    with open(args.bench_json) as f:
        fresh_doc = json.load(f)
    fresh = extract_metrics(fresh_doc.get("rows", []))

    if args.update:
        with open(args.baseline, "w") as f:
            json.dump({"modules": list(GUARDED_MODULES),
                       "smoke": fresh_doc.get("smoke"),
                       "source_unix_time": fresh_doc.get("unix_time"),
                       "metrics": {k: {"kind": kind, "value": v}
                                   for k, (kind, v) in sorted(fresh.items())}},
                      f, indent=1)
        print(f"# baseline updated: {args.baseline} ({len(fresh)} metrics)")
        return

    with open(args.baseline) as f:
        base_doc = json.load(f)
    if bool(base_doc.get("smoke")) != bool(fresh_doc.get("smoke")):
        sys.exit(f"smoke-mode mismatch: baseline smoke={base_doc.get('smoke')}"
                 f" vs fresh smoke={fresh_doc.get('smoke')} — the configs "
                 "differ (multiplier width, trial budgets), so the rows are "
                 "not comparable; re-run benchmarks.run with matching --smoke"
                 " or --update the baseline")
    baseline = {k: (m["kind"], float(m["value"]))
                for k, m in base_doc["metrics"].items()}

    regressions, notes = compare(baseline, fresh, args.tolerance)
    for line in notes:
        print(f"[bench-guard] ok: {line}")
    for line in regressions:
        print(f"[bench-guard] REGRESSION: {line}", file=sys.stderr)
    if regressions:
        sys.exit(f"{len(regressions)} bench row(s) regressed by more than "
                 f"{args.tolerance}x vs {args.baseline}")
    print(f"[bench-guard] {len(notes)} row(s) within {args.tolerance}x "
          f"of baseline")


if __name__ == "__main__":
    main()
