"""Netlist execution-engine bench: scan vs levelized vs packed Pallas kernel.

Measures gate-evaluations/second of the three netlist engines
(core/netlist.execute lax.scan reference, core/scheduler.execute_levelized,
kernels/netlist_exec one-launch kernel) on the N-bit MultPIM multiplier —
the hot loop behind fig4_mult, fig4_nn and campaign_mc — plus netlist
compilation stats: gate count with/without structural-hash CSE, DAG depth,
schedule levels/width/padding (DESIGN.md §11).

Fault-free and iid-injected variants are timed separately: the injected
paths share the scan reference's per-gate threefry stream bit-for-bit, so
their cost includes identical mask sampling and the speedup isolates the
execution engine.  Smoke mode (REPRO_BENCH_SMOKE=1) shrinks the iteration
count but keeps the 32-bit / 512-trial headline row so the
speedup-over-scan measurement stays comparable across CI runs.

The kernel rows also sweep `tile_tw` (packed-trial words per grid step) —
the knob ROADMAP item 4 asked about for the kernel-vs-level gap.  The
verdict (DESIGN.md §11): no tile shape closes it on CPU, because the gap
is interpret-mode dispatch (one Python-level grid-step loop per level x
trial-tile), not tiling — which is why the registry default for
`netlist_exec` is `level`.
"""
from __future__ import annotations

import os
import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multpim, scheduler
from repro.reliability import backend

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
N_BITS = 32
TRIALS = 512
ITERS = 2 if SMOKE else 5
#: all registered engines, scan (the reference/oracle) first
IMPLS = ("scan",) + tuple(i for i in backend.implementations("netlist_exec")
                          if i != "scan")


def _time(f, *args, iters: int = ITERS) -> float:
    jax.block_until_ready(f(*args))          # compile + warm
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / iters


def _operands(n_bits: int, trials: int):
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**n_bits, trials, dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2**n_bits, trials, dtype=np.uint64).astype(np.uint32)
    return jnp.array(a), jnp.array(b)


def run() -> list:
    rows = []
    nl = multpim.multiplier_netlist(N_BITS)
    nl_raw = multpim.multiplier_netlist(N_BITS, cse=False)
    sch = scheduler.schedule(nl)
    tag = f"{N_BITS}b_{TRIALS}t"
    rows.append((f"netlist.stats_{N_BITS}b", 0.0,
                 f"gates={nl.n_gates} gates_nocse={nl_raw.n_gates} "
                 f"cse_saved={nl_raw.n_gates - nl.n_gates} depth={sch.depth} "
                 f"levels={sch.n_levels} width={sch.max_width} "
                 f"slots={sch.n_slots} pad_ratio={sch.n_slots / nl.n_gates:.2f}"))

    a, b = _operands(N_BITS, TRIALS)
    key = jax.random.PRNGKey(1)
    want = np.asarray(multpim.multiply_bits(a, b, N_BITS, impl="scan"))
    evals = nl.n_gates * TRIALS

    secs = {}
    for impl in IMPLS:
        f = jax.jit(lambda a, b, impl=impl:
                    multpim.multiply_bits(a, b, N_BITS, impl=impl))
        got = np.asarray(f(a, b))
        assert (got == want).all(), f"{impl} diverges from scan"
        secs[impl] = _time(f, a, b)
        rows.append((f"netlist.exec_{impl}_{tag}", secs[impl] * 1e6,
                     f"gate_evals_per_s={evals / secs[impl]:.3e} "
                     f"speedup_vs_scan={secs['scan'] / secs[impl]:.1f}x"))

    # iid fault injection (p_gate high enough that masks are dense-ish);
    # streams are bit-identical across engines, so outputs must agree too
    p = 1e-4
    want_iid = np.asarray(multpim.multiply_bits(a, b, N_BITS, key=key,
                                                p_gate=p, impl="scan"))
    secs_iid = {}
    for impl in IMPLS:
        f = jax.jit(lambda a, b, k, impl=impl:
                    multpim.multiply_bits(a, b, N_BITS, key=k, p_gate=p,
                                          impl=impl))
        got = np.asarray(f(a, b, key))
        assert (got == want_iid).all(), f"{impl} iid stream diverges from scan"
        secs_iid[impl] = _time(f, a, b, key)
        rows.append((f"netlist.exec_iid_{impl}_{tag}", secs_iid[impl] * 1e6,
                     f"gate_evals_per_s={evals / secs_iid[impl]:.3e} "
                     f"speedup_vs_scan={secs_iid['scan'] / secs_iid[impl]:.1f}x"))

    # tile_tw sweep for the packed kernel (ROADMAP item 4): is the
    # kernel-vs-level gap a grid-shape artifact?  Each tile_tw is verified
    # bit-exact, timed fault-free, and the best variant is recorded; the
    # sweep shows the gap survives every tile shape on CPU (DESIGN.md §11).
    if "kernel" in IMPLS:
        from repro.kernels.netlist_exec import execute_packed
        packed = multpim._pack_inputs(a, b, N_BITS)
        tiles = (4, 16) if SMOKE else (1, 2, 4, 8, 16)
        best_tile, best_s = None, None
        for t in tiles:
            f = jax.jit(lambda x, t=t: execute_packed(nl, x, tile_tw=t))
            got = np.asarray(f(packed))
            assert (got == want).all(), f"kernel tile_tw={t} diverges"
            s = _time(f, packed)
            rows.append((f"netlist.exec_kernel_tile{t}_{tag}", s * 1e6,
                         f"gate_evals_per_s={evals / s:.3e}"))
            if best_s is None or s < best_s:
                best_tile, best_s = t, s
        rows.append((f"netlist.kernel_tile_sweep_{tag}", 0.0,
                     f"best_tile_tw={best_tile} "
                     f"gate_evals_per_s={evals / best_s:.3e} "
                     f"vs_level={secs['level'] / best_s:.2f}x"))

    best = min(secs, key=secs.get)
    rows.append((f"netlist.best_speedup_{tag}", 0.0,
                 f"impl={best} speedup_vs_scan="
                 f"{secs['scan'] / secs[best]:.1f}x "
                 f"gate_evals_per_s={evals / secs[best]:.3e}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-bits", type=int, default=N_BITS,
                    help="multiplier width")
    ap.add_argument("--trials", type=int, default=TRIALS,
                    help="batched multiplications per timed call")
    args = ap.parse_args()
    N_BITS, TRIALS = args.n_bits, args.trials
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
