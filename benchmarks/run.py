"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (per-call rows carry microseconds,
``*.total_wall_s`` rows carry seconds); ``--json PATH`` additionally
writes the same rows machine-readably (the ``BENCH_*.json`` trajectory
artifact CI uploads) — per-call rows as ``us_per_call``, wall-clock
totals as ``{"kind": "time", "seconds": ...}`` so check_regression.py
compares like units.  Run with:
    PYTHONPATH=src python -m benchmarks.run [--only fig4_mult,...] \
        [--json bench.json] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

MODULES = ["fig4_mult", "fig4_nn", "fig5_weights", "ecc_overhead",
           "tmr_tradeoff", "kernels_bench", "campaign_mc", "netlist_bench",
           "serve_bench", "serve_load", "obs_overhead", "mmpu_cost",
           "ecc_frontier"]


def provenance() -> dict:
    """Run provenance stamped onto every JSON row: a bench number without
    its git SHA, backend resolution and device shape is unreproducible.
    `backend` records the *resolved* implementation per op (the REPRO_IMPL
    env var / registered defaults actually in effect), so a row measured
    against jnp fallbacks can never masquerade as a kernel number."""
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip() \
            or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    import jax
    from repro.reliability import backend
    return {
        "git_sha": sha,
        "backend": {op: backend.resolve(op) for op in backend.ops()},
        "platform": jax.default_backend(),
        # forced-host device count IS the bench mesh capacity: sharded
        # serve rows appear exactly when this is >= 4 (DESIGN.md §14)
        "devices": jax.device_count(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(MODULES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON to PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink trial budgets (sets REPRO_BENCH_SMOKE=1 "
                         "for modules that scale with it)")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    stamp = provenance()
    print("name,value,derived")
    rows = []
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=[name])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.3f},{derived}", flush=True)
                rows.append({"module": name, "name": row_name,
                             "us_per_call": round(us, 3),
                             "derived": str(derived), **stamp})
        except Exception:
            failures += 1
            err = traceback.format_exc(limit=2)
            print(f"{name}.ERROR,0,{err!r}", flush=True)
            rows.append({"module": name, "name": f"{name}.ERROR",
                         "us_per_call": 0.0, "derived": err, **stamp})
        # wall-clock totals are a different unit from the per-call rows:
        # record them as kind=time seconds, never as a microsecond
        # us_per_call (the old mislabeling check_regression had to absorb)
        wall_s = time.time() - t0
        print(f"{name}.total_wall_s,{wall_s:.3f},unit=s", flush=True)
        rows.append({"module": name, "name": f"{name}.total_wall_s",
                     "kind": "time", "seconds": round(wall_s, 3),
                     "derived": "unit=s", **stamp})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"modules": mods, "smoke": bool(args.smoke),
                       "failures": failures, "unix_time": int(time.time()),
                       "provenance": stamp, "rows": rows}, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
