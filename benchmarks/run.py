"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Run with:
    PYTHONPATH=src python -m benchmarks.run [--only fig4_mult,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

sys.path.insert(0, "src")

MODULES = ["fig4_mult", "fig4_nn", "fig5_weights", "ecc_overhead",
           "tmr_tradeoff", "kernels_bench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(MODULES))
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=[name])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.3f},{derived}", flush=True)
        except Exception:
            failures += 1
            print(f"{name}.ERROR,0,{traceback.format_exc(limit=2)!r}", flush=True)
        print(f"{name}.total_wall_s,{(time.time()-t0)*1e6:.0f},-", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
