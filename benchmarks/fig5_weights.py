"""Paper Fig. 5: expected corrupted weights over T batches, baseline vs
mMPU ECC, for a range of per-access bit-corruption rates p_input.

Also validates the analytic model against a direct simulation of the
word-level ReliableStore (inject -> scrub per batch) at an accelerated
rate.
"""
from __future__ import annotations

import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics as A
from repro.core.reliability import ReliableStore
from repro.faults import inject_bit_flips


def simulate_store(p_bit: float, batches: int, n_weights: int = 4096) -> int:
    """Accelerated end-to-end check: corrupt + scrub `batches` times,
    count finally-corrupted weights."""
    key = jax.random.PRNGKey(0)
    w0 = jax.random.normal(key, (n_weights,), jnp.float32)
    store = ReliableStore.protect({"w": w0})
    params = {"w": w0}
    for t in range(batches):
        params = inject_bit_flips(params, jax.random.fold_in(key, t), p_bit)
        fixed, rep = ReliableStore(params, store.parity).scrub()
        params, store = fixed.params, fixed
    return int((np.asarray(params["w"]) != np.asarray(w0)).sum())


def run() -> list:
    rows = []
    cs = A.AlexNetCaseStudy()
    T = np.logspace(3, 8, 6)
    for p_input in (1e-10, 1e-9, 1e-8):
        base = A.expected_corrupted_weights(A.weight_corruption_baseline(p_input, T), cs)
        ecc = A.expected_corrupted_weights(A.weight_corruption_ecc_refined(p_input, T), cs)
        for i, t in enumerate(T):
            rows.append((f"fig5.p{p_input:g}_T{t:.0e}", 0.0,
                         f"baseline={base[i]:.3e} ecc={ecc[i]:.3e}"))
    rows.append(("fig5.headline_1e7_batches_p1e-9", 0.0,
                 f"baseline={A.expected_corrupted_weights(A.weight_corruption_baseline(1e-9, np.array([1e7])), cs)[0]:.2e} "
                 f"ecc={A.expected_corrupted_weights(A.weight_corruption_ecc_refined(1e-9, np.array([1e7])), cs)[0]:.2f} "
                 f"(paper: ~1 corrupted weight)"))

    # accelerated end-to-end simulation vs analytics
    t0 = time.time()
    corrupted = simulate_store(p_bit=2e-6, batches=32)
    us = (time.time() - t0) * 1e6 / 32
    rows.append(("fig5.sim_store_32scrubs_p2e-6", us,
                 f"corrupted_weights={corrupted} (expect ~0-2: double hits only)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
