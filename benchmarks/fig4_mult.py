"""Paper Fig. 4 (top): 32-bit multiplication failure probability vs p_gate.

Methodology (DESIGN.md §8): Monte-Carlo fault injection into every stateful
gate request at high p_gate; exhaustive single-fault masking analysis (one
trial per gate position) calibrates alpha = the unmasked fraction, which
extrapolates the curves into the 1e-12..1e-6 regime the paper plots.
Curves: unreliable baseline, proposed TMR (non-ideal in-memory Minority3
voting), and ideal voting (the dashed line showing voting becomes the
bottleneck near p_gate = 1e-9).
"""
from __future__ import annotations

import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics as A
from repro.core import multpim

N_BITS = 32
MC_TRIALS = 512
MC_PGATES = [3e-4, 1e-3, 3e-3]


def measure_alpha(n_bits: int = N_BITS, chunk: int = 4096) -> float:
    """Exhaustive single-fault masking: fraction of gate positions whose
    single fault corrupts the product (averaged over random operands).

    One trial per gate position, executed in `chunk`-gate slices: the
    per-slice working set is chunk x n_wires bits instead of
    n_gates x n_wires, so 64-bit netlists (~56k gates, ~56k wires) stay
    within host memory.  The operand stream is drawn up front, so alpha is
    identical for every chunk size.
    """
    nl = multpim.multiplier_netlist(n_bits)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**n_bits, nl.n_gates, dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2**n_bits, nl.n_gates, dtype=np.uint64).astype(np.uint32)
    want = multpim.true_product_bits(a, b, n_bits)
    wrong = 0
    for s in range(0, nl.n_gates, chunk):
        e = min(s + chunk, nl.n_gates)
        bits = multpim.multiply_bits(jnp.array(a[s:e]), jnp.array(b[s:e]),
                                     n_bits,
                                     fault_gate=jnp.arange(s, e, dtype=jnp.int32))
        wrong += int((np.asarray(bits) != want[s:e]).any(axis=1).sum())
    return wrong / nl.n_gates


def monte_carlo(p_gate: float, tmr: bool, n_bits: int = N_BITS,
                trials: int = MC_TRIALS) -> float:
    rng = np.random.default_rng(42)
    a = jnp.array(rng.integers(0, 2**n_bits, trials, dtype=np.uint64).astype(np.uint32))
    b = jnp.array(rng.integers(0, 2**n_bits, trials, dtype=np.uint64).astype(np.uint32))
    want = multpim.true_product_bits(np.asarray(a), np.asarray(b), n_bits)
    if tmr:
        bits = multpim.multiply_tmr_bits(a, b, n_bits, jax.random.PRNGKey(1),
                                         p_gate=p_gate)
    else:
        bits = multpim.multiply_bits(a, b, n_bits, key=jax.random.PRNGKey(2),
                                     p_gate=p_gate)
    return float((np.asarray(bits) != want).any(axis=1).mean())


def run() -> list:
    rows = []
    t0 = time.time()
    nl = multpim.multiplier_netlist(N_BITS)
    alpha = measure_alpha(N_BITS)
    rows.append(("fig4_mult.alpha_unmasked", (time.time() - t0) * 1e6 / nl.n_gates,
                 f"alpha={alpha:.4f} gates={nl.n_gates}"))

    # MC validation points (high p_gate)
    for p in MC_PGATES:
        t0 = time.time()
        mc_base = monte_carlo(p, tmr=False, n_bits=N_BITS)
        pred = float(A.p_mult_from_alpha(np.array([p]), alpha, nl.n_gates)[0])
        rows.append((f"fig4_mult.mc_baseline_p{p:g}",
                     (time.time() - t0) * 1e6 / MC_TRIALS,
                     f"measured={mc_base:.4f} predicted={min(pred,1):.4f}"))
    t0 = time.time()
    mc_tmr = monte_carlo(MC_PGATES[0], tmr=True, n_bits=N_BITS)
    pred_tmr = float(A.p_mult_tmr(np.array([MC_PGATES[0]]), alpha, nl.n_gates)[0])
    rows.append((f"fig4_mult.mc_tmr_p{MC_PGATES[0]:g}",
                 (time.time() - t0) * 1e6 / MC_TRIALS,
                 f"measured={mc_tmr:.4f} predicted={min(pred_tmr,1):.4f}"))

    # the extrapolated figure itself
    pg = np.logspace(-12, -4, 17)
    base = A.p_mult_from_alpha(pg, alpha, nl.n_gates)
    tmr_ni = A.p_mult_tmr(pg, alpha, nl.n_gates, ideal_voting=False)
    tmr_id = A.p_mult_tmr(pg, alpha, nl.n_gates, ideal_voting=True)
    for i, p in enumerate(pg):
        rows.append((f"fig4_mult.curve_p{p:.0e}", 0.0,
                     f"baseline={base[i]:.3e} tmr={tmr_ni[i]:.3e} "
                     f"tmr_ideal={tmr_id[i]:.3e}"))
    # the paper's crossover claim: non-ideal voting dominates near 1e-9
    i9 = int(np.argmin(np.abs(pg - 1e-9)))
    rows.append(("fig4_mult.voting_bottleneck_at_1e-9", 0.0,
                 f"nonideal/ideal={tmr_ni[i9]/max(tmr_id[i9],1e-300):.1e}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-bits", type=int, default=N_BITS,
                    help="multiplier width (the chunked alpha pass keeps "
                         "64-bit netlists within host memory)")
    args = ap.parse_args()
    N_BITS = args.n_bits
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
