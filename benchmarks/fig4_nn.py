"""Paper Fig. 4 (bottom): NN feed-forward misclassification vs p_gate.

FloatPIM-style AlexNet/ImageNet accelerator: M = 612e6 multiplications per
sample, p_mask = 0.03% of soft errors flip the classification (G. Li et
al.); p_misclassify = 1 - (1 - p_mask * p_mult)^M.  The paper's headline:
74% baseline vs ~2% with TMR at p_gate = 1e-9 (network's inherent error is
~27%, so the TMR residual is negligible).
"""
from __future__ import annotations


try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import numpy as np

from repro.core import analytics as A
from repro.core import multpim
from .fig4_mult import measure_alpha


def run() -> list:
    nl = multpim.multiplier_netlist(32)
    alpha = measure_alpha()
    cs = A.AlexNetCaseStudy()
    pg = np.logspace(-12, -8, 9)
    base = A.nn_misclassification(A.p_mult_from_alpha(pg, alpha, nl.n_gates), cs)
    tmr = A.nn_misclassification(A.p_mult_tmr(pg, alpha, nl.n_gates), cs)
    rows = []
    for i, p in enumerate(pg):
        rows.append((f"fig4_nn.curve_p{p:.0e}", 0.0,
                     f"baseline={base[i]:.4f} tmr={tmr[i]:.4f}"))
    i9 = int(np.argmin(np.abs(pg - 1e-9)))
    rows.append(("fig4_nn.headline_1e-9", 0.0,
                 f"baseline={base[i9]:.3f} (paper ~0.74) "
                 f"tmr={tmr[i9]:.4f} (paper ~0.02) "
                 f"inherent_error={cs.inherent_error}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
