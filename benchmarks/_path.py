"""Single sys.path bootstrap for the benchmark modules.

Each benchmark historically did its own ``sys.path.insert(0, "src")`` —
which only worked when the CWD was the repo root, and mutated sys.path once
per imported module.  Importing this module instead inserts the absolute
``src/`` path exactly once, idempotently:

    try:                      # package execution: python -m benchmarks.run
        from . import _path   # noqa: F401
    except ImportError:       # direct script: python benchmarks/fig4_mult.py
        import _path          # noqa: F401

(With the repro package pip-installed the import is a harmless no-op.)
"""
import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
