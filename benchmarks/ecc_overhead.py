"""Paper §IV: ECC latency overhead on mMPU operations (~26% average).

We account crossbar cycles with the simulator's CycleCounter over a mix of
vectored workloads (the same op classes the DAC'21 evaluation uses):
per arithmetic function, the diagonal-parity update costs O(1) vectored
XOR steps per written column/row (verify on inputs + update on outputs),
independent of the crossbar height — vs O(n) for horizontal parity under
in-column ops (the naive baseline of Fig. 2a).
"""
from __future__ import annotations


try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

# cycle model: a vectored stateful gate = 1 cycle; the diagonal ECC update
# per written column = |families| XOR gate-steps (barrel-shifted, parallel
# across rows) + 1 parity write; verification per read column likewise.
FAMILIES = 2               # paper-faithful leading + counter diagonals
XOR_CYCLES = 5             # NOR-decomposed XOR (stateful_logic.GATE_COSTS)

#: (name, gate-cycles per output column, inputs read, outputs written)
WORKLOADS = {
    # N-bit ripple add: ~12 cycles/bit (FA via Min3/NOR), writes N+1 cols
    "vector_add_32": (12 * 32, 2 * 32, 33),
    # schoolbook multiply: ~14k cycles, writes 64 product columns
    "vector_mult_32": (13792, 2 * 32, 64),
    # elementwise NOR (1 gate), 2 reads 1 write
    "vector_nor": (1, 2, 1),
    # 8-bit image convolution 3x3: ~9 mult-accumulate of 8-bit
    "conv3x3_8bit": (9 * (760 + 12 * 16), 9 * 8, 24),
}


def run() -> list:
    rows = []
    serial_ovh, overlap_ovh = [], []
    for name, (compute, reads, writes) in WORKLOADS.items():
        verify = reads * FAMILIES * XOR_CYCLES // 8   # verify per 8-col word, amortized
        update = writes * (FAMILIES * XOR_CYCLES + 1)
        serialized = compute + verify + update
        # the paper's design: a dedicated memristive extension computes the
        # parity updates in parallel with the main crossbar; only the write
        # synchronization (1 cycle per written column) is exposed
        overlapped = compute + writes
        so = (serialized / compute - 1) * 100
        oo = (overlapped / compute - 1) * 100
        serial_ovh.append(so)
        overlap_ovh.append(oo)
        rows.append((f"ecc_overhead.{name}", 0.0,
                     f"base={compute}cy serialized=+{so:.1f}% overlapped=+{oo:.1f}%"))
    rows.append(("ecc_overhead.average", 0.0,
                 f"overlapped_mean=+{sum(overlap_ovh)/len(overlap_ovh):.1f}% "
                 f"(paper: ~26% average with the parallel dedicated extension); "
                 f"serialized_mean=+{sum(serial_ovh)/len(serial_ovh):.1f}%"))
    # the O(1) vs O(n) contrast of Fig. 2
    n = 1024
    rows.append(("ecc_overhead.naive_horizontal_in_column_op", 0.0,
                 f"O(n)={n} cycles per update vs diagonal O(1)="
                 f"{FAMILIES * XOR_CYCLES + 1} cycles"))

    # measured counterpart on the TPU-word code: the per-step parity refresh
    # (re-encode after an optimizer write) as ONE fused launch over the
    # packed arena — driven through the unified Scheme API (DESIGN.md §12)
    # — vs one encode per pytree leaf (the pre-arena layout)
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.arena import pack
    from repro.core.reliability import protect_leaves
    from repro.reliability import DiagParityEcc

    key = jax.random.PRNGKey(0)
    params = {f"leaf{i}": jax.random.normal(jax.random.fold_in(key, i),
                                            (64, 48 + i), jnp.float32)
              for i in range(20)}

    def timed(f, iters=3):
        jax.block_until_ready(f())
        t0 = time.time()
        for _ in range(iters):
            jax.block_until_ready(f())
        return (time.time() - t0) / iters * 1e6

    buf, _ = pack(params)
    scheme = DiagParityEcc()
    us_fused = timed(lambda: scheme.refresh(params).redundancy)
    us_leaf = timed(lambda: protect_leaves(params))
    rows.append(("ecc_overhead.refresh_arena_fused_20leaves", us_fused,
                 f"words={buf.shape[0]} one encode launch "
                 f"({scheme.overhead().describe()})"))
    rows.append(("ecc_overhead.refresh_per_leaf_20leaves", us_leaf,
                 f"speedup_arena_fused={us_leaf / us_fused:.2f}x"))

    # code zoo (DESIGN.md §18): per-code encode and fused-scrub launch
    # cost over the SAME packed arena — the maintenance tax each code
    # charges per refresh/scrub, next to its storage/latency accounting
    from repro.reliability import HsiaoSecDed
    for code in (DiagParityEcc(), HsiaoSecDed()):
        prot = code.protect(params)
        us_enc = timed(lambda c=code: c._encode(buf))
        us_scrub = timed(
            lambda c=code, p=prot: c.scrub(p)[1].corrected)
        rows.append((f"ecc_overhead.encode_{code.code_name}", us_enc,
                     f"words={buf.shape[0]} parity_words_per_block="
                     f"{code.n_parity_words} "
                     f"({code.overhead().describe()})"))
        rows.append((f"ecc_overhead.scrub_{code.code_name}", us_scrub,
                     f"fused encode->syndrome->correct launch, "
                     f"words={buf.shape[0]}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
