"""Paper §V: protection-scheme latency/area/throughput trade-off table,
swept over the whole `repro.reliability` design space (DESIGN.md §12) —
every scheme's CostReport plus the crossbar simulator's cycle accounting
for the three TMR disciplines (vs the unreliable baseline), plus the
periphery-based alternative's 1024x latency penalty the paper cites.
"""
from __future__ import annotations

import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro import costmodel as cm
from repro.configs.mmpu_paper import get_device
from repro.core import multpim
from repro.core.tmr import TMR_COSTS
from repro.reliability import Tmr, standard_grid

ROWS_PER_XBAR = 1024

#: crossbar cycle model per TMR discipline: (execution multiplier, copies
#: running concurrently) — vote is always Min3+NOT per output bit
_DISCIPLINE_CYCLES = {"serial": 3, "parallel": 1, "semi_parallel": 1}


def run() -> list:
    rows = []
    nl = multpim.multiplier_netlist(32)
    base_cycles = nl.n_gates                       # 1 cycle per vectored gate
    vote_cycles = 2 * 64                           # Min3+NOT per output bit

    # hardware-grounded axis (DESIGN.md §17): every scheme row carries its
    # mMPU projection next to the analytical CostReport — the wall-clock
    # CPU numbers below stay, but the cycles/energy columns are the
    # device-real statement of the same trade-off
    dev = get_device("paper")
    profile = cm.StepProfile(weight_words=1 << 16, macs_per_token=1 << 20,
                             tokens=1, mac_bits=8)
    mmpu = cm.evaluate_grid(standard_grid(), profile, dev)

    # one code path over the scheme grid: each scheme reports its own
    # CostReport; TMR disciplines additionally get the simulator's cycle
    # accounting cross-checked against the paper's stated costs
    for scheme in standard_grid():
        cost = scheme.overhead()
        proj = mmpu[scheme.name]
        derived = (cost.describe()
                   + f" mmpu_cycles_tok={proj.cycles_per_token:.4g}"
                   + f" mmpu_pj_tok={proj.energy_pj_per_token:.4g}")
        if isinstance(scheme, Tmr):
            cycles = (_DISCIPLINE_CYCLES[scheme.discipline] * base_cycles
                      + vote_cycles)
            paper = TMR_COSTS[scheme.discipline]
            derived += (f" sim_latency={cycles / base_cycles:.2f}x "
                        f"(paper: {paper.latency_x:.0f}x/"
                        f"{paper.area_x:.0f}x/{paper.throughput_x:.2f}x)")
        rows.append((f"tmr_tradeoff.{scheme.name}", 0.0, derived))
    rows.append(("tmr_tradeoff.periphery_alternative", 0.0,
                 f"latency={ROWS_PER_XBAR}x (paper: up to 1024x for 1024 rows)"))

    # wall-time sanity: serial TMR is ~3x one execution in the simulator too
    rng = np.random.default_rng(0)
    a = jnp.array(rng.integers(0, 2**16, 128).astype(np.uint32))
    b = jnp.array(rng.integers(0, 2**16, 128).astype(np.uint32))
    f1 = jax.jit(lambda a, b: multpim.multiply_bits(a, b, 16))
    f3 = jax.jit(lambda a, b, k: multpim.multiply_tmr_bits(a, b, 16, k, 0.0))
    f1(a, b).block_until_ready()
    f3(a, b, jax.random.PRNGKey(0)).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        f1(a, b).block_until_ready()
    t1 = (time.time() - t0) / 3
    t0 = time.time()
    for _ in range(3):
        f3(a, b, jax.random.PRNGKey(0)).block_until_ready()
    t3 = (time.time() - t0) / 3
    rows.append(("tmr_tradeoff.sim_walltime", t1 * 1e6,
                 f"serial_tmr/baseline={t3/t1:.2f}x wall (3 executions + "
                 f"vectorized voting; CPU sim amortizes fixed overheads)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
