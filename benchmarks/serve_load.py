"""Continuous-batching load benchmark: wall-clock goodput of the
chunk-boundary scheduler (paged ECC-protected KV pool, DESIGN.md §16)
against sequential whole-batch serving on a skewed Poisson trace.

The workload continuous batching exists for: mostly-short generations
with a heavy tail (3:1 two-token vs cap-length, interleaved so every
arrival-order group of `slots` contains a long request).  Whole-batch
serving takes requests `slots` at a time in arrival order and pads every
row of a group to the group's longest generation (the fixed-batch engine
contract — each distinct group length gets its own compiled engine, a
*generous* baseline; padding to gen_cap would be worse).  The scheduler
instead recycles a short request's slot and pages at the next chunk
boundary.

Guarded signals (check_regression):

* ``goodput_gain`` — machine-independent ratio: whole-batch wall time /
  scheduler wall time over the same trace (same useful tokens).  The
  acceptance bar is >= 2x on the skewed trace — for the ECC row this
  depends on the touched-pages incremental parity refresh (a full-pool
  re-encode per tick prices ECC serving out of the win); the guard
  catches either collapsing.
* ``tok_s`` on both rows and ``ttft_p50/p99`` on the scheduler row —
  machine-normalized absolutes; p99 catches tail-only scheduling
  regressions (admission starvation fattens TTFT p99 while goodput
  means move little).

Run: PYTHONPATH=src python -m benchmarks.run --only serve_load --smoke
"""
from __future__ import annotations

import dataclasses
import os
import time

try:
    from . import _path  # noqa: F401
except ImportError:
    import _path  # noqa: F401

import jax
import numpy as np

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run():
    from repro.configs import get_config
    from repro.launch import (BatchSpec, ContinuousBatcher,
                              GenerationEngine, poisson_trace)
    from repro.models import params as P
    from repro.models import transformer as T
    from repro.reliability import parse_scheme

    key = jax.random.PRNGKey(0)
    # smoke-scale model (the serve_bench model-scale regime): per-step
    # compute dominates dispatch, so slot-step savings reach wall clock
    cfg = get_config("phi3-mini-3.8b").smoke()
    params = P.materialize(key, T.model_specs(cfg))

    SLOTS, CHUNK, PROMPT = 4, 8, 16
    GEN_CAP, N = (128, 16) if SMOKE else (192, 24)
    repeats = 3
    spec = BatchSpec(slots=SLOTS, page_tokens=8, chunk=CHUNK,
                     prompt_buckets=(PROMPT,), gen_cap=GEN_CAP)
    # Poisson arrivals; deterministic 3:1 short/long mix with the longs
    # interleaved — every whole-batch group pays its long request's cap
    trace = poisson_trace(N, rate_rps=50.0, spec=spec, vocab=cfg.vocab,
                          seed=0)
    trace = [dataclasses.replace(r, gen=GEN_CAP if i % SLOTS == 0 else 2)
             for i, r in enumerate(trace)]
    useful = sum(r.gen for r in trace)
    order = sorted(trace, key=lambda r: r.arrival_s)
    groups = [order[g:g + SLOTS] for g in range(0, len(order), SLOTS)]

    rows = []
    for name in ("off", "ecc"):
        # -- whole-batch baseline: one engine per distinct group length --
        engines = {}
        for g in sorted({max(r.gen for r in grp) for grp in groups}):
            eng = GenerationEngine(cfg, parse_scheme(name), gen=g,
                                   cache_len=spec.cache_tokens)
            store, _ = eng.prepare(params, key=key)
            engines[g] = (eng, store)

        def whole_batch():
            for grp in groups:
                eng, store = engines[max(r.gen for r in grp)]
                toks = np.stack([r.prompt for r in grp])
                jax.block_until_ready(
                    eng.generate(store, {"tokens": toks})[0])

        whole_batch()                                  # compile/warmup
        t_whole = min(_timed(whole_batch) for _ in range(repeats))

        # -- the scheduler over the same trace (arrival order, no pacing:
        # wall time is pure service time, same useful tokens) -----------
        b = ContinuousBatcher(cfg, parse_scheme(name), spec)
        b.prepare(params, key=key)
        b.run(trace)                                   # compile/warmup
        t_cont, results = float("inf"), None
        for _ in range(repeats):
            dt = time.perf_counter()
            res = b.run(trace)
            dt = time.perf_counter() - dt
            if dt < t_cont:
                t_cont, results = dt, res

        ttft = np.asarray([r.ttft_s for r in results]) * 1e6
        rows.append((
            f"serve_load.load_whole_batch_{name}_b{SLOTS}_g{GEN_CAP}",
            t_whole / useful * 1e6, f"tok_s={useful / t_whole:.5g}"))
        rows.append((
            f"serve_load.load_continuous_{name}_s{SLOTS}_c{CHUNK}"
            f"_g{GEN_CAP}",
            t_cont / useful * 1e6,
            f"tok_s={useful / t_cont:.5g} "
            f"goodput_gain={t_whole / t_cont:.2f}x "
            f"ttft_p50={np.percentile(ttft, 50):.5g}us "
            f"ttft_p99={np.percentile(ttft, 99):.5g}us"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
