"""Empirical Monte-Carlo reproduction of Fig. 4 / Fig. 5 (paper §VI).

Where fig4_mult/fig4_nn/fig5_weights extrapolate closed forms
(core/analytics.py), this module *measures* the same quantities with the
fault-campaign engine (repro.faults) and asserts that the closed forms fall
inside the campaigns' Wilson confidence intervals:

* Fig. 4 — multiplication failure and (scaled) NN misclassification vs
  p_gate: trials push random operands through the MultPIM Min3 netlist with
  i.i.d. gate faults.  The paper's own operating regime (p_gate ~ 1e-9) is
  unreachable by direct MC — that is exactly why the analytics extrapolate —
  so the campaigns run at MC-feasible p_gate and validate the *model* the
  extrapolation rests on, at ≥2 points.  The misclassification campaign is
  a scaled case study (M_SCALED multiplications per sample, p_mask scaled
  up) evaluated against the same nn_misclassification closed form.
* Fig. 5 — long-term weight corruption under ECC scrubbing: one trial is
  one 32-word arena block over T scrub intervals; a whole batch of trials
  is ONE fused inject→encode→syndrome→correct launch per interval
  (kernels/inject_scrub), i.e. the batch axis is the block axis.  Compared
  against weight_corruption_ecc with m=32 (the word code's 32x32 block).

TMR is included as a report-only point: analytics.p_mult_tmr is an explicit
word-level upper bound, so it is *expected* to sit above the per-bit-voting
measurement (no containment assert).

A protection-scheme grid campaign additionally walks the whole
`repro.reliability` design space (unprotected / ECC / three TMR
disciplines / ECC+TMR) through one `sweep_schemes` code path, measuring
long-term block corruption per scheme and asserting every protected
scheme beats the unprotected baseline.

Smoke mode (REPRO_BENCH_SMOKE=1, set by `benchmarks.run --smoke`): 16-bit
multiplier and smaller trial budgets — the CI artifact path.
"""
from __future__ import annotations

import os
import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics as A
from repro.core import multpim
from repro.core.reliability import encode_words
from repro.faults import (CampaignConfig, TransientBitFlips, run_campaign,
                          sweep, sweep_schemes)
from repro.kernels.inject_scrub import inject_scrub
from repro.reliability import standard_grid

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
N_BITS = 16 if SMOKE else 32
MAX_TRIALS = 2048 if SMOKE else 4096
BATCH = 512 if SMOKE else 1024
#: assert with a 99% Wilson interval — containment failures are model bugs,
#: not 1-in-20 MC noise
Z = 2.576
#: MC-feasible operating points (expected faults/trial stays O(0.1-1) so the
#: single-fault masking extrapolation is still accurate)
FIG4_PGATES = (3e-5, 1e-4) if SMOKE else (1e-5, 3e-5)
#: scaled NN case study: M_SCALED mults/sample, p_mask scaled from 0.03%
M_SCALED, P_MASK_SCALED = (8, 0.25) if SMOKE else (16, 0.25)
FIG5_POINTS = ({"p_input": 1e-4, "T": 8}, {"p_input": 5e-4, "T": 8})
#: scheme-grid operating point (repro.reliability design space, §V-§VI):
#: high enough that the unprotected baseline visibly fails over the horizon
GRID_P_INPUT, GRID_T = 2e-4, 4


def _rand_words(key, n: int) -> jax.Array:
    lim = jnp.uint32(0xFFFFFFFF >> (32 - N_BITS))
    return jax.random.bits(key, (n,), jnp.uint32) & lim


def measure_alpha(n_bits: int = N_BITS) -> float:
    """Exhaustive single-fault masking fraction (one trial per gate)."""
    nl = multpim.multiplier_netlist(n_bits)
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a, b = _rand_words(ka, nl.n_gates), _rand_words(kb, nl.n_gates)
    clean = multpim.multiply_bits(a, b, n_bits)
    faulted = multpim.multiply_bits(
        a, b, n_bits, fault_gate=jnp.arange(nl.n_gates, dtype=jnp.int32))
    return float((np.asarray(faulted) != np.asarray(clean)).any(axis=1).mean())


# -- Fig. 4 campaigns ---------------------------------------------------------

def make_mult_trial(p_gate: float, tmr: bool = False):
    """Batched trial: n multiplications, failure = any wrong product bit."""
    def impl(key, n):
        ka, kb, kf = jax.random.split(key, 3)
        a, b = _rand_words(ka, n), _rand_words(kb, n)
        clean = multpim.multiply_bits(a, b, N_BITS)
        if tmr:
            faulty = multpim.multiply_tmr_bits(a, b, N_BITS, kf, p_gate)
        else:
            faulty = multpim.multiply_bits(a, b, N_BITS, key=kf, p_gate=p_gate)
        return (faulty != clean).any(axis=-1)
    jitted = jax.jit(impl, static_argnums=1)
    return lambda key, n: jitted(key, n)


def make_nn_trial(p_gate: float):
    """Batched trial: one sample = M_SCALED mults through the netlist; each
    corrupted product flips the classification w.p. P_MASK_SCALED."""
    def impl(key, n):
        ka, kb, kf, km = jax.random.split(key, 4)
        a, b = _rand_words(ka, n * M_SCALED), _rand_words(kb, n * M_SCALED)
        clean = multpim.multiply_bits(a, b, N_BITS)
        faulty = multpim.multiply_bits(a, b, N_BITS, key=kf, p_gate=p_gate)
        mult_fail = (faulty != clean).any(axis=-1).reshape(n, M_SCALED)
        flips = jax.random.bernoulli(km, P_MASK_SCALED, (n, M_SCALED))
        return (mult_fail & flips).any(axis=-1)
    jitted = jax.jit(impl, static_argnums=1)
    return lambda key, n: jitted(key, n)


# -- Fig. 5 campaign ----------------------------------------------------------

def make_fig5_trial(p_input: float, T: int):
    """Batched trial: one trial = one 32-word ECC block across T scrub
    intervals; the batch shares one fused inject_scrub launch per interval.
    Failure = the block's data differs from the original at the horizon."""
    model = TransientBitFlips(p_input)

    def impl(key, n):
        kb, ki = jax.random.split(key)
        buf = jax.random.bits(kb, (n * 32,), jnp.uint32)
        orig, par = buf, encode_words(buf)
        corrected = jnp.zeros((), jnp.int32)
        uncorrectable = jnp.zeros((), jnp.int32)
        for t in range(T):
            mask = model.word_mask(jax.random.fold_in(ki, t), buf)
            buf, par, counts = inject_scrub(buf, par, mask)
            corrected = corrected + counts[1]
            uncorrectable = uncorrectable + counts[3]
        fail = (buf.reshape(n, 32) != orig.reshape(n, 32)).any(axis=-1)
        return fail, {"corrected": corrected, "uncorrectable": uncorrectable}
    jitted = jax.jit(impl, static_argnums=1)
    return lambda key, n: jitted(key, n)


# -- protection-scheme design-space grid --------------------------------------

def make_scheme_trial(scheme):
    """One trial: a 32-word block pytree protected by `scheme`, corrupted
    and scrubbed over GRID_T exposure intervals; failure = the decoded
    payload differs from the original at the horizon.  The same closure
    works for every scheme in the grid — this is the paper's §V-§VI design
    space walked through ONE code path (faults.campaign.sweep_schemes)."""
    model = TransientBitFlips(GRID_P_INPUT)

    def trial(key):
        kb, ki = jax.random.split(key)
        w = jax.random.bits(kb, (32,), jnp.uint32)
        prot = scheme.protect({"w": w})
        for t in range(GRID_T):
            prot = scheme.corrupt_store(prot, model,
                                        jax.random.fold_in(ki, t))
            prot, _ = scheme.scrub(prot)
        return (scheme.read(prot)["w"] != w).any()

    return trial


def run() -> list:
    rows = []
    cfg = CampaignConfig(batch_size=BATCH, max_trials=MAX_TRIALS,
                         min_trials=min(BATCH * 2, MAX_TRIALS),
                         ci_halfwidth=0.02, z=Z)
    key = jax.random.PRNGKey(2021)
    nl = multpim.multiplier_netlist(N_BITS)

    t0 = time.time()
    alpha = measure_alpha()
    rows.append(("campaign_mc.alpha", (time.time() - t0) * 1e6 / nl.n_gates,
                 f"alpha={alpha:.4f} gates={nl.n_gates} n_bits={N_BITS}"))

    # Fig. 4 top: empirical p_mult vs the alpha extrapolation
    for i, p_gate in enumerate(FIG4_PGATES):
        t0 = time.time()
        res = run_campaign(make_mult_trial(p_gate),
                           jax.random.fold_in(key, i), cfg, batched=True,
                           name=f"mult p_gate={p_gate:g}")
        model = float(A.p_mult_from_alpha(np.array([p_gate]), alpha,
                                          nl.n_gates)[0])
        lo, hi = res.ci
        agree = res.contains(model)
        rows.append((f"campaign_mc.fig4_mult_p{p_gate:g}",
                     (time.time() - t0) * 1e6 / res.n_trials,
                     f"p_hat={res.p_hat:.4f} ci=[{lo:.4f},{hi:.4f}] "
                     f"model={model:.4f} n={res.n_trials} agree={agree}"))
        assert agree, (
            f"fig4 p_gate={p_gate:g}: closed form {model:.4f} outside "
            f"Wilson interval [{lo:.4f}, {hi:.4f}] (n={res.n_trials})")

    # Fig. 4 bottom: empirical (scaled) misclassification vs the closed form
    cs = A.AlexNetCaseStudy(M=M_SCALED, p_mask=P_MASK_SCALED)
    for i, p_gate in enumerate(FIG4_PGATES):
        t0 = time.time()
        res = run_campaign(make_nn_trial(p_gate),
                           jax.random.fold_in(key, 100 + i), cfg,
                           batched=True, name=f"nn p_gate={p_gate:g}")
        p_mult_model = A.p_mult_from_alpha(np.array([p_gate]), alpha,
                                           nl.n_gates)
        model = float(A.nn_misclassification(p_mult_model, cs)[0])
        lo, hi = res.ci
        agree = res.contains(model)
        rows.append((f"campaign_mc.fig4_nn_p{p_gate:g}",
                     (time.time() - t0) * 1e6 / res.n_trials,
                     f"p_hat={res.p_hat:.4f} ci=[{lo:.4f},{hi:.4f}] "
                     f"model={model:.4f} M={M_SCALED} agree={agree}"))
        assert agree, (
            f"fig4_nn p_gate={p_gate:g}: closed form {model:.4f} outside "
            f"Wilson interval [{lo:.4f}, {hi:.4f}] (n={res.n_trials})")

    # TMR (report-only: the analytic form is a stated upper bound)
    p_tmr = FIG4_PGATES[-1]
    t0 = time.time()
    res = run_campaign(make_mult_trial(p_tmr, tmr=True),
                       jax.random.fold_in(key, 200), cfg, batched=True,
                       name=f"tmr p_gate={p_tmr:g}")
    bound = float(A.p_mult_tmr(np.array([p_tmr]), alpha, nl.n_gates)[0])
    lo, hi = res.ci
    rows.append((f"campaign_mc.fig4_tmr_p{p_tmr:g}",
                 (time.time() - t0) * 1e6 / res.n_trials,
                 f"p_hat={res.p_hat:.4f} ci=[{lo:.4f},{hi:.4f}] "
                 f"upper_bound={bound:.4f} below_bound={lo <= bound}"))

    # Fig. 5: long-term ECC-protected weight corruption, swept over p_input
    fig5 = sweep(make_fig5_trial, FIG5_POINTS, jax.random.fold_in(key, 300),
                 cfg, batched=True)
    for pt, res in fig5:
        model = float(A.weight_corruption_ecc(pt["p_input"],
                                              np.array([pt["T"]]), m=32)[0])
        lo, hi = res.ci
        agree = res.contains(model)
        rows.append((f"campaign_mc.fig5_p{pt['p_input']:g}_T{pt['T']}", 0.0,
                     f"p_hat={res.p_hat:.4f} ci=[{lo:.4f},{hi:.4f}] "
                     f"model={model:.4f} n={res.n_trials} "
                     f"corrected={res.extras['corrected']:.0f} "
                     f"uncorrectable={res.extras['uncorrectable']:.0f} "
                     f"agree={agree}"))
        assert agree, (
            f"fig5 {pt}: closed form {model:.4f} outside Wilson interval "
            f"[{lo:.4f}, {hi:.4f}] (n={res.n_trials})")

    # protection-scheme grid: long-term block corruption across the whole
    # repro.reliability design space (jnp backends: trials are vmapped)
    grid_cfg = CampaignConfig(
        batch_size=min(BATCH, 256), max_trials=512 if SMOKE else 1024,
        min_trials=256, ci_halfwidth=0.03, z=Z)
    grid = sweep_schemes(make_scheme_trial, standard_grid(impl="jnp"),
                         jax.random.fold_in(key, 400), grid_cfg)
    p_hats = {}
    for scheme, res in grid:
        lo, hi = res.ci
        p_hats[scheme.name] = res.p_hat
        cost = scheme.overhead()
        rows.append((f"campaign_mc.scheme_{scheme.name}", 0.0,
                     f"p_hat={res.p_hat:.4f} ci=[{lo:.4f},{hi:.4f}] "
                     f"n={res.n_trials} p_input={GRID_P_INPUT:g} T={GRID_T} "
                     f"cost[{cost.describe()}]"))
    # ordering sanity: every protected scheme beats (or ties) the baseline
    for name, p_hat in p_hats.items():
        if name != "unprotected":
            assert p_hat <= p_hats["unprotected"] + 0.02, (
                f"scheme {name} (p_hat={p_hat:.4f}) worse than unprotected "
                f"({p_hats['unprotected']:.4f})")
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
