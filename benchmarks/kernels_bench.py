"""Kernel microbenches: wall time of the jnp reference paths (CPU) and
derived TPU-roofline estimates for the Pallas kernels (which only run in
interpret mode here, so wall clock is meaningless for them — the derived
column reports the bandwidth/FLOP model instead).
"""
from __future__ import annotations

import time

try:                      # package execution: python -m benchmarks.<mod>
    from . import _path   # noqa: F401
except ImportError:       # direct script execution
    import _path          # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reliability import (ReliableStore, encode_words,
                                    protect_leaves, scrub_leaves)
from repro.core.tmr import vote_words
from repro.models.attention import blocked_attention

HBM_BW = 819e9
PEAK = 197e12


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / iters


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    # ECC encode: memory-bound — bytes = buf + parity out
    buf = jax.random.randint(key, (1 << 20,), 0, 1 << 30, jnp.int32).astype(jnp.uint32)
    f = jax.jit(lambda b: encode_words(b))
    us = _time(f, buf) * 1e6
    bytes_moved = buf.nbytes * (1 + 3 / 32)
    rows.append(("kernels.ecc_encode_4MiB", us,
                 f"tpu_roofline_est={bytes_moved/HBM_BW*1e6:.1f}us (memory-bound)"))

    # TMR vote: 3 reads 1 write
    a = jax.random.randint(key, (1 << 20,), 0, 1 << 30, jnp.int32).astype(jnp.uint32)
    fv = jax.jit(lambda a: vote_words(a, a, a))
    us = _time(fv, a) * 1e6
    rows.append(("kernels.tmr_vote_4MiB", us,
                 f"tpu_roofline_est={4*a.nbytes/HBM_BW*1e6:.1f}us (memory-bound)"))

    # scrub engine: arena-fused single launch vs the per-leaf jnp loop on a
    # transformer-shaped 24-leaf pytree (the pre-arena hot path).  Timed
    # eagerly — that is how TrainLoop calls scrub between steps, and the
    # per-leaf path's cost IS its Python/dispatch overhead.
    keys = jax.random.split(key, 24)
    params = {}
    for i in range(8):
        params[f"blk{i}.w"] = jax.random.normal(keys[3 * i], (128, 96), jnp.float32)
        params[f"blk{i}.b"] = jax.random.normal(keys[3 * i + 1], (96,), jnp.float32)
        params[f"blk{i}.scale"] = jax.random.normal(keys[3 * i + 2], (129,), jnp.bfloat16)
    store = ReliableStore.protect(params)
    n_leaves = len(jax.tree.leaves(params))

    def fused_scrub():
        fixed, rep = store.scrub()
        return rep.corrected

    ptree = protect_leaves(params)

    def per_leaf_scrub():
        _, _, rep = scrub_leaves(params, ptree)
        return rep.corrected

    us_fused = _time(fused_scrub, iters=3) * 1e6
    us_leaf = _time(per_leaf_scrub, iters=3) * 1e6
    rows.append((f"kernels.scrub_arena_fused_{n_leaves}leaves", us_fused,
                 f"blocks={store.n_blocks} single fused launch"))
    rows.append((f"kernels.scrub_per_leaf_jnp_{n_leaves}leaves", us_leaf,
                 f"speedup_arena_fused={us_leaf / us_fused:.2f}x"))

    # flash attention fwd (jnp blocked path)
    B, S, H, KV, hd = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: blocked_attention(q, k, v, q_block=256, kv_block=256))
    us = _time(fa, q, k, v) * 1e6
    flops = 2 * B * H * (S * S / 2) * hd * 2
    rows.append((f"kernels.flash_fwd_S{S}", us,
                 f"tpu_roofline_est={flops/PEAK*1e6:.1f}us (compute-bound)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
