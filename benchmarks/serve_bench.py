"""Serving-engine benchmark: tok/s and time-to-first-token per scheme x
execution strategy x batch (DESIGN.md §13).

Two regimes, both exercised through `launch.engine.GenerationEngine`:

* ``launch_*`` rows — a launch-bound configuration (1-layer micro-model,
  long generation) where per-token Python dispatch dominates: the regime
  the scan engine exists for.  Two machine-independent ratios are guarded
  by check_regression here: ``speedup_vs_loop`` on the scan row, and
  ``tmr_amortization`` = 3 x single-copy scan time / vmapped 3-copy time
  on the TMR row — when launches are the cost, the stacked copy axis
  amortizes them (>= 1 means vmapped TMR beats even three sequential
  single-copy runs; 0.33 would be pay-full-3x).
* ``smoke_*`` / ``full_*`` rows — the standard smoke-scale serving config
  across the scheme grid (off / ecc / tmr-serial / tmr-parallel /
  ecc+tmr): absolute tok/s, TTFT, and the informational ``copy3_cost_x``
  diagnostic (vmapped 3-copy time / single-copy scan time; ~4.5-6x on
  XLA:CPU where per-step compute dominates and batched ops run slower
  than sequential ones — on a real accelerator the copy axis shards).
  ``copy3_cost_x`` is deliberately NOT matched by the guard's regexes:
  it divides two exec-bound measurements and is too contention-noisy.

* ``sharded_*`` rows (only when the process has >= 4 devices, i.e. under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``) — the engine on
  forced-host test meshes: off on 1x1 vs 2x2, and tmr-parallel on the
  copy-folded 3x1 mesh where the sharded ``copy3_cost_x`` measures the
  marginal cost of TMR when the copies land on distinct replica groups
  (guarded via its ``tmr_amortization`` ratio; DESIGN.md §14).

TTFT rows time the prefill launch alone (the token a user waits for).
Run: PYTHONPATH=src python -m benchmarks.run --only serve_bench --smoke
"""
from __future__ import annotations

import os
import time

try:
    from . import _path  # noqa: F401
except ImportError:
    import _path  # noqa: F401

import jax

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _bench(fn, repeats: int) -> float:
    """Seconds per call: compile/warmup once, then min over `repeats`."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _engines(cfg, spec, gen, execution="scan", mesh=None):
    from repro.launch.engine import GenerationEngine
    from repro.reliability import parse_scheme
    return GenerationEngine(cfg, parse_scheme(spec), gen=gen,
                            execution=execution, mesh=mesh)


def _batch(cfg, key, B, prompt):
    return {"tokens": jax.random.randint(key, (B, prompt), 0, cfg.vocab)}


def run():
    from repro.configs import get_config
    from repro.models import params as P
    from repro.models import transformer as T

    key = jax.random.PRNGKey(0)
    # min-of-N per row: the guarded ratios divide two independent
    # measurements, so their noise doubles — N is sized for the min to
    # converge on a contended CPU (each repeat is only ~10-100 ms)
    repeats = 9 if SMOKE else 11
    rows = []

    # -- launch-bound regime: dispatch overhead >> per-step compute --------
    lb_cfg = get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=1, n_kv=1, d_ff=32, vocab=512)
    lb_params = P.materialize(key, T.model_specs(lb_cfg))
    LB_GEN, LB_B = 256, 1
    lb_batch = _batch(lb_cfg, key, LB_B, 2)

    e_loop = _engines(lb_cfg, "off", LB_GEN, "loop")
    e_scan = _engines(lb_cfg, "off", LB_GEN, "scan")
    t_loop = _bench(lambda: e_loop.generate(lb_params, lb_batch)[0], repeats)
    t_scan = _bench(lambda: e_scan.generate(lb_params, lb_batch)[0], repeats)
    n_tok = LB_B * LB_GEN
    rows.append((f"serve.launch_off_loop_g{LB_GEN}", t_loop / n_tok * 1e6,
                 f"tok_s={n_tok / t_loop:.5g}"))
    rows.append((f"serve.launch_off_scan_g{LB_GEN}", t_scan / n_tok * 1e6,
                 f"tok_s={n_tok / t_scan:.5g} "
                 f"speedup_vs_loop={t_loop / t_scan:.2f}x"))
    e_tmr = _engines(lb_cfg, "tmr-parallel", LB_GEN)
    lb_store, _ = e_tmr.prepare(lb_params)
    t_tmr = _bench(lambda: e_tmr.generate(lb_store, lb_batch)[0], repeats)
    rows.append((f"serve.launch_tmr_parallel_scan_g{LB_GEN}",
                 t_tmr / n_tok * 1e6,
                 f"tok_s={n_tok / t_tmr:.5g} "
                 f"tmr_amortization={3 * t_scan / t_tmr:.2f}x"))

    # -- model-scale regime: the scheme grid at serving smoke scale --------
    tag = "smoke" if SMOKE else "full"
    cfg = get_config("phi3-mini-3.8b").smoke()
    params = P.materialize(key, T.model_specs(cfg))
    B, PROMPT, GEN = (2, 16, 16) if SMOKE else (4, 32, 48)
    batch = _batch(cfg, key, B, PROMPT)
    n_tok = B * GEN

    t_by_spec = {}
    for spec, execution in (("off", "loop"), ("off", "scan"),
                            ("ecc", "scan"), ("tmr-serial", "scan"),
                            ("tmr-parallel", "scan"),
                            ("ecc+tmr-parallel", "scan")):
        eng = _engines(cfg, spec, GEN, execution)
        store, _ = eng.prepare(params, key=key)
        t = _bench(lambda: eng.generate(store, batch)[0], repeats)
        t_by_spec[(spec, execution)] = t
        name = spec.replace("ecc+tmr-parallel", "compose").replace("-", "_")
        extra = ""
        if (spec, execution) == ("off", "scan"):
            extra = (f" speedup_vs_loop="
                     f"{t_by_spec[('off', 'loop')] / t:.2f}x")
        elif spec == "tmr-parallel":
            extra = (f" copy3_cost_x="
                     f"{t / t_by_spec[('off', 'scan')]:.2f}")
        rows.append((f"serve.{tag}_{name}_{execution}_b{B}_g{GEN}",
                     t / n_tok * 1e6, f"tok_s={n_tok / t:.5g}{extra}"))

    # -- sharded rows: the engine over forced-host-device meshes -----------
    # (DESIGN.md §14; present only when the process has >= 4 devices, i.e.
    # under XLA_FLAGS=--xla_force_host_platform_device_count=4 — the CI
    # sharded smoke job.  check_regression reports them as missing-notes,
    # never failures, on single-device runs.)
    #
    # The headline is the TMR copy-cost on replicas: on mesh 3x1 the copy
    # axis folds onto three disjoint replica groups, so with >= 3 physical
    # cores tmr-parallel's marginal cost over `off` (sharded
    # ``copy3_cost_x``) drops below the single-device ~4.5-6x of the grid
    # rows above, toward 1x on real accelerator replicas — the paper's
    # ride-the-existing-parallelism claim measured end-to-end.  Forced
    # host devices share the machine's cores (a 1-core box pure
    # time-slices: sharded copy3_cost_x ~= the vmapped 4.1x, which still
    # proves the shard_map/collective machinery itself costs ~nothing).
    # The guarded ratio is ``tmr_amortization`` = 3 x t_off(1x1) /
    # t_tmr(3x1); ``speedup_vs_1x1`` / ``tok_s_per_dev`` on the 2x2 row
    # are recorded unguarded (core contention makes scaling numbers
    # machine-shape-dependent).
    if jax.device_count() >= 4:
        from repro.launch.mesh import make_test_mesh
        t_sharded = {}
        for mesh_shape, spec in (((1, 1), "off"), ((2, 2), "off"),
                                 ((3, 1), "tmr-parallel")):
            mesh = make_test_mesh(*mesh_shape)
            eng = _engines(cfg, spec, GEN, mesh=mesh)
            store, _ = eng.prepare(params, key=key)
            t = _bench(lambda: eng.generate(store, batch)[0], repeats)
            t_sharded[(mesh_shape, spec)] = t
            mtag = "x".join(map(str, mesh_shape))
            name = spec.replace("-", "_")
            extra = ""
            if mesh_shape == (2, 2):
                t11 = t_sharded[((1, 1), "off")]
                extra = (f" tok_s_per_dev={n_tok / t / 4:.5g}"
                         f" speedup_vs_1x1={t11 / t:.2f}")
            elif spec == "tmr-parallel":
                t11 = t_sharded[((1, 1), "off")]
                extra = (f" tmr_amortization={3 * t11 / t:.2f}x"
                         f" copy3_cost_x={t / t11:.2f}")
            rows.append((f"serve.sharded_{name}_mesh{mtag}_b{B}_g{GEN}",
                         t / n_tok * 1e6, f"tok_s={n_tok / t:.5g}{extra}"))
        tmr_sh = _engines(cfg, "tmr-parallel", GEN,
                          mesh=make_test_mesh(3, 1))
        store, _ = tmr_sh.prepare(params)
        rows.append((f"serve.ttft_sharded_tmr_parallel_mesh3x1_b{B}",
                     _bench(lambda: tmr_sh.ttft(store, batch),
                            repeats) * 1e6, "-"))

    # -- time-to-first-token: the prefill launch ---------------------------
    off_eng = _engines(cfg, "off", GEN)
    rows.append((f"serve.ttft_{tag}_off_b{B}",
                 _bench(lambda: off_eng.ttft(params, batch), repeats) * 1e6,
                 "-"))
    tmr_eng = _engines(cfg, "tmr-parallel", GEN)
    store, _ = tmr_eng.prepare(params)
    rows.append((f"serve.ttft_{tag}_tmr_parallel_b{B}",
                 _bench(lambda: tmr_eng.ttft(store, batch), repeats) * 1e6,
                 "-"))

    # -- latency tails: chunk-compiled generation (DESIGN.md §15) ----------
    # Per-chunk host timestamps from LatencyTimeline give real TTFT/TPOT
    # distributions (the serving SLO quantities) rather than a single
    # whole-run mean.  The row value is the TPOT p50 in µs so the
    # machine-factor normalization treats it like any other timing; the
    # p99s ride along in `derived` as guarded time metrics — a scheduling
    # or voting change that fattens only the tail moves p99 while leaving
    # tok_s means untouched.
    from repro.obs import Histogram
    CHUNK = 4
    for spec in ("off", "tmr-parallel"):
        eng = _engines(cfg, spec, GEN)
        store, _ = eng.prepare(params, key=key)
        # warmup compiles the prefill + chunk launches
        jax.block_until_ready(
            eng.generate_chunked(store, batch, chunk=CHUNK)[0])
        ttft_h, tpot_h = Histogram(), Histogram()
        for _ in range(repeats):
            _, _, tl = eng.generate_chunked(store, batch, chunk=CHUNK)
            ttft_h.record(tl.ttft_s)
            tpot_h.extend(tl.tpot_samples())
        name = spec.replace("-", "_")
        rows.append((
            f"serve.lat_{tag}_{name}_b{B}_g{GEN}",
            tpot_h.percentile(50) * 1e6,
            f"ttft_p50={ttft_h.percentile(50) * 1e6:.5g}us "
            f"ttft_p99={ttft_h.percentile(99) * 1e6:.5g}us "
            f"tpot_p50={tpot_h.percentile(50) * 1e6:.5g}us "
            f"tpot_p99={tpot_h.percentile(99) * 1e6:.5g}us "
            f"chunk={CHUNK}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
