"""Serving-engine benchmark: tok/s and time-to-first-token per scheme x
execution strategy x batch (DESIGN.md §13).

Two regimes, both exercised through `launch.engine.GenerationEngine`:

* ``launch_*`` rows — a launch-bound configuration (1-layer micro-model,
  long generation) where per-token Python dispatch dominates: the regime
  the scan engine exists for.  Two machine-independent ratios are guarded
  by check_regression here: ``speedup_vs_loop`` on the scan row, and
  ``tmr_amortization`` = 3 x single-copy scan time / vmapped 3-copy time
  on the TMR row — when launches are the cost, the stacked copy axis
  amortizes them (>= 1 means vmapped TMR beats even three sequential
  single-copy runs; 0.33 would be pay-full-3x).
* ``smoke_*`` / ``full_*`` rows — the standard smoke-scale serving config
  across the scheme grid (off / ecc / tmr-serial / tmr-parallel /
  ecc+tmr): absolute tok/s, TTFT, and the informational ``copy3_cost_x``
  diagnostic (vmapped 3-copy time / single-copy scan time; ~4.5-6x on
  XLA:CPU where per-step compute dominates and batched ops run slower
  than sequential ones — on a real accelerator the copy axis shards).
  ``copy3_cost_x`` is deliberately NOT matched by the guard's regexes:
  it divides two exec-bound measurements and is too contention-noisy.

TTFT rows time the prefill launch alone (the token a user waits for).
Run: PYTHONPATH=src python -m benchmarks.run --only serve_bench --smoke
"""
from __future__ import annotations

import os
import time

try:
    from . import _path  # noqa: F401
except ImportError:
    import _path  # noqa: F401

import jax

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _bench(fn, repeats: int) -> float:
    """Seconds per call: compile/warmup once, then min over `repeats`."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _engines(cfg, spec, gen, execution="scan"):
    from repro.launch.engine import GenerationEngine
    from repro.reliability import parse_scheme
    return GenerationEngine(cfg, parse_scheme(spec), gen=gen,
                            execution=execution)


def _batch(cfg, key, B, prompt):
    return {"tokens": jax.random.randint(key, (B, prompt), 0, cfg.vocab)}


def run():
    from repro.configs import get_config
    from repro.models import params as P
    from repro.models import transformer as T

    key = jax.random.PRNGKey(0)
    # min-of-N per row: the guarded ratios divide two independent
    # measurements, so their noise doubles — N is sized for the min to
    # converge on a contended CPU (each repeat is only ~10-100 ms)
    repeats = 9 if SMOKE else 11
    rows = []

    # -- launch-bound regime: dispatch overhead >> per-step compute --------
    lb_cfg = get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=1, n_kv=1, d_ff=32, vocab=512)
    lb_params = P.materialize(key, T.model_specs(lb_cfg))
    LB_GEN, LB_B = 256, 1
    lb_batch = _batch(lb_cfg, key, LB_B, 2)

    e_loop = _engines(lb_cfg, "off", LB_GEN, "loop")
    e_scan = _engines(lb_cfg, "off", LB_GEN, "scan")
    t_loop = _bench(lambda: e_loop.generate(lb_params, lb_batch)[0], repeats)
    t_scan = _bench(lambda: e_scan.generate(lb_params, lb_batch)[0], repeats)
    n_tok = LB_B * LB_GEN
    rows.append((f"serve.launch_off_loop_g{LB_GEN}", t_loop / n_tok * 1e6,
                 f"tok_s={n_tok / t_loop:.5g}"))
    rows.append((f"serve.launch_off_scan_g{LB_GEN}", t_scan / n_tok * 1e6,
                 f"tok_s={n_tok / t_scan:.5g} "
                 f"speedup_vs_loop={t_loop / t_scan:.2f}x"))
    e_tmr = _engines(lb_cfg, "tmr-parallel", LB_GEN)
    lb_store, _ = e_tmr.prepare(lb_params)
    t_tmr = _bench(lambda: e_tmr.generate(lb_store, lb_batch)[0], repeats)
    rows.append((f"serve.launch_tmr_parallel_scan_g{LB_GEN}",
                 t_tmr / n_tok * 1e6,
                 f"tok_s={n_tok / t_tmr:.5g} "
                 f"tmr_amortization={3 * t_scan / t_tmr:.2f}x"))

    # -- model-scale regime: the scheme grid at serving smoke scale --------
    tag = "smoke" if SMOKE else "full"
    cfg = get_config("phi3-mini-3.8b").smoke()
    params = P.materialize(key, T.model_specs(cfg))
    B, PROMPT, GEN = (2, 16, 16) if SMOKE else (4, 32, 48)
    batch = _batch(cfg, key, B, PROMPT)
    n_tok = B * GEN

    t_by_spec = {}
    for spec, execution in (("off", "loop"), ("off", "scan"),
                            ("ecc", "scan"), ("tmr-serial", "scan"),
                            ("tmr-parallel", "scan"),
                            ("ecc+tmr-parallel", "scan")):
        eng = _engines(cfg, spec, GEN, execution)
        store, _ = eng.prepare(params, key=key)
        t = _bench(lambda: eng.generate(store, batch)[0], repeats)
        t_by_spec[(spec, execution)] = t
        name = spec.replace("ecc+tmr-parallel", "compose").replace("-", "_")
        extra = ""
        if (spec, execution) == ("off", "scan"):
            extra = (f" speedup_vs_loop="
                     f"{t_by_spec[('off', 'loop')] / t:.2f}x")
        elif spec == "tmr-parallel":
            extra = (f" copy3_cost_x="
                     f"{t / t_by_spec[('off', 'scan')]:.2f}")
        rows.append((f"serve.{tag}_{name}_{execution}_b{B}_g{GEN}",
                     t / n_tok * 1e6, f"tok_s={n_tok / t:.5g}{extra}"))

    # -- time-to-first-token: the prefill launch ---------------------------
    off_eng = _engines(cfg, "off", GEN)
    rows.append((f"serve.ttft_{tag}_off_b{B}",
                 _bench(lambda: off_eng.ttft(params, batch), repeats) * 1e6,
                 "-"))
    tmr_eng = _engines(cfg, "tmr-parallel", GEN)
    store, _ = tmr_eng.prepare(params)
    rows.append((f"serve.ttft_{tag}_tmr_parallel_b{B}",
                 _bench(lambda: tmr_eng.ttft(store, batch), repeats) * 1e6,
                 "-"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
