"""Pay-as-you-fault frontier: coverage vs serving overhead across the
code zoo (DESIGN.md §18) and the scrub disciplines that maintain it.

Two questions, one benchmark:

1. **The frontier** — for each arena code x discipline x scrub interval
   (diag parity vs Hsiao SEC-DED, scrub-only vs write-back-on-read,
   interval swept), serve the same trace under per-tick KV-pool fault
   injection and report wall-clock ``tok_s`` next to observed
   ``coverage`` (fraction of emitted tokens bit-identical to the
   fault-free reference).  More protection costs throughput; the rows
   ARE the trade-off curve `sweep_schemes`-style consumers plot.

2. **The adaptive headline** — at a LOW fault rate, the
   `runtime.AdaptiveScrub` controller backs the scrub interval off and
   must recover most of ECC's tok/s gap vs a conservative fixed cadence:
   ``adaptive_speedup`` (fixed wall time / adaptive wall time, same
   trace, machine-independent) is asserted >= 1.1x here AND guarded as a
   ratio row by check_regression.  At a HIGH fault rate the controller
   slams the interval to its floor, and its coverage must not fall below
   the fixed cadence's Wilson 95% lower bound — backing off must never
   cost correctness when the store is actually storming.

Determinism: faults are drawn from per-tick fold_in keys, the trace is
fixed-seed, and the controller is a pure function of observed counts —
reruns reproduce the same schedule and the same tokens.

Run: PYTHONPATH=src python -m benchmarks.run --only ecc_frontier --smoke
"""
from __future__ import annotations

import math
import os
import time

try:
    from . import _path  # noqa: F401
except ImportError:
    import _path  # noqa: F401

import jax
import numpy as np

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

#: KV-pool per-bit fault rates per scheduler tick: the quiet regime the
#: controller should back off in (low enough that events/scrub stays
#: under the hysteresis band even at max_interval, so the controller
#: rails at its ceiling and the fixed-cadence gap is structural, not
#: noise), and the storm it must slam on
P_LOW, P_HIGH = 1e-8, 2e-4


def wilson_lower(successes: int, n: int, z: float = 1.96) -> float:
    """Wilson-score 95% lower bound on a binomial proportion."""
    if n == 0:
        return 0.0
    p = successes / n
    denom = 1.0 + z * z / n
    center = p + z * z / (2 * n)
    margin = z * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n))
    return max(0.0, (center - margin) / denom)


def run():
    from repro.configs import get_config
    from repro.faults import TransientBitFlips
    from repro.launch import BatchSpec, ContinuousBatcher, fetch_telemetry, \
        poisson_trace
    from repro.models import params as P
    from repro.models import transformer as T
    from repro.reliability import parse_scheme
    from repro.runtime import AdaptiveScrub, AdaptiveScrubConfig

    key = jax.random.PRNGKey(0)
    # small model, big pool: the scrub/decode cost ratio — the thing the
    # adaptive controller optimizes — is set by the KV pool the scrubs
    # cover, not by the weight matmuls
    cfg = get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=2, d_model=32, n_heads=4, n_kv=4, d_ff=64, vocab=512)
    params = P.materialize(key, T.model_specs(cfg))
    GEN_CAP, N = (64, 8) if SMOKE else (96, 16)
    # a serving-sized pool (8 slots x 5 pages x 16 tokens): the pool
    # arena the scrub covers is ~4x the tick's decode compute at this
    # model scale, so the fixed-cadence scrub tax — and the controller's
    # room to recover it — is structural, not timing noise
    spec = BatchSpec(slots=8, page_tokens=16, chunk=2, prompt_buckets=(8,),
                     gen_cap=GEN_CAP)
    trace = poisson_trace(N, rate_rps=100.0, spec=spec, vocab=cfg.vocab,
                          seed=0)
    useful = sum(r.gen for r in trace)

    def serve(scheme_tok, p_bit, *, scrub_every=0, adaptive=None,
              timed_reps=1, inject_every=1):
        """One configuration over the trace: returns (best wall seconds,
        results, batcher).  Faults hit the KV pool between ticks from
        per-tick keys — identical across configurations.  inject_every
        amortizes the injection launch itself (exposure-scaled via dt) so
        sparse-fault timing rows measure the scrub tax, not the fault
        generator's RNG cost."""
        b = ContinuousBatcher(cfg, parse_scheme(scheme_tok), spec,
                              scrub_every=scrub_every, adaptive=adaptive)
        b.prepare(params, key=key)
        if p_bit > 0:
            fault = TransientBitFlips(p_bit)
            k0 = jax.random.PRNGKey(1234)

            def inject(bb):
                if bb.ticks % inject_every == 0:
                    bb.pool.corrupt(jax.random.fold_in(k0, bb.ticks),
                                    fault, dt=float(inject_every))
            b.on_tick = inject
        b.run(trace)                                   # compile/warmup
        t_best, results = float("inf"), None
        for _ in range(timed_reps):
            t0 = time.perf_counter()
            res = b.run(trace)
            dt = time.perf_counter() - t0
            if dt < t_best:
                t_best, results = dt, res
        return t_best, results, b

    def coverage(results, reference):
        match = sum(int(np.sum(r.tokens == reference[r.rid]))
                    for r in results)
        return match, useful

    # fault-free reference tokens (identical under every scheme)
    _, ref_res, _ = serve("off", 0.0)
    ref = {r.rid: r.tokens for r in ref_res}

    rows = []

    # -- 1. the frontier: code x discipline x interval at P_HIGH ----------
    codes = ("off", "ecc", "ecc-wb", "hsiao", "hsiao-wb")
    intervals = (1, 4) if SMOKE else (1, 2, 8)
    for tok in codes:
        for iv in ((0,) if tok == "off" else intervals):
            t, res, b = serve(tok, P_HIGH, scrub_every=iv)
            match, n = coverage(res, ref)
            telem = {k: int(v) for k, v in
                     fetch_telemetry(b.telemetry()).items()
                     if k.startswith("ecc")}
            name = f"ecc_frontier.frontier_{tok}" \
                + (f"_i{iv}" if iv else "")
            rows.append((name, t / useful * 1e6,
                         f"tok_s={useful / t:.5g} "
                         f"coverage={match / n:.4f} "
                         f"coverage_lo95={wilson_lower(match, n):.4f} "
                         f"corrected={telem.get('ecc_corrected', 0)} "
                         f"uncorrectable="
                         f"{telem.get('ecc_uncorrectable', 0)} "
                         f"read_corrected="
                         f"{telem.get('ecc_read_corrected', 0)}"))

    # protection must buy coverage at the storm point: every ECC row at
    # the shortest interval covers at least as much as unprotected
    cov = {r[0]: float(r[2].split("coverage=")[1].split()[0])
           for r in rows}
    off_cov = cov["ecc_frontier.frontier_off"]
    for tok in ("ecc", "hsiao", "ecc-wb", "hsiao-wb"):
        assert cov[f"ecc_frontier.frontier_{tok}_i1"] >= off_cov, \
            (tok, cov)

    # -- 2a. adaptive headline at P_LOW: recover the quiet-store tax ------
    def fresh_ctl():
        return AdaptiveScrub(AdaptiveScrubConfig(
            interval0=1, min_interval=1,
            max_interval=64 if SMOKE else 256, patience=1))

    t_fixed, _, _ = serve("hsiao", P_LOW, scrub_every=1, timed_reps=3,
                          inject_every=8)
    t_adapt, res_a, b_a = serve("hsiao", P_LOW, adaptive=fresh_ctl(),
                                timed_reps=3, inject_every=8)
    match_a, n = coverage(res_a, ref)
    speedup = t_fixed / t_adapt
    rows.append(("ecc_frontier.adaptive_low_fault",
                 t_adapt / useful * 1e6,
                 f"tok_s={useful / t_adapt:.5g} "
                 f"adaptive_speedup={speedup:.2f}x "
                 f"coverage={match_a / n:.4f} "
                 f"interval_final={b_a.adaptive.interval} "
                 f"scrubs={len(b_a.scrub_ticks)}"))
    assert speedup >= 1.1, \
        f"adaptive scrub recovered only {speedup:.2f}x vs fixed " \
        f"(acceptance: >= 1.1x at p_bit={P_LOW:g})"

    # -- 2b. adaptive at P_HIGH: no coverage loss (Wilson 95%) ------------
    _, res_f, _ = serve("hsiao", P_HIGH, scrub_every=1)
    match_f, n = coverage(res_f, ref)
    _, res_s, b_s = serve("hsiao", P_HIGH, adaptive=fresh_ctl())
    match_s, _ = coverage(res_s, ref)
    lo = wilson_lower(match_f, n)
    rows.append(("ecc_frontier.adaptive_high_fault", 0.0,
                 f"coverage={match_s / n:.4f} "
                 f"fixed_coverage={match_f / n:.4f} "
                 f"fixed_lo95={lo:.4f} "
                 f"interval_final={b_s.adaptive.interval} "
                 f"scrubs={len(b_s.scrub_ticks)}"))
    assert match_s / n >= lo, \
        f"adaptive coverage {match_s / n:.4f} fell below the fixed " \
        f"cadence's Wilson lower bound {lo:.4f} at p_bit={P_HIGH:g}"
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
