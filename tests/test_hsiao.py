"""Golden bit-exactness for the (39,32) Hsiao SEC-DED arena code
(DESIGN.md §18): the fused Pallas scrub must agree with the jnp oracle
word-for-word on clean buffers, single flips (corrected, exact counters),
parity-word flips (healed, not charged to data) and double flips in one
word (DETECTED — reported uncorrectable, never silently miscorrected);
the `HsiaoSecDed` scheme must restore pytrees bit-exactly, compose with
TMR, serve through the generation engine, and scrub identically when the
arena is shard_map'd over a forced-host mesh."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels.hsiao_secded import (N_CHECKS, encode_hsiao, scrub,
                                        scrub_sharded)
from repro.kernels.hsiao_secded.ref import encode_hsiao_ref, scrub_hsiao_ref
from repro.launch import BatchSpec, ContinuousBatcher, Request
from repro.launch.mesh import make_test_mesh
from repro.models import params as P
from repro.models import transformer as T
from repro.reliability import (Compose, DiagParityEcc, HsiaoSecDed, Tmr,
                               parse_scheme, standard_grid)

MULTI = jax.device_count() >= 4
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture
def key():
    return jax.random.PRNGKey(7)


def _buf(key, n_blocks=6):
    return jax.random.randint(key, (n_blocks * 32,), 0, 1 << 30,
                              jnp.uint32) << 2 | 1


def _flip(buf, idx, bit):
    return buf.at[idx].set(buf[idx] ^ jnp.uint32(1 << bit))


# -- kernel vs oracle ---------------------------------------------------------

@pytest.mark.parametrize("n_blocks", [1, 3, 17])
def test_encode_matches_oracle(key, n_blocks):
    buf = _buf(key, n_blocks)
    got = encode_hsiao(buf)
    want = encode_hsiao_ref(buf)
    assert got.shape == (n_blocks, N_CHECKS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scrub_clean_is_identity(key):
    buf = _buf(key)
    par = encode_hsiao(buf)
    fixed, par2, counts = scrub(buf, par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(par))
    assert np.asarray(counts).tolist() == [0, 0, 0]


@pytest.mark.parametrize("idx,bit", [(0, 0), (5, 31), (37, 13), (191, 7)])
def test_scrub_corrects_single_flip(key, idx, bit):
    buf = _buf(key)
    par = encode_hsiao(buf)
    bad = _flip(buf, idx, bit)
    fixed, par2, counts = scrub(bad, par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(par))
    assert np.asarray(counts).tolist() == [1, 0, 0]
    # and the oracle agrees on every output
    rfixed, rpar, rcounts = scrub_hsiao_ref(bad, par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(rfixed))
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(rpar))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rcounts))


def test_one_flip_per_word_all_corrected(key):
    """Per-WORD correction: diag parity's one-per-32-word-block budget
    does not apply — every word of a block may flip once and all heal."""
    buf = _buf(key, 2)
    par = encode_hsiao(buf)
    bad = buf
    for i in range(64):
        bad = _flip(bad, i, (7 * i) % 32)
    fixed, par2, counts = scrub(bad, par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))
    assert np.asarray(counts).tolist() == [64, 0, 0]


def test_parity_word_flip_healed_not_charged(key):
    buf = _buf(key)
    par = encode_hsiao(buf)
    bad_par = par.at[2, 3].set(par[2, 3] ^ jnp.uint32(1 << 21))
    fixed, par2, counts = scrub(buf, bad_par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(par))
    c = np.asarray(counts)
    assert c[1] >= 1 and c[0] == 0 and c[2] == 0
    r = scrub_hsiao_ref(buf, bad_par)
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(r[1]))
    np.testing.assert_array_equal(c, np.asarray(r[2]))


def test_double_flip_same_word_detected_not_miscorrected(key):
    """The SEC-DED contract: two flips in one word produce an even-weight
    nonzero syndrome — DETECTED, counted uncorrectable, and the word is
    left alone rather than 'corrected' into a third wrong value."""
    buf = _buf(key)
    par = encode_hsiao(buf)
    bad = _flip(_flip(buf, 9, 4), 9, 27)
    fixed, par2, counts = scrub(bad, par)
    assert np.asarray(counts).tolist() == [0, 0, 1]
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(bad))
    r = scrub_hsiao_ref(bad, par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(r[0]))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(r[2]))


def test_double_flip_different_words_both_corrected(key):
    """...whereas two flips in DIFFERENT words of the same 32-word block
    — the exact pattern that defeats diagonal parity — both correct."""
    buf = _buf(key, 1)
    par = encode_hsiao(buf)
    bad = _flip(_flip(buf, 3, 11), 29, 30)
    fixed, _, counts = scrub(bad, par)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))
    assert np.asarray(counts).tolist() == [2, 0, 0]
    # diag parity on the same corruption: one block, two errors -> lost
    diag = DiagParityEcc()
    dpar = diag.encode_arena(bad ^ buf ^ buf)  # encode the CLEAN buf
    dpar = diag.encode_arena(buf)
    _, _, dcounts = diag.scrub_arena(bad, dpar)
    assert int(np.asarray(dcounts)[2]) >= 1


def test_random_flip_fuzz_matches_oracle(key):
    """Randomized masks (0-3 flips per word) — kernel and oracle agree on
    every word and every counter."""
    buf = _buf(key, 8)
    par = encode_hsiao(buf)
    for i in range(4):
        k = jax.random.fold_in(key, 100 + i)
        mask = jnp.where(
            jax.random.uniform(k, buf.shape) < 0.05,
            jax.random.randint(jax.random.fold_in(k, 1), buf.shape, 0,
                               jnp.iinfo(jnp.int32).max, jnp.uint32)
            & jnp.uint32(0x80000001), 0).astype(jnp.uint32)
        bad = buf ^ mask
        got = scrub(bad, par)
        want = scrub_hsiao_ref(bad, par)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# -- scheme level -------------------------------------------------------------

def _params(key):
    return {"a": jax.random.normal(key, (65, 7), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (129,),
                                   jnp.bfloat16)}


def test_scheme_protect_scrub_restores(key):
    params = _params(key)
    scheme = HsiaoSecDed()
    assert scheme.name == "hsiao" and scheme.n_parity_words == 7
    assert HsiaoSecDed(write_back=True).name == "hsiao-wb"
    prot = scheme.protect(params)
    u = jax.lax.bitcast_convert_type(prot.payload["a"],
                                     jnp.uint32).reshape(-1)
    bad = dict(prot.payload,
               a=jax.lax.bitcast_convert_type(
                   u.at[11].set(u[11] ^ jnp.uint32(1 << 19)).reshape(
                       params["a"].shape), jnp.float32))
    prot = scheme.adopt(bad, prot.redundancy)
    fixed, report = scheme.scrub(prot)
    np.testing.assert_array_equal(np.asarray(fixed.payload["a"]),
                                  np.asarray(params["a"]))
    assert int(report.corrected) == 1 and int(report.uncorrectable) == 0
    # write-back-on-read: corrected view AND the store heals
    pay, prot2, r2 = HsiaoSecDed(write_back=True).read_corrected(
        scheme.adopt(bad, scheme.protect(params).redundancy))
    np.testing.assert_array_equal(np.asarray(pay["a"]),
                                  np.asarray(params["a"]))
    assert int(r2.corrected) == 1


def test_compose_with_tmr_recovers_word_double_error(key):
    """hsiao+tmr: a double flip in one word is uncorrectable for the code
    alone but the vote across copies recovers it."""
    params = _params(key)
    comp = parse_scheme("hsiao+tmr-serial")
    assert isinstance(comp, Compose) and isinstance(comp.ecc, HsiaoSecDed)
    prot = comp.protect(params)
    u = jax.lax.bitcast_convert_type(params["a"], jnp.uint32).reshape(-1)
    u = u.at[5].set(u[5] ^ jnp.uint32((1 << 3) | (1 << 17)))
    bad = dict(params, a=jax.lax.bitcast_convert_type(
        u.reshape(params["a"].shape), jnp.float32))
    fixed, report = comp.scrub(comp.adopt(bad, prot.redundancy))
    # the word is SEC-DED-dead on copy 0 but the vote recovers it — and
    # because it was detected (not miscorrected), nothing surfaces as
    # uncorrectable at the composition level
    assert int(report.uncorrectable) == 0
    np.testing.assert_array_equal(np.asarray(fixed.payload["a"]),
                                  np.asarray(params["a"]))
    np.testing.assert_array_equal(np.asarray(comp.read(fixed)["a"]),
                                  np.asarray(params["a"]))


def test_standard_grid_gains_hsiao_only_on_request():
    base = [s.name for s in standard_grid()]
    assert "hsiao" not in "".join(base)
    full = [s.name for s in standard_grid(include_hsiao=True)]
    assert "hsiao" in full and "hsiao+tmr-serial" in full
    assert [n for n in full if "hsiao" not in n] == base
    for s in standard_grid(include_hsiao=True):
        c = s.overhead()
        assert c.storage_x >= 1.0 and c.throughput_x <= 1.0
    # storage accounting: 7 parity words per 32 data words vs diag's 3
    assert HsiaoSecDed().overhead().storage_x == pytest.approx(1 + 7 / 32)
    assert DiagParityEcc().overhead().storage_x == pytest.approx(1 + 3 / 32)


def test_parse_scheme_hsiao_tokens():
    assert isinstance(parse_scheme("hsiao"), HsiaoSecDed)
    assert parse_scheme("hsiao-wb").write_back
    assert not parse_scheme("hsiao").write_back
    comp = parse_scheme("tmr-parallel+hsiao")
    assert isinstance(comp.ecc, HsiaoSecDed)
    assert comp.tmr.discipline == "parallel"


# -- serving integration ------------------------------------------------------

def _tiny_setup(key):
    cfg = get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32, vocab=512)
    params = P.materialize(key, T.model_specs(cfg))
    prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, 9),
                                           (4,), 0, cfg.vocab))
    return cfg, params, prompt


@pytest.mark.parametrize("name", ["hsiao", "hsiao-wb", "hsiao+tmr-serial"])
def test_batcher_serves_hsiao_bit_exact_vs_off(key, name):
    """Fault-free serving under every hsiao scheme emits exactly the
    unprotected engine's tokens (correction is a no-op on clean bits)."""
    cfg, params, prompt = _tiny_setup(key)
    spec = BatchSpec(slots=2, page_tokens=8, chunk=3, prompt_buckets=(4,),
                     gen_cap=6)

    def serve(tok):
        b = ContinuousBatcher(cfg, parse_scheme(tok), spec)
        b.prepare(params, key=key)
        return b.run([Request(1, prompt, 5, arrival_s=0.0)])[0]

    ref = serve("off")
    got = serve(name)
    np.testing.assert_array_equal(got.tokens, ref.tokens)


@needs_devices
def test_scrub_sharded_matches_local(key):
    """Mesh-sharded scrub: the word-local op composes exactly across a
    forced-host mesh — same corrected buffer, same counts."""
    mesh = make_test_mesh(2, 2)
    buf = _buf(key, 8)
    par = encode_hsiao(buf)
    bad = _flip(_flip(buf, 33, 12), 200, 30)
    lf, lp, lc = scrub(bad, par)
    sf, sp, sc = scrub_sharded(bad, par, mesh=mesh,
                               axes=("data", "model"))
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(lf))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(lp))
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(lc))
