import numpy as np

from repro.data import Prefetcher, ShardedLoader, SyntheticLM


def test_determinism():
    a = SyntheticLM(vocab=100, seq_len=32, batch_per_rank=4, seed=7)
    b = SyntheticLM(vocab=100, seq_len=32, batch_per_rank=4, seed=7)
    assert np.array_equal(a.batch_at(5), b.batch_at(5))
    assert not np.array_equal(a.batch_at(5), a.batch_at(6))


def test_ranks_disjoint():
    r0 = SyntheticLM(vocab=100, seq_len=32, batch_per_rank=4, rank=0, world=4)
    r1 = SyntheticLM(vocab=100, seq_len=32, batch_per_rank=4, rank=1, world=4)
    assert not np.array_equal(r0.batch_at(0), r1.batch_at(0))


def test_learnable_structure():
    """Most transitions follow the Markov rule (a learnable backbone)."""
    d = SyntheticLM(vocab=1000, seq_len=256, batch_per_rank=8)
    b = d.batch_at(0)
    follows = (b[:, 1:] == (31 * b[:, :-1] + 17) % 1000).mean()
    assert follows > 0.7


def test_tokens_in_range():
    d = SyntheticLM(vocab=50, seq_len=16, batch_per_rank=2)
    b = d.batch_at(3)
    assert b.min() >= 0 and b.max() < 50


def test_prefetcher_preserves_order_and_closes():
    pf = Prefetcher(iter(range(10)), depth=3)
    assert list(pf) == list(range(10))
    pf2 = Prefetcher(iter(range(1000)), depth=2)
    next(pf2)
    pf2.close()


def test_sharded_loader_concat():
    ld = ShardedLoader(lambda r, w: SyntheticLM(vocab=100, seq_len=8,
                                                batch_per_rank=2, rank=r, world=w),
                       world=3)
    b = ld.batch_at(0)
    assert b.shape == (6, 8)
