import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only the dry-run (and the subprocess
# sharding tests) force placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks pkg
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_compat shim

import jax
import pytest

jax.config.update("jax_default_matmul_precision", "float32")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
