"""Per-architecture smoke tests: reduced same-family configs, one forward +
train step on CPU, shape/NaN assertions, prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import params as P
from repro.models import transformer as T
from repro.models import steps
from repro.optim import AdamWConfig

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_emb"] = jax.random.normal(key, (B, cfg.vis_tokens, cfg.vis_dim),
                                             jnp.float32)
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=list_archs())
def arch_setup(request):
    key = jax.random.PRNGKey(0)
    cfg = get_config(request.param).smoke().replace(compute_dtype="float32")
    params = P.materialize(key, T.model_specs(cfg))
    return request.param, cfg, params, _batch(cfg, key)


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, params, batch = arch_setup
    h, aux = T.forward(params, cfg, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    assert np.isfinite(float(aux))


def test_train_step_finite(arch_setup):
    arch, cfg, params, batch = arch_setup
    ts = steps.make_train_step(cfg, AdamWConfig(total_steps=10))
    state, m = jax.jit(ts)(steps.init_train_state(params), batch)
    assert np.isfinite(float(m["total"]))
    assert np.isfinite(float(m["grad_norm"]))


def test_prefill_matches_forward_and_decode_runs(arch_setup):
    arch, cfg, params, batch = arch_setup
    h, _ = T.forward(params, cfg, batch)
    pf = jax.jit(steps.make_prefill_step(cfg, cache_len=S + 4))
    dc = jax.jit(steps.make_decode_step(cfg))
    tok, logits, cache = pf(params, batch)
    ref = (h[:, -1:, :] @ steps.head_weights(params, cfg).astype(h.dtype)
           ).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    tok2, logits2, cache2 = dc(params, tok, cache)
    assert int(cache2["pos"]) == S + 1
    assert np.isfinite(np.asarray(logits2)).all()


def test_decode_matches_teacher_forcing(arch_setup):
    """Decoding token-by-token must equal a full forward over the same
    prefix (strict causality + cache correctness)."""
    arch, cfg, params, batch = arch_setup
    pf = jax.jit(steps.make_prefill_step(cfg, cache_len=S + 4))
    dc = jax.jit(steps.make_decode_step(cfg))
    tok, logits, cache = pf(params, batch)
    # decode 3 forced tokens, then compare logits with a fresh prefill over
    # the extended prompt
    forced = jax.random.randint(jax.random.PRNGKey(7), (B, 3), 0, cfg.vocab)
    for i in range(3):
        tok_i = forced[:, i:i + 1]
        _, logits_dec, cache = dc(params, tok_i, cache)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], forced], axis=1)
    pf2 = jax.jit(steps.make_prefill_step(cfg, cache_len=S + 4))
    _, logits_full, _ = pf2(params, ext)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=3e-3, atol=3e-3)


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned shapes."""
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (95, 8192, 64, 8, 22016, 102400)
    c = get_config("nemotron-4-15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (32, 6144, 48, 8, 24576, 256000)
    assert c.act == "relu2"
    c = get_config("qwen2.5-14b")
    assert c.qkv_bias and c.d_ff == 13824 and c.vocab == 152064
    c = get_config("llama4-maverick-400b-a17b")
    assert c.moe_experts == 128 and c.moe_topk == 1
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert c.moe_experts == 16 and c.moe_topk == 2
    c = get_config("mamba2-130m")
    assert c.ssm_state == 128 and c.n_layers == 24 and c.d_model == 768
    c = get_config("recurrentgemma-2b")
    assert c.layer_pattern == ("R", "R", "A") and c.n_kv == 1
    c = get_config("llama-3.2-vision-11b")
    assert c.cross_attn_every == 5 and c.n_layers == 40
    c = get_config("seamless-m4t-medium")
    assert c.enc_layers == 12 and c.vocab == 256206
