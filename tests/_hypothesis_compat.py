"""Hypothesis shim: property tests with a deterministic fallback sweep.

When `hypothesis` is installed (declared in pyproject/requirements), the
real library is re-exported unchanged and the property tests run as
written.  When it is missing (minimal containers), `given`/`settings`/`st`
degrade to a deterministic parametrized sweep: each strategy draws from a
`random.Random` seeded by the test name, so every run exercises the same
fixed sample of the space instead of erroring at collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sampler):
            self._sampler = sampler

        def sample(self, rng: random.Random):
            return self._sampler(rng)

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [elements.sample(rng) for _ in
                                          range(rng.randint(min_size, max_size))])

    def settings(**kwargs):
        max_examples = kwargs.get("max_examples", _FALLBACK_EXAMPLES)

        def deco(fn):
            fn._compat_examples = min(max_examples, _FALLBACK_EXAMPLES)
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            n = getattr(fn, "_compat_examples", _FALLBACK_EXAMPLES)

            def sweep():
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    args = [s.sample(rng) for s in arg_strategies]
                    kwargs = {k: s.sample(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            sweep.__name__ = fn.__name__
            sweep.__doc__ = fn.__doc__
            return sweep
        return deco
