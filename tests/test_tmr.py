"""TMR voting properties (paper §V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import tmr
from repro.faults import inject_bit_flips


def test_vote_identity(key):
    x = jax.random.normal(key, (16, 16))
    assert (tmr.vote_array(x, x, x) == x).all()


@given(seed=st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_vote_corrects_any_single_corrupted_copy(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (32, 8), jnp.float32)
    bad = inject_bit_flips(x, jax.random.fold_in(key, 1), 0.05)
    for copies in [(bad, x, x), (x, bad, x), (x, x, bad)]:
        assert (tmr.vote_array(*copies) == x).all()


def test_per_bit_beats_per_element():
    """Paper's example: copies 1000, 0100, 0010 -> per-bit votes 0000."""
    a = jnp.array([0b1000], jnp.uint32)
    b = jnp.array([0b0100], jnp.uint32)
    c = jnp.array([0b0010], jnp.uint32)
    assert int(tmr.vote_words(a, b, c)[0]) == 0


def test_vote_bits_nonideal_injection(key):
    a = jax.random.bernoulli(key, 0.5, (1000,))
    out = tmr.vote_bits(a, a, a, key=jax.random.fold_in(key, 7), p_gate=0.2)
    # two fault-injected gates per bit: output must differ from a somewhere
    assert bool((out != a).any())


def test_tmr_wrapper_serial_and_parallel(key):
    def noisy_fn(k, x):
        flip = jax.random.bernoulli(k, 0.2, x.shape)
        return jnp.where(flip, -x, x)

    x = jax.random.normal(key, (64,))
    for mode in ("serial", "parallel"):
        wrapped = tmr.tmr(noisy_fn, mode=mode)
        out = wrapped(key, x)
        # majority of 3 copies with p=0.2 iid sign flips: expected wrong
        # fraction ~ 3p^2 - 2p^3 ~ 0.10; all-correct is overwhelmingly
        # unlikely to be worse than a single copy
        errs = float((out != x).mean())
        single = float((noisy_fn(jax.random.split(key, 3)[0], x) != x).mean())
        assert errs <= single + 0.05


def test_costs_table():
    assert tmr.TMR_COSTS["serial"].latency_x == 3.0
    assert tmr.TMR_COSTS["serial"].area_x == 1.0
    assert tmr.TMR_COSTS["parallel"].latency_x == 1.0
    assert tmr.TMR_COSTS["parallel"].area_x == 3.0
    assert tmr.TMR_COSTS["semi_parallel"].throughput_x == pytest.approx(1 / 3)
