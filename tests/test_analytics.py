"""Case-study reliability math (paper §VI, Figs. 4–5)."""
import numpy as np
import pytest

from repro.core import analytics as A


def test_p_mult_monotone_and_bounded():
    pg = np.logspace(-12, -2, 30)
    pm = A.p_mult_from_alpha(pg, alpha=0.5, n_gates=14000)
    assert (np.diff(pm) >= 0).all()
    assert (pm >= 0).all() and (pm <= 1).all()


def test_tmr_beats_baseline_at_low_p():
    pg = np.array([1e-10, 1e-9, 1e-8, 1e-7])
    base = A.p_mult_from_alpha(pg, 0.5, 14000)
    tm = A.p_mult_tmr(pg, 0.5, 14000)
    assert (tm < base).all()


def test_nonideal_voting_floor():
    """Fig. 4: near p_gate=1e-9 non-ideal voting dominates TMR failures."""
    pg = np.array([1e-9])
    ideal = A.p_mult_tmr(pg, 0.5, 14000, ideal_voting=True)
    nonideal = A.p_mult_tmr(pg, 0.5, 14000, ideal_voting=False)
    assert nonideal > 10 * ideal


def test_nn_misclassification_matches_paper_scale():
    """Paper: baseline ~74% misclassification at p_gate = 1e-9."""
    cs = A.AlexNetCaseStudy()
    pm = A.p_mult_from_alpha(np.array([1e-9]), alpha=0.5, n_gates=14000)
    fail = A.nn_misclassification(pm, cs)
    assert 0.4 < fail[0] < 0.95


def test_tmr_nn_error_small_at_1e9():
    """Paper: ~2% with TMR at p_gate <= 1e-9."""
    pm = A.p_mult_tmr(np.array([1e-9]), 0.5, 14000)
    fail = A.nn_misclassification(pm)
    assert fail[0] < 0.10


def test_weight_degradation_fig5():
    """Paper: baseline loses ~all weights by 1e7 batches at high p_input;
    ECC holds ~O(1) corrupted weights at p_input=1e-9."""
    T = np.array([1e7])
    base_hi = A.weight_corruption_baseline(1e-7, T)
    assert A.expected_corrupted_weights(base_hi)[0] > 0.9 * 62e6
    ecc = A.weight_corruption_ecc_refined(1e-9, T, m=16)
    n = A.expected_corrupted_weights(ecc)[0]
    assert n < 50                        # single-digit-ish vs 17M baseline
    base = A.weight_corruption_baseline(1e-9, T)
    assert A.expected_corrupted_weights(base)[0] / max(n, 1e-9) > 1e5


def test_ecc_conservative_upper_bounds_refined():
    T = np.array([1e6, 1e7])
    cons = A.weight_corruption_ecc(1e-9, T)
    ref = A.weight_corruption_ecc_refined(1e-9, T)
    assert (cons >= ref).all()
