"""Golden equivalence for the unified `repro.reliability` scheme API
(DESIGN.md §12): every Scheme must be bit-exact against the pre-redesign
`ReliableStore` / `core.tmr` paths, and `Protected` must survive jit, vmap
and Checkpointer round-trips unchanged."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import reliability as R
from repro.core import tmr as legacy_tmr
from repro.faults import TransientBitFlips, inject_bit_flips
from repro.reliability import (Compose, DiagParityEcc, Protected, Tmr,
                               Unprotected, backend, parse_scheme,
                               standard_grid)
from repro.runtime import LoopConfig, TrainLoop


def _params(key):
    return {"a": jax.random.normal(key, (65, 7), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (129,),
                                   jnp.bfloat16),
            "c": jax.random.randint(jax.random.fold_in(key, 2), (40,),
                                    0, 100, jnp.int32)}


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not np.array_equal(np.asarray(x, np.float32),
                              np.asarray(y, np.float32)):
            return False
    return True


def _flip_word_bits(params, flips):
    """Flip specific (index, bit) positions of leaf 'a' (float32)."""
    u = jax.lax.bitcast_convert_type(params["a"], jnp.uint32).reshape(-1)
    for idx, bit in flips:
        u = u.at[idx].set(u[idx] ^ jnp.uint32(1 << bit))
    return dict(params, a=jax.lax.bitcast_convert_type(
        u.reshape(params["a"].shape), jnp.float32))


# -- backend registry ---------------------------------------------------------

def test_registry_resolution_order(monkeypatch):
    assert backend.resolve("netlist_exec") == "level"
    assert backend.resolve("diag_parity") == "kernel"
    # per-call argument wins over everything
    monkeypatch.setenv("REPRO_IMPL", "netlist_exec=kernel")
    assert backend.resolve("netlist_exec", "scan") == "scan"
    assert backend.resolve("netlist_exec") == "kernel"
    # bare env token applies to every op that has the implementation
    monkeypatch.setenv("REPRO_IMPL", "jnp")
    assert backend.resolve("diag_parity") == "jnp"
    assert backend.resolve("tmr_vote") == "jnp"
    assert backend.resolve("netlist_exec") == "level"   # no jnp impl: default
    # ...including in its bare-token form
    monkeypatch.setenv("REPRO_IMPL", "scan")
    assert backend.resolve("netlist_exec") == "scan"


def test_registry_rejects_unknown():
    with pytest.raises(KeyError):
        backend.resolve("no_such_op")
    with pytest.raises(ValueError):
        backend.resolve("diag_parity", "no_such_impl")


def test_multpim_impl_dispatch_via_registry(monkeypatch, key):
    from repro.core import multpim
    a = jax.random.bits(key, (16,), jnp.uint32) & jnp.uint32(0xFF)
    b = jax.random.bits(jax.random.fold_in(key, 1), (16,), jnp.uint32) \
        & jnp.uint32(0xFF)
    want = np.asarray(multpim.multiply_bits(a, b, 8, impl="scan"))
    monkeypatch.setenv("REPRO_IMPL", "netlist_exec=level")
    got = np.asarray(multpim.multiply_bits(a, b, 8))
    np.testing.assert_array_equal(got, want)


# -- DiagParityEcc vs ReliableStore (golden) ----------------------------------

@pytest.mark.parametrize("impl", ["kernel", "jnp"])
def test_ecc_protect_matches_reliable_store(key, impl):
    params = _params(key)
    store = R.ReliableStore.protect(params, backend=impl)
    prot = DiagParityEcc(impl=impl).protect(params)
    np.testing.assert_array_equal(np.asarray(prot.redundancy),
                                  np.asarray(store.parity))


@pytest.mark.parametrize("n_flips", [0, 1, 2])
def test_ecc_scrub_bit_exact_vs_reliable_store(key, n_flips):
    params = _params(key)
    scheme = DiagParityEcc()
    parity = scheme.protect(params).redundancy
    # 0 / 1 / 2 flips in the same 32-word block: clean, corrected, and
    # uncorrectable paths must all match the legacy store bit-for-bit
    bad = _flip_word_bits(params, [(3, 5), (9, 21)][:n_flips])
    f_old, r_old = R.ReliableStore(bad, parity).scrub()
    f_new, r_new = scheme.scrub(scheme.adopt(bad, parity))
    assert [int(v) for v in r_old] == [int(v) for v in r_new]
    assert _tree_equal(f_old.params, f_new.payload)
    expected = {0: (0, 0), 1: (1, 0), 2: (0, 1)}[n_flips]
    assert (int(r_new.corrected), int(r_new.uncorrectable)) == expected
    if n_flips < 2:
        assert _tree_equal(f_new.payload, params)


def test_ecc_sparse_corruption_backends_agree(key):
    params = _params(key)
    bad = inject_bit_flips(params, jax.random.fold_in(key, 9), 1e-4)
    outs = []
    for impl in ("kernel", "jnp"):
        scheme = DiagParityEcc(impl=impl)
        prot = scheme.protect(params)
        fixed, rep = scheme.scrub(scheme.adopt(bad, prot.redundancy))
        outs.append((fixed, rep))
    (f_k, r_k), (f_j, r_j) = outs
    assert [int(v) for v in r_k] == [int(v) for v in r_j]
    assert _tree_equal(f_k.payload, f_j.payload)


# -- Tmr vs core.tmr (golden) -------------------------------------------------

@pytest.mark.parametrize("discipline", ["serial", "parallel", "semi_parallel"])
def test_tmr_read_matches_legacy_vote(key, discipline):
    x = jax.random.normal(key, (32, 8), jnp.float32)
    bad = inject_bit_flips(x, jax.random.fold_in(key, 1), 0.05)
    scheme = Tmr(discipline)
    for copies in [(bad, x, x), (x, bad, x), (x, x, bad)]:
        prot = scheme.adopt(copies[0], (copies[1], copies[2]))
        want = legacy_tmr.vote_array(*copies)
        np.testing.assert_array_equal(np.asarray(scheme.read(prot)),
                                      np.asarray(want))
        np.testing.assert_array_equal(np.asarray(scheme.read(prot)),
                                      np.asarray(x))


def test_tmr_scrub_repairs_and_counts(key):
    params = _params(key)
    scheme = Tmr("serial")
    bad = _flip_word_bits(params, [(3, 5), (9, 21)])   # 2 words corrupted
    prot = scheme.adopt(bad, (params, params))
    fixed, rep = scheme.scrub(prot)
    assert _tree_equal(fixed.payload, params)
    assert _tree_equal(fixed.redundancy[0], params)
    assert int(rep.corrected) == 2                     # two repaired words
    assert int(rep.uncorrectable) == 0


def test_tmr_three_way_conflict_reports_uncorrectable(key):
    """A word corrupted differently in ALL three copies may out-vote
    wrong; that detectable conflict must surface as uncorrectable so the
    train loop's RESTART path can fire (like an ECC-dead block)."""
    params = _params(key)
    scheme = Tmr("serial")
    b0 = _flip_word_bits(params, [(3, 1)])
    b1 = _flip_word_bits(params, [(3, 2)])
    b2 = _flip_word_bits(params, [(3, 4)])
    fixed, rep = scheme.scrub(scheme.adopt(b0, (b1, b2)))
    assert int(rep.uncorrectable) == 1
    # single-copy corruption stays conflict-free
    _, rep2 = scheme.scrub(scheme.adopt(b0, (params, params)))
    assert int(rep2.uncorrectable) == 0


def test_tmr_serve_shim_all_disciplines(key):
    """The deprecated tmr_serve shim exposes all three paper disciplines
    end-to-end and votes identically to the legacy serial/parallel paths."""
    x = jax.random.normal(key, (16, 4), jnp.float32)
    bad = inject_bit_flips(x, jax.random.fold_in(key, 3), 0.05)

    def serve_fn(p):
        return p * 2.0

    want = np.asarray(serve_fn(x))
    for mode in ("serial", "parallel", "semi_parallel"):
        wrapped = R.tmr_serve(serve_fn, mode=mode)
        out = wrapped(bad, x, x)
        np.testing.assert_array_equal(np.asarray(out), want, err_msg=mode)
        assert wrapped.cost.throughput_x == \
            pytest.approx(legacy_tmr.TMR_COSTS[mode].throughput_x)


# -- Compose ------------------------------------------------------------------

def test_compose_recovers_ecc_uncorrectable_block(key):
    """Two flips in one block defeat the word code on one copy; the vote
    across per-copy-scrubbed replicas must still recover the payload."""
    params = _params(key)
    scheme = Compose(DiagParityEcc(), Tmr("serial"))
    prot = scheme.protect(params)
    (c1, c2), pars = prot.redundancy
    bad = _flip_word_bits(params, [(3, 5), (9, 21)])   # same ECC block
    corrupted = scheme.adopt(bad, ((c1, c2), pars))
    fixed, rep = scheme.scrub(corrupted)
    # the ECC-dead block is recovered by the vote, so it must NOT surface
    # as uncorrectable (no spurious checkpoint restore) — the 2 surviving
    # bad words count as vote repairs instead
    assert int(rep.uncorrectable) == 0
    assert int(rep.corrected) >= 2
    assert _tree_equal(fixed.payload, params)
    assert _tree_equal(scheme.read(fixed), params)


def test_compose_matches_manual_legacy_composition(key):
    """Compose.scrub == (per-copy ReliableStore scrub) + vote_array."""
    params = _params(key)
    scheme = Compose(DiagParityEcc(), Tmr("serial"))
    prot = scheme.protect(params)
    (_, _), (p0, p1, p2) = prot.redundancy
    model = TransientBitFlips(2e-4)
    copies = [model.corrupt(params, jax.random.fold_in(key, i))
              for i in range(3)]
    manual = []
    for c, par in zip(copies, (p0, p1, p2)):
        fixed, _ = R.ReliableStore(c, par).scrub()
        manual.append(fixed.params)
    want = jax.tree.map(legacy_tmr.vote_array, *manual)
    got, _ = scheme.scrub(scheme.adopt(copies[0],
                                       ((copies[1], copies[2]),
                                        (p0, p1, p2))))
    assert _tree_equal(got.payload, want)


# -- Protected as a pytree ----------------------------------------------------

def test_protected_through_jit(key):
    params = _params(key)
    scheme = DiagParityEcc()
    prot = scheme.protect(params)

    @jax.jit
    def roundtrip(p):
        return p

    out = roundtrip(prot)
    assert isinstance(out, Protected)
    assert out.scheme == scheme
    assert _tree_equal(out.payload, params)
    np.testing.assert_array_equal(np.asarray(out.redundancy),
                                  np.asarray(prot.redundancy))

    @jax.jit
    def scrub_in_jit(p):
        return scheme.scrub(p)

    fixed, rep = scrub_in_jit(scheme.adopt(
        _flip_word_bits(params, [(7, 11)]), prot.redundancy))
    assert isinstance(fixed, Protected)
    assert int(rep.corrected) == 1
    assert _tree_equal(fixed.payload, params)


def test_protected_through_vmap(key):
    x = jax.random.normal(key, (4, 64), jnp.float32)
    bad = inject_bit_flips(x, jax.random.fold_in(key, 1), 0.02)
    scheme = Tmr("parallel", impl="jnp")
    batched = Protected(bad, (x, x), scheme)   # leading batch axis on leaves
    out = jax.vmap(scheme.read)(batched)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))

    # ECC scrub vmapped over per-example stores (jnp impl: pure lax ops)
    ecc = DiagParityEcc(impl="jnp")
    w = jax.random.bits(key, (3, 64), jnp.uint32)

    def protect_scrub(row):
        prot = ecc.protect({"w": row})
        fixed, rep = ecc.scrub(prot)
        return fixed.payload["w"], rep.corrected

    rows, corrected = jax.vmap(protect_scrub)(w)
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(w))
    assert int(np.asarray(corrected).sum()) == 0


def test_protected_checkpoint_roundtrip(tmp_path, key):
    params = _params(key)
    for scheme in (DiagParityEcc(), Tmr("serial"),
                   Compose(DiagParityEcc(), Tmr("parallel"))):
        prot = scheme.protect(params)
        ck = Checkpointer(str(tmp_path / scheme.name), async_save=False)
        ck.save(0, {"prot": prot}, block=True)
        snap = ck.restore()
        restored = snap["prot"]
        assert isinstance(restored, Protected)
        assert restored.scheme == scheme
        assert _tree_equal(restored.payload, params)
        fixed, rep = scheme.scrub(jax.tree.map(jnp.asarray, restored))
        assert int(rep.corrected) == 0 and int(rep.uncorrectable) == 0
        assert _tree_equal(fixed.payload, params)


# -- parse_scheme / grid ------------------------------------------------------

def test_parse_scheme_grammar():
    assert isinstance(parse_scheme("off"), Unprotected)
    assert isinstance(parse_scheme("ecc"), DiagParityEcc)
    assert parse_scheme("tmr-semi").discipline == "semi_parallel"
    assert parse_scheme("tmr-semi-parallel").discipline == "semi_parallel"
    assert parse_scheme("tmr").discipline == "serial"
    comp = parse_scheme("ecc+tmr-parallel")
    assert isinstance(comp, Compose)
    assert comp.tmr.discipline == "parallel"
    comp2 = parse_scheme("tmr-serial+ecc")        # order-insensitive
    assert isinstance(comp2, Compose)
    assert parse_scheme("ecc", impl="jnp").impl == "jnp"
    for bad in ("nope", "ecc+ecc", "tmr-bogus"):
        with pytest.raises(ValueError):
            parse_scheme(bad)


def test_standard_grid_names_and_costs():
    names = [s.name for s in standard_grid()]
    assert names == ["unprotected", "ecc", "tmr-serial", "tmr-parallel",
                     "tmr-semi-parallel", "ecc+tmr-serial"]
    for s in standard_grid():
        c = s.overhead()
        assert c.storage_x >= 1.0 and c.throughput_x <= 1.0


# -- train-loop integration ---------------------------------------------------

def _toy_loop(tmp_path, scheme, total=12, **kw):
    def train_step(state, batch):
        p = state["params"]["w"] - 0.1 * batch.mean()
        return {"params": {"w": p}}, {"loss": jnp.abs(p).sum()}

    state = {"params": {"w": jnp.ones(64)}}
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    cfg = LoopConfig(total_steps=total, checkpoint_every=5, log_every=0,
                     scrub_every=4, scheme=scheme, **kw)
    return TrainLoop(train_step, state,
                     lambda s: jnp.full((4,), float(s % 3)),
                     cfg, ckpt=ck, log=lambda *_: None)


@pytest.mark.parametrize("spec", ["ecc", "tmr-serial", "ecc+tmr"])
def test_train_loop_scrubs_any_scheme(tmp_path, spec):
    """Every scheme family is reachable from the train loop through
    LoopConfig.scheme and corrects a deterministic single-bit flip."""
    def inject(params, step):
        u = jax.lax.bitcast_convert_type(params["w"], jnp.uint32)
        u = u.at[7].set(u[7] ^ jnp.uint32(1 << 11))
        return dict(params, w=jax.lax.bitcast_convert_type(u, jnp.float32))

    clean = _toy_loop(tmp_path / "clean", parse_scheme("off"))
    # clean reference run without any scheme attached
    clean.run()

    loop = _toy_loop(tmp_path / spec, parse_scheme(spec))
    loop.inject_fn = inject
    loop.attach_scheme()
    out = loop.run()
    assert out["final_step"] == 12
    assert len(loop.scrub_reports) == 3
    assert sum(int(r.corrected) for _, r in loop.scrub_reports) >= 3
    assert sum(int(r.uncorrectable) for _, r in loop.scrub_reports) == 0
    np.testing.assert_array_equal(np.asarray(loop.state["params"]["w"]),
                                  np.asarray(clean.state["params"]["w"]))


def test_train_loop_tmr_heavy_corruption_reaches_restart_path(tmp_path):
    """Built-in injection must corrupt ALL held copies (independent keys),
    so TMR double-faults and the RESTART path are reachable — a payload-only
    injector would report uncorrectable == 0 at any rate."""
    loop = _toy_loop(tmp_path, parse_scheme("tmr-serial"), total=12,
                     inject_p_bit=0.2)
    loop.attach_scheme()
    out = loop.run()                 # must terminate despite restores
    assert out["final_step"] == 12
    assert sum(int(r.uncorrectable) for _, r in loop.scrub_reports) > 0


def test_train_loop_fresh_process_rearms_copy_scheme(tmp_path):
    """A fresh process restoring a TMR-protected run must re-arm the scheme
    from the snapshot marker (there is no parity table to detect it by)."""
    loop = _toy_loop(tmp_path, parse_scheme("tmr-serial"), total=20)
    loop.attach_scheme()
    try:
        loop.run(fail_at=13)
    except RuntimeError:
        pass
    loop2 = _toy_loop(tmp_path, None, total=20)   # cfg carries no scheme
    assert loop2.restore()
    assert loop2.scheme is not None and loop2.scheme.name == "tmr-serial"
    assert loop2.protected is not None
    loop2.run()
    assert len(loop2.scrub_reports) > 0           # scrubbing continued


def test_loop_attach_scheme_surface(tmp_path):
    """The supported scheme-attachment surface (the PR-4/PR-7 raising
    shims are fully deleted): attach_scheme defaults to DiagParityEcc
    and the loop scrubs through it."""
    loop = _toy_loop(tmp_path, parse_scheme("ecc"))
    loop.attach_scheme()
    assert isinstance(loop.scheme, DiagParityEcc)
    loop.run()
    assert len(loop.scrub_reports) == 3
