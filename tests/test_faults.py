"""Fault subsystem: model determinism, campaign statistics, consumer wiring
(DESIGN.md §10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytics as A
from repro.core.crossbar import Crossbar, ErrorModel
from repro.core.reliability import encode_words
from repro.core.stateful_logic import g_nor
from repro.faults import (CampaignConfig, CompositeFault, RetentionDrift,
                          StuckAtFaults, TransientBitFlips,
                          TransientGateFaults, inject_bit_flips,
                          run_campaign, sweep, wilson_interval)
from repro.kernels.inject_scrub import inject_scrub
from repro.runtime import LoopConfig, TrainLoop


# --- FaultModel determinism ---------------------------------------------------

@pytest.mark.parametrize("model", [
    TransientBitFlips(0.05), TransientGateFaults(0.05),
    StuckAtFaults(0.03, 0.03), RetentionDrift(0.05),
    CompositeFault((TransientBitFlips(0.02), StuckAtFaults(0.02, 0.02))),
], ids=lambda m: type(m).__name__)
def test_same_key_same_mask(model, key):
    words = jax.random.bits(key, (128,), jnp.uint32)
    m1 = model.corrupt_words(words, jax.random.fold_in(key, 7))
    m2 = model.corrupt_words(words, jax.random.fold_in(key, 7))
    assert (np.asarray(m1) == np.asarray(m2)).all()


def test_disjoint_keys_independent_draws(key):
    """Masks from fold_in(key, i) are pairwise distinct and uncorrelated:
    the overlap of flipped-bit sets matches the p^2 product rate."""
    model = TransientBitFlips(0.25)
    zeros = jnp.zeros((512,), jnp.uint32)
    masks = [np.asarray(model.word_mask(jax.random.fold_in(key, i), zeros))
             for i in range(4)]
    n_bits = 512 * 32
    for i in range(4):
        for j in range(i + 1, 4):
            assert (masks[i] != masks[j]).any(), (i, j)
            both = np.bitwise_and(masks[i], masks[j])
            overlap = sum(bin(x).count("1") for x in both) / n_bits
            # E[overlap] = 0.0625; 4-sigma band for n_bits draws
            assert abs(overlap - 0.0625) < 4 * np.sqrt(0.0625 / n_bits) + 0.01


def test_models_vmap_over_keys(key):
    model = TransientBitFlips(0.1)
    keys = jax.random.split(key, 8)
    masks = jax.vmap(lambda k: model.word_mask(k, jnp.zeros(32, jnp.uint32)))(keys)
    assert masks.shape == (8, 32)
    single = model.word_mask(keys[3], jnp.zeros(32, jnp.uint32))
    assert (np.asarray(masks[3]) == np.asarray(single)).all()


def test_stuck_at_permanent_and_idempotent(key):
    sa = StuckAtFaults(0.05, 0.05)
    words = jax.random.bits(key, (256,), jnp.uint32)
    once = sa.corrupt_words(words, key)
    twice = sa.corrupt_words(once, key)
    assert (np.asarray(once) == np.asarray(twice)).all()
    # dt-invariant: a defect map is not an exposure process
    long_dt = sa.corrupt_words(words, key, dt=1e6)
    assert (np.asarray(once) == np.asarray(long_dt)).all()
    sa0, sa1 = sa.stuck_masks(key, (256, 32))
    assert not bool((sa0 & sa1).any())


def test_transient_dt_scaling(key):
    p, dt = 0.01, 16.0
    model = RetentionDrift(p)
    flips = model.bit_flips(key, (100_000,), dt=dt)
    want = -np.expm1(dt * np.log1p(-p))          # 1 - (1-p)^dt ~ 0.149
    got = float(jnp.mean(flips))
    assert abs(got - want) < 4 * np.sqrt(want * (1 - want) / 100_000)


def test_inject_bit_flips_rate_and_determinism(key):
    params = {"w": jax.random.normal(key, (4096,), jnp.float32)}
    bad = inject_bit_flips(params, key, 1e-3)
    bad2 = inject_bit_flips(params, key, 1e-3)
    # compare bit patterns: a flip can mint NaNs, and NaN != NaN
    u32 = lambda x: np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32))
    assert (u32(bad["w"]) == u32(bad2["w"])).all()
    xor = u32(bad["w"]) ^ u32(params["w"])
    rate = sum(bin(x).count("1") for x in xor) / (4096 * 32)
    assert 3e-4 < rate < 3e-3


def test_deprecated_reexport_is_same_object():
    from repro.core import reliability
    from repro.faults import models
    assert reliability.inject_bit_flips is models.inject_bit_flips


# --- campaign statistics ------------------------------------------------------

def test_wilson_interval_basics():
    lo, hi = wilson_interval(0, 0)
    assert (lo, hi) == (0.0, 1.0)
    lo, hi = wilson_interval(0, 100)
    assert lo == 0.0 and 0.0 < hi < 0.06      # rare-event: no zero-width lie
    lo, hi = wilson_interval(50, 100)
    assert lo < 0.5 < hi and hi - lo < 0.25
    wide = wilson_interval(50, 100, z=3.0)
    assert wide[0] < lo and wide[1] > hi


def test_campaign_recovers_known_probability(key):
    res = run_campaign(lambda k: jax.random.bernoulli(k, 0.3), key,
                       CampaignConfig(batch_size=512, max_trials=2048))
    assert res.n_trials == 2048
    lo, hi = res.ci
    assert lo < 0.3 < hi


def test_campaign_early_stop_and_extras(key):
    def trial(k):
        fail = jax.random.bernoulli(k, 0.5)
        return fail, {"weight": jnp.float32(2.0)}

    cfg = CampaignConfig(batch_size=128, max_trials=1 << 20,
                         min_trials=128, ci_halfwidth=0.1)
    res = run_campaign(trial, key, cfg)
    assert res.n_trials < 1 << 20              # stopped on CI width
    assert res.ci_halfwidth <= 0.1
    assert res.extras["weight"] == pytest.approx(2.0 * res.n_trials)


def test_campaign_batched_mode_matches_vmap(key):
    p = 0.2

    def batch_fn(k, n):
        return jax.random.bernoulli(k, p, (n,))

    res = run_campaign(batch_fn, key, CampaignConfig(batch_size=256,
                                                     max_trials=1024),
                       batched=True)
    assert res.n_trials == 1024
    lo, hi = res.ci
    assert lo < p < hi


def test_sweep_grid(key):
    rows = sweep(lambda p: (lambda k: jax.random.bernoulli(k, p)),
                 [{"p": 0.1}, {"p": 0.6}], jax.random.fold_in(key, 17),
                 CampaignConfig(batch_size=512, max_trials=2048, z=2.576))
    assert len(rows) == 2
    for pt, res in rows:
        assert res.contains(pt["p"]), res.describe()
    assert rows[0][1].p_hat < rows[1][1].p_hat


# --- empirical ECC statistics vs the closed forms ----------------------------

def test_single_flip_correction_rate_matches_analytics(key):
    """One scrub interval at small p: the block-failure rate matches
    weight_corruption_ecc(p, T=1, m=32) and the corrected-block rate
    matches the exactly-one-flip term, both within the Wilson interval."""
    p = 2e-4
    model = TransientBitFlips(p)

    def batch(k, n):
        kb, ki = jax.random.split(k)
        buf = jax.random.bits(kb, (n * 32,), jnp.uint32)
        par = encode_words(buf)
        mask = model.word_mask(ki, buf)
        fixed, _, counts = inject_scrub(buf, par, mask)
        fail = (fixed.reshape(n, 32) != buf.reshape(n, 32)).any(axis=-1)
        return fail, {"corrected": counts[1]}

    res = run_campaign(batch, key,
                       CampaignConfig(batch_size=2048, max_trials=8192,
                                      z=2.576), batched=True)
    p_model = float(A.weight_corruption_ecc(p, np.array([1]), m=32)[0])
    assert res.contains(p_model), (res.describe(), p_model)
    # exactly-one-flip rate: n_bits * p * (1-p)^(n_bits-1)
    n_bits = 32 * 32
    p1 = n_bits * p * (1 - p) ** (n_bits - 1)
    lo, hi = wilson_interval(int(res.extras["corrected"]), res.n_trials,
                             z=2.576)
    assert lo <= p1 <= hi, (lo, p1, hi)


# --- consumer wiring ----------------------------------------------------------

def test_error_model_float_and_model_paths_identical(key):
    rng = np.random.default_rng(3)
    state = rng.integers(0, 2, (64, 8))
    a = Crossbar.from_array(state, errors=ErrorModel(p_input=0.1))
    b = Crossbar.from_array(state,
                            errors=ErrorModel(input=TransientBitFlips(0.1)))
    oa = a.row_gate("nor", [0, 1], 5, key=key)
    ob = b.row_gate("nor", [0, 1], 5, key=key)
    assert (np.asarray(oa.state) == np.asarray(ob.state)).all()


def test_crossbar_stuck_at_inputs(key):
    """A stuck-at input model pins cells: corrupting twice with the same
    key changes nothing further (unlike transient flips)."""
    rng = np.random.default_rng(4)
    xb = Crossbar.from_array(rng.integers(0, 2, (128, 4)),
                             errors=ErrorModel(input=StuckAtFaults(0.2, 0.2)))
    once = xb.row_gate("nor", [0, 1], 3, key=key)
    again = once.row_gate("nor", [0, 1], 3, key=key)
    assert (np.asarray(again.state[:, :2]) == np.asarray(once.state[:, :2])).all()


def test_maybe_flip_accepts_fault_model(key):
    a = jax.random.bernoulli(key, 0.5, (4096,))
    b = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (4096,))
    # NOTE: the corruption key must be independent of the keys that drew the
    # inputs — bernoulli(key) and the stuck mask share uniforms otherwise
    out = g_nor(a, b, key=jax.random.fold_in(key, 2),
                p_gate=StuckAtFaults(0.5, 0.0))
    want = ~(a | b)
    # half the output cells are stuck at 0
    stuck_frac = float((out < want).mean())     # 1 -> 0 transitions
    assert 0.4 < stuck_frac / max(float(want.mean()), 1e-9) < 0.6


def test_train_loop_fault_model_hook(key):
    params = {"w": jax.random.normal(key, (256,), jnp.float32)}
    cfg = LoopConfig(inject_seed=5, fault_model=TransientBitFlips(1e-2))
    loop = TrainLoop(None, {"params": params}, None, cfg, log=lambda *_: None)
    c1 = loop._corrupt(params)
    c2 = loop._corrupt(params)
    assert (np.asarray(c1["w"]) == np.asarray(c2["w"])).all()  # keyed by step
    assert (np.asarray(c1["w"]) != np.asarray(params["w"])).any()
    loop.total_restores = 1    # post-restore replays must draw fresh flips
    c3 = loop._corrupt(params)
    assert (np.asarray(c3["w"]) != np.asarray(c1["w"])).any()


def test_train_loop_permanent_faults_use_stable_key(key):
    """A stuck-at model keeps the SAME defect map across steps and restores
    (a defect is a device property, not an exposure process)."""
    params = {"w": jax.random.normal(key, (256,), jnp.float32)}
    cfg = LoopConfig(inject_seed=5, fault_model=StuckAtFaults(0.01, 0.01))
    loop = TrainLoop(None, {"params": params}, None, cfg, log=lambda *_: None)
    c1 = loop._corrupt(params)
    loop.step = 7
    loop.total_restores = 2
    c2 = loop._corrupt(params)
    assert (np.asarray(c1["w"]) == np.asarray(c2["w"])).all()
    # corrupting the already-corrupted params is a no-op (idempotent defects)
    c3 = loop._corrupt(c1)
    assert (np.asarray(c3["w"]) == np.asarray(c1["w"])).all()
