"""Word-level ECC + ReliableStore (the paper's §IV on TPU buffers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import reliability as R


def _words(seed, n_blocks=8):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (n_blocks * 32,), 0, 1 << 30, jnp.int32).astype(jnp.uint32)


@given(seed=st.integers(0, 50), block=st.integers(0, 7),
       word=st.integers(0, 31), bit=st.integers(0, 31))
@settings(max_examples=50, deadline=None)
def test_single_bit_flip_corrected(seed, block, word, bit):
    w = _words(seed)
    par = R.encode_words(w)
    bad = w.at[block * 32 + word].set(w[block * 32 + word] ^ jnp.uint32(1 << bit))
    fixed, par2, rep = R.correct_words(bad, par)
    assert (fixed == w).all()
    assert int(rep.corrected) == 1
    assert int(rep.uncorrectable) == 0


def test_parity_word_flip_detected_and_fixed():
    w = _words(3)
    par = R.encode_words(w)
    bad_par = par.at[2, 1].set(par[2, 1] ^ jnp.uint32(1 << 9))
    fixed, par2, rep = R.correct_words(w, bad_par)
    assert (fixed == w).all()
    assert int(rep.parity_fixed) == 1
    assert (par2 == par).all()


def test_double_flip_same_block_uncorrectable():
    w = _words(4)
    par = R.encode_words(w)
    bad = w.at[0].set(w[0] ^ jnp.uint32(1)).at[5].set(w[5] ^ jnp.uint32(1 << 17))
    _, _, rep = R.correct_words(bad, par)
    assert int(rep.uncorrectable) == 1


def test_store_roundtrip_all_dtypes(key):
    params = {"a": jax.random.normal(key, (65, 7), jnp.float32),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (129,), jnp.bfloat16),
              "c": jax.random.randint(jax.random.fold_in(key, 2), (40,), 0, 100, jnp.int32)}
    store = R.ReliableStore.protect(params)
    fixed, rep = store.scrub()
    assert int(rep.corrected) == 0 and int(rep.uncorrectable) == 0
    for k in params:
        assert np.array_equal(np.asarray(fixed.params[k]), np.asarray(params[k]))


@pytest.mark.parametrize("p_bit", [1e-5, 5e-5])
def test_store_scrub_corrects_sparse_corruption(key, p_bit):
    params = {"w": jax.random.normal(key, (256, 33), jnp.float32)}
    store = R.ReliableStore.protect(params)
    bad = R.inject_bit_flips(params, jax.random.fold_in(key, 9), p_bit)
    fixed, rep = R.ReliableStore(bad, store.parity).scrub()
    if int(rep.uncorrectable) == 0:
        assert np.array_equal(np.asarray(fixed.params["w"]), np.asarray(params["w"]))
    assert int(rep.corrected) >= 0


def test_storage_overhead():
    cfg = R.WordEccConfig()
    assert cfg.n_parity_words / R.BLOCK == pytest.approx(3 / 32)  # ~9.4%
