"""Word-level ECC + ReliableStore (the paper's §IV on TPU buffers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import reliability as R
from repro.faults import inject_bit_flips


def _words(seed, n_blocks=8):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (n_blocks * 32,), 0, 1 << 30, jnp.int32).astype(jnp.uint32)


@given(seed=st.integers(0, 50), block=st.integers(0, 7),
       word=st.integers(0, 31), bit=st.integers(0, 31))
@settings(max_examples=50, deadline=None)
def test_single_bit_flip_corrected(seed, block, word, bit):
    w = _words(seed)
    par = R.encode_words(w)
    bad = w.at[block * 32 + word].set(w[block * 32 + word] ^ jnp.uint32(1 << bit))
    fixed, par2, rep = R.correct_words(bad, par)
    assert (fixed == w).all()
    assert int(rep.corrected) == 1
    assert int(rep.uncorrectable) == 0


def test_parity_word_flip_detected_and_fixed():
    w = _words(3)
    par = R.encode_words(w)
    bad_par = par.at[2, 1].set(par[2, 1] ^ jnp.uint32(1 << 9))
    fixed, par2, rep = R.correct_words(w, bad_par)
    assert (fixed == w).all()
    assert int(rep.parity_fixed) == 1
    assert (par2 == par).all()


def test_double_flip_same_block_uncorrectable():
    w = _words(4)
    par = R.encode_words(w)
    bad = w.at[0].set(w[0] ^ jnp.uint32(1)).at[5].set(w[5] ^ jnp.uint32(1 << 17))
    _, _, rep = R.correct_words(bad, par)
    assert int(rep.uncorrectable) == 1


def test_store_roundtrip_all_dtypes(key):
    params = {"a": jax.random.normal(key, (65, 7), jnp.float32),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (129,), jnp.bfloat16),
              "c": jax.random.randint(jax.random.fold_in(key, 2), (40,), 0, 100, jnp.int32)}
    store = R.ReliableStore.protect(params)
    fixed, rep = store.scrub()
    assert int(rep.corrected) == 0 and int(rep.uncorrectable) == 0
    for k in params:
        assert np.array_equal(np.asarray(fixed.params[k]), np.asarray(params[k]))


@pytest.mark.parametrize("p_bit", [1e-5, 5e-5])
def test_store_scrub_corrects_sparse_corruption(key, p_bit):
    params = {"w": jax.random.normal(key, (256, 33), jnp.float32)}
    store = R.ReliableStore.protect(params)
    bad = inject_bit_flips(params, jax.random.fold_in(key, 9), p_bit)
    fixed, rep = R.ReliableStore(bad, store.parity).scrub()
    if int(rep.uncorrectable) == 0:
        assert np.array_equal(np.asarray(fixed.params["w"]), np.asarray(params["w"]))
    assert int(rep.corrected) >= 0


def test_storage_overhead():
    cfg = R.WordEccConfig()
    assert cfg.n_parity_words / R.BLOCK == pytest.approx(3 / 32)  # ~9.4%


def test_odd_length_bf16_leaf_protect_flip_scrub(key):
    """Regression: odd-element bfloat16 leaves share their last arena word
    with a zero pad half-word; protect -> flip -> scrub must round-trip."""
    for n in (1, 33, 129):
        x = jax.random.normal(jax.random.fold_in(key, n), (n,), jnp.bfloat16)
        params = {"w": x}
        store = R.ReliableStore.protect(params)
        # flip one mantissa bit of the LAST element (lives in the half-word
        # next to the padding)
        u16 = jax.lax.bitcast_convert_type(x, jnp.uint16)
        bad_x = jax.lax.bitcast_convert_type(
            u16.at[n - 1].set(u16[n - 1] ^ jnp.uint16(1 << 3)), jnp.bfloat16)
        fixed, rep = R.ReliableStore({"w": bad_x}, store.parity).scrub()
        assert int(rep.corrected) == 1, n
        assert int(rep.uncorrectable) == 0, n
        assert np.array_equal(np.asarray(fixed.params["w"], np.float32),
                              np.asarray(x, np.float32)), n


def test_store_backends_agree(key):
    params = {"a": jax.random.normal(key, (67, 5), jnp.float32),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (77,), jnp.bfloat16)}
    bad = inject_bit_flips(params, jax.random.fold_in(key, 2), 1e-4)
    parity = R.ReliableStore.protect(params).parity
    f_k, r_k = R.ReliableStore(bad, parity, backend="kernel").scrub()
    f_j, r_j = R.ReliableStore(bad, parity, backend="jnp").scrub()
    assert [int(v) for v in r_k] == [int(v) for v in r_j]
    for k in params:
        assert np.array_equal(np.asarray(f_k.params[k], np.float32),
                              np.asarray(f_j.params[k], np.float32))


def test_per_leaf_legacy_path_matches_arena(key):
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (31 + i,), jnp.float32)
              for i in range(6)}
    bad = inject_bit_flips(params, jax.random.fold_in(key, 99), 1e-4)
    ptree = R.protect_leaves(params)
    fixed_tree, _, rep_leaf = R.scrub_leaves(bad, ptree)
    store = R.ReliableStore.protect(params)
    fixed_arena, rep_arena = R.ReliableStore(bad, store.parity).scrub()
    assert int(rep_leaf.corrected) == int(rep_arena.corrected)
    assert int(rep_leaf.uncorrectable) == int(rep_arena.uncorrectable)
    for k in params:
        assert np.array_equal(np.asarray(fixed_tree[k]),
                              np.asarray(fixed_arena.params[k]))
