"""Fault-tolerance runtime: straggler detection, preemption restart, ECC
scrub loop integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime import HeartbeatMonitor, LoopConfig, StragglerPolicy, TrainLoop
from repro.runtime.monitor import Decision


def test_straggler_flags_and_checkpoint_decision():
    mon = HeartbeatMonitor(StragglerPolicy(window=8, slow_factor=2.0,
                                           max_consecutive_slow=3))
    for _ in range(8):
        assert mon.record_step(0.1) == Decision.CONTINUE
    assert mon.record_step(0.5) == Decision.CONTINUE
    assert mon.record_step(0.5) == Decision.CONTINUE
    assert mon.record_step(0.5) == Decision.CHECKPOINT_NOW
    assert mon.summary()["n_flags"] == 3


def _toy_loop(tmp_path, total=20, **kw):
    def train_step(state, batch):
        p = state["params"]["w"] - 0.1 * batch.mean()
        return {"params": {"w": p}}, {"loss": jnp.abs(p).sum()}

    state = {"params": {"w": jnp.ones(64)}}
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    cfg = LoopConfig(total_steps=total, checkpoint_every=5, log_every=0, **kw)
    return TrainLoop(train_step, state, lambda s: jnp.full((4,), float(s % 3)),
                     cfg, ckpt=ck, log=lambda *_: None)


def test_preemption_restart_resumes_from_checkpoint(tmp_path):
    loop = _toy_loop(tmp_path)
    with pytest.raises(RuntimeError):
        loop.run(fail_at=13)
    # simulate a fresh process: new loop object, restore, continue
    loop2 = _toy_loop(tmp_path)
    assert loop2.restore()
    assert loop2.step == 10               # last checkpoint before the failure
    out = loop2.run()
    assert out["final_step"] == 20


def test_ecc_scrub_in_loop_corrects_injected_flips(tmp_path):
    loop = _toy_loop(tmp_path, scrub_every=4, inject_p_bit=1e-4)
    loop.attach_ecc()
    loop.run()
    assert len(loop.scrub_reports) == 5
    total_fixed = sum(int(r.corrected) + int(r.parity_fixed)
                      for _, r in loop.scrub_reports)
    assert total_fixed >= 0               # injection is sparse; no crashes
    assert np.isfinite(np.asarray(loop.state["params"]["w"])).all()


def test_loop_without_ecc_never_scrubs(tmp_path):
    loop = _toy_loop(tmp_path, scrub_every=4)
    loop.run()
    assert loop.scrub_reports == []
