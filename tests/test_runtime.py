"""Fault-tolerance runtime: straggler detection, preemption restart, ECC
scrub loop integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime import HeartbeatMonitor, LoopConfig, StragglerPolicy, TrainLoop
from repro.runtime.monitor import Decision


def test_straggler_flags_and_checkpoint_decision():
    mon = HeartbeatMonitor(StragglerPolicy(window=8, slow_factor=2.0,
                                           max_consecutive_slow=3))
    for _ in range(8):
        assert mon.record_step(0.1) == Decision.CONTINUE
    assert mon.record_step(0.5) == Decision.CONTINUE
    assert mon.record_step(0.5) == Decision.CONTINUE
    assert mon.record_step(0.5) == Decision.CHECKPOINT_NOW
    assert mon.summary()["n_flags"] == 3


def _toy_loop(tmp_path, total=20, **kw):
    def train_step(state, batch):
        p = state["params"]["w"] - 0.1 * batch.mean()
        return {"params": {"w": p}}, {"loss": jnp.abs(p).sum()}

    state = {"params": {"w": jnp.ones(64)}}
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    cfg = LoopConfig(total_steps=total, checkpoint_every=5, log_every=0, **kw)
    return TrainLoop(train_step, state, lambda s: jnp.full((4,), float(s % 3)),
                     cfg, ckpt=ck, log=lambda *_: None)


def test_preemption_restart_resumes_from_checkpoint(tmp_path):
    loop = _toy_loop(tmp_path)
    with pytest.raises(RuntimeError):
        loop.run(fail_at=13)
    # simulate a fresh process: new loop object, restore, continue
    loop2 = _toy_loop(tmp_path)
    assert loop2.restore()
    assert loop2.step == 10               # last checkpoint before the failure
    out = loop2.run()
    assert out["final_step"] == 20


def test_ecc_scrub_in_loop_corrects_injected_flips(tmp_path):
    loop = _toy_loop(tmp_path, scrub_every=4, inject_p_bit=1e-4)
    loop.attach_scheme()
    loop.run()
    assert len(loop.scrub_reports) == 5
    total_fixed = sum(int(r.corrected) + int(r.parity_fixed)
                      for _, r in loop.scrub_reports)
    assert total_fixed >= 0               # injection is sparse; no crashes
    assert np.isfinite(np.asarray(loop.state["params"]["w"])).all()


def test_loop_without_ecc_never_scrubs(tmp_path):
    loop = _toy_loop(tmp_path, scrub_every=4)
    loop.run()
    assert loop.scrub_reports == []


def test_heavy_corruption_terminates_via_restore_limit(tmp_path):
    """Regression: with the built-in random injector, an uncorrectable draw
    used to replay bit-identically after every restore (same step => same
    PRNG key), livelocking run().  Fresh draws per restore plus the
    consecutive-restore cap must guarantee termination."""
    loop = _toy_loop(tmp_path, total=12, scrub_every=2, inject_p_bit=0.2)
    loop.attach_scheme()
    out = loop.run()                 # must terminate
    assert out["final_step"] == 12
    assert loop._consecutive_scrub_restores <= loop.cfg.max_scrub_restores
    assert sum(int(r.uncorrectable) for _, r in loop.scrub_reports) > 0


def test_restore_with_legacy_parity_layout_reencodes(tmp_path):
    """Pre-arena checkpoints stored parity as a per-leaf pytree; restore
    must fall back to re-encoding instead of crashing."""
    loop = _toy_loop(tmp_path, scrub_every=4)
    loop.attach_scheme()
    loop.run()
    # rewrite the newest snapshot with a legacy-style per-leaf parity dict
    snap = loop.ckpt.restore()
    snap["parity"] = {"w": np.asarray(snap["parity"])}
    loop.ckpt.save(loop.ckpt.latest_step(), snap, block=True)
    loop2 = _toy_loop(tmp_path, scrub_every=4)
    assert loop2.restore()
    assert loop2.store is not None and loop2.store.parity.ndim == 2
    _, rep = loop2.store.scrub()
    assert int(rep.uncorrectable) == 0


def test_fresh_process_restore_rearms_ecc(tmp_path):
    """Regression: a restore in a fresh process (store is None) must re-arm
    the scrub engine from the snapshot's parity, not silently drop ECC."""
    loop = _toy_loop(tmp_path, scrub_every=4)
    loop.attach_scheme()
    with pytest.raises(RuntimeError):
        loop.run(fail_at=13)
    loop2 = _toy_loop(tmp_path, scrub_every=4)   # fresh process: no attach_scheme
    assert loop2.restore()
    assert loop2.store is not None
    _, rep = loop2.store.scrub()                 # parity matches the params
    assert int(rep.uncorrectable) == 0 and int(rep.corrected) == 0
    loop2.run()
    assert len(loop2.scrub_reports) > 0          # scrubbing continued


def _flip_bits(params, positions):
    w = params["w"]
    u = jax.lax.bitcast_convert_type(w, jnp.uint32)
    for idx, bit in positions:
        u = u.at[idx].set(u[idx] ^ jnp.uint32(1 << bit))
    return dict(params, w=jax.lax.bitcast_convert_type(u, jnp.float32))


def test_kernel_scrub_corrects_single_flips_in_loop(tmp_path):
    """scrub_every > 0 + the fused kernel path corrects a deterministic
    single-bit flip per interval, leaving training bit-exact."""
    flips = []

    def inject(params, step):
        flips.append(step)
        return _flip_bits(params, [(7, 11)])   # one bit, one block

    clean = _toy_loop(tmp_path / "clean", total=12, scrub_every=4)
    clean.run()

    loop = _toy_loop(tmp_path / "ecc", total=12, scrub_every=4)
    loop.inject_fn = inject
    loop.attach_scheme()
    assert loop.store.backend == "kernel"
    out = loop.run()
    assert flips == [4, 8, 12]
    assert sum(int(r.corrected) for _, r in loop.scrub_reports) == 3
    assert sum(int(r.uncorrectable) for _, r in loop.scrub_reports) == 0
    # every injected flip was corrected: trajectory identical to no-fault run
    np.testing.assert_array_equal(np.asarray(loop.state["params"]["w"]),
                                  np.asarray(clean.state["params"]["w"]))
    assert out["monitor"]["bits_corrected"] == 3
    assert out["scrub"]["corrected"] == 3


def test_uncorrectable_block_triggers_checkpoint_restore(tmp_path):
    """Two flips in one 32-word block defeat the single-error code; the
    monitor decision must restore from the latest checkpoint."""
    logs = []
    fired = []

    def inject(params, step):
        if step == 12 and not fired:          # after the step-10 checkpoint;
            fired.append(step)                # once, or the replay re-corrupts
            return _flip_bits(params, [(3, 5), (9, 21)])  # same block
        return params

    loop = _toy_loop(tmp_path, total=20, scrub_every=4)
    loop.inject_fn = inject
    loop.log = logs.append
    loop.attach_scheme()
    out = loop.run()
    assert out["final_step"] == 20
    assert any("uncorrectable" in l for l in logs)
    assert any("[restore] resumed from step 10" in l for l in logs)
    assert sum(int(r.uncorrectable) for _, r in loop.scrub_reports) == 1
    assert out["monitor"]["uncorrectable"] == 1
    assert np.isfinite(np.asarray(loop.state["params"]["w"])).all()
