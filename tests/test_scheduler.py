"""Levelized netlist scheduling + bit-packed execution engines (DESIGN.md §11).

Covers the levelizer invariants (inputs strictly earlier, capacity cap,
exactly-once scheduling, contiguous row remap), bit-exactness of the
levelized jnp path and the netlist_exec Pallas kernel against the lax.scan
reference under 0/1/many-fault injection (float rates, FaultModels and
single-fault planes), and the trial-packing round trip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import multpim, netlist, scheduler
from repro.core.bitops import pack_trials, unpack_trials
from repro.faults import (CompositeFault, RetentionDrift, StuckAtFaults,
                          TransientGateFaults)
from repro.kernels.netlist_exec import execute_packed


# --- trial packing -----------------------------------------------------------

@pytest.mark.parametrize("trials,cols", [(1, 1), (31, 3), (32, 2), (70, 5)])
def test_pack_trials_roundtrip(trials, cols):
    rng = np.random.default_rng(trials)
    bits = jnp.array(rng.integers(0, 2, (trials, cols)).astype(bool))
    words = pack_trials(bits)
    assert words.shape == ((trials + 31) // 32, cols)
    assert (np.asarray(unpack_trials(words, trials)) == np.asarray(bits)).all()


# --- levelizer invariants ----------------------------------------------------

def _check_schedule_invariants(nl, sch):
    # every gate scheduled exactly once
    gids = sch.sched_gid[sch.sched_gid >= 0]
    assert sorted(gids.tolist()) == list(range(nl.n_gates))
    assert (sch.widths <= sch.max_width).all()
    assert sch.n_levels >= sch.depth
    # every gate's inputs are produced at strictly earlier levels
    level_of_wire = np.zeros(nl.n_wires, np.int64)        # consts/inputs: 0
    for l in range(sch.n_levels):
        for s in range(int(sch.widths[l])):
            i1, i2, i3, out = sch.sched[l, s]
            assert max(level_of_wire[i1], level_of_wire[i2],
                       level_of_wire[i3]) < l + 1
            level_of_wire[out] = l + 1
    # remap: bijective into the packed row space, slot ownership honored
    assert sch.remap[0] == 0 and (sch.remap[nl.inputs] ==
                                  2 + np.arange(len(nl.inputs))).all()
    rows = sch.remap[nl.gates[:, 3]]
    assert len(set(rows.tolist())) == nl.n_gates
    slot = rows - sch.base
    lvl, s = slot // sch.max_width, slot % sch.max_width
    assert (sch.sched_gid[lvl, s] == np.arange(nl.n_gates)).all()


@pytest.mark.parametrize("nb", [2, 4, 8, 16])
def test_multiplier_schedule_invariants(nb):
    nl = multpim.multiplier_netlist(nb)
    _check_schedule_invariants(nl, scheduler.schedule(nl))


@pytest.mark.parametrize("max_width", [1, 7, 32])
def test_width_cap_respected(max_width):
    nl = multpim.multiplier_netlist(4)
    sch = scheduler.levelize(nl, max_width=max_width)
    assert sch.max_width == max_width
    _check_schedule_invariants(nl, sch)


def test_empty_netlist():
    bld = netlist.NetlistBuilder()
    (x,) = bld.input_bits(1)
    bld.mark_outputs([x, bld.ZERO, bld.ONE])
    nl = bld.build()
    sch = scheduler.schedule(nl)
    assert sch.n_levels == 0 and sch.n_gates == 0
    inputs = jnp.array([[True], [False], [True]])
    got = scheduler.execute_levelized(nl, inputs)
    want = netlist.execute(nl, inputs)
    assert (np.asarray(got) == np.asarray(want)).all()


def _random_netlist(seed: int) -> netlist.Netlist:
    rng = np.random.default_rng(seed)
    bld = netlist.NetlistBuilder(cse=bool(rng.integers(2)))
    wires = list(bld.input_bits(int(rng.integers(2, 6)))) + [bld.ZERO, bld.ONE]
    ops = [bld.not_, bld.nor, bld.nand, bld.and_, bld.or_, bld.xor,
           bld.min3, bld.maj3]
    for _ in range(int(rng.integers(5, 60))):
        op = ops[rng.integers(len(ops))]
        n_args = {bld.not_: 1, bld.min3: 3, bld.maj3: 3}.get(op, 2)
        args = [wires[rng.integers(len(wires))] for _ in range(n_args)]
        wires.append(op(*args))
    out = [wires[rng.integers(len(wires))]
           for _ in range(int(rng.integers(1, 8)))]
    bld.mark_outputs(out)
    return bld.build()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_random_netlist_schedule_replays_scan(seed):
    """Property: on random netlists the schedule satisfies the level
    invariants and the levelized engine replays the scan reference exactly
    (clean and under iid + single-fault injection)."""
    nl = _random_netlist(seed)
    sch = scheduler.levelize(nl)
    _check_schedule_invariants(nl, sch)

    rng = np.random.default_rng(seed + 1)
    trials = int(rng.integers(1, 80))
    inputs = jnp.array(rng.integers(0, 2, (trials, len(nl.inputs))).astype(bool))
    key = jax.random.PRNGKey(seed % 997)
    fg = jnp.array(rng.integers(-1, max(nl.n_gates, 1), trials).astype(np.int32))
    for kw in (dict(),
               dict(key=key, p_gate=0.1),
               dict(fault_gate=fg),
               dict(key=key, p_gate=0.1, fault_gate=fg)):
        want = netlist.execute(nl, inputs, **kw)
        got = scheduler.execute_levelized(nl, inputs, **kw)
        assert (np.asarray(got) == np.asarray(want)).all(), kw


# --- packed engines vs the scan reference, all fault surfaces ----------------

FAULT_CASES = [
    ("clean", dict()),
    ("iid", dict(key=True, p_gate=0.03)),
    ("single", dict(fault_gate=True)),
    ("iid+single", dict(key=True, p_gate=0.03, fault_gate=True)),
    ("gate_model", dict(key=True, p_gate=TransientGateFaults(0.03))),
    ("stuckat", dict(key=True, p_gate=StuckAtFaults(0.04, 0.02))),
    ("composite", dict(key=True, p_gate=CompositeFault(
        (TransientGateFaults(0.02), StuckAtFaults(0.02, 0.01),
         RetentionDrift(0.01))))),
]


@pytest.mark.parametrize("name,spec", FAULT_CASES, ids=[c[0] for c in FAULT_CASES])
@pytest.mark.parametrize("nb,trials", [(4, 33), (8, 300)])
def test_engines_bit_exact_vs_scan(name, spec, nb, trials):
    """level and kernel engines are bit-exact vs the scan reference,
    fault streams included — iid, FaultModel taxonomies and single-fault
    planes, at trial counts that exercise lane padding and multi-tile
    grids."""
    nl = multpim.multiplier_netlist(nb)
    rng = np.random.default_rng(nb * 1000 + trials)
    a = jnp.array(rng.integers(0, 2**nb, trials).astype(np.uint32))
    b = jnp.array(rng.integers(0, 2**nb, trials).astype(np.uint32))
    kw = dict(spec)
    if kw.pop("key", False):
        kw["key"] = jax.random.PRNGKey(3)
    if kw.get("fault_gate") is True:
        kw["fault_gate"] = jnp.array(
            rng.integers(-1, nl.n_gates, trials).astype(np.int32))
    want = np.asarray(multpim.multiply_bits(a, b, nb, impl="scan", **kw))
    level = np.asarray(multpim.multiply_bits(a, b, nb, impl="level", **kw))
    kern = np.asarray(multpim.multiply_bits(a, b, nb, impl="kernel", **kw))
    assert (level == want).all(), "level != scan"
    assert (kern == want).all(), "kernel != scan"


def test_single_fault_every_gate_position_matches_scan():
    """The exhaustive fault_gate=arange(G) sweep (the alpha measurement)
    is identical across engines."""
    nl = multpim.multiplier_netlist(4)
    rng = np.random.default_rng(0)
    a = jnp.array(rng.integers(0, 16, nl.n_gates).astype(np.uint32))
    b = jnp.array(rng.integers(0, 16, nl.n_gates).astype(np.uint32))
    fg = jnp.arange(nl.n_gates, dtype=jnp.int32)
    want = np.asarray(multpim.multiply_bits(a, b, 4, fault_gate=fg, impl="scan"))
    for impl in ("level", "kernel"):
        got = np.asarray(multpim.multiply_bits(a, b, 4, fault_gate=fg, impl=impl))
        assert (got == want).all(), impl


def test_kernel_max_width_override_bit_exact():
    nl = multpim.multiplier_netlist(8)
    rng = np.random.default_rng(5)
    a = jnp.array(rng.integers(0, 256, 40).astype(np.uint32))
    b = jnp.array(rng.integers(0, 256, 40).astype(np.uint32))
    want = np.asarray(multpim.multiply_bits(a, b, 8, impl="scan"))
    inputs = jnp.concatenate([
        jnp.array(((np.asarray(a)[:, None] >> np.arange(8)) & 1).astype(bool)),
        jnp.array(((np.asarray(b)[:, None] >> np.arange(8)) & 1).astype(bool)),
    ], axis=-1)
    for mw in (16, 64):
        got = np.asarray(execute_packed(nl, inputs, max_width=mw))
        assert (got == want).all(), mw
        got = np.asarray(scheduler.execute_levelized(nl, inputs, max_width=mw))
        assert (got == want).all(), mw


# --- scan path fault-model parity (satellite) --------------------------------

def test_scan_execute_accepts_fault_model():
    """netlist.execute takes a FaultModel wherever p_gate is accepted
    (matching stateful_logic.maybe_flip): a float rate and its
    TransientGateFaults wrapper draw the identical stream."""
    nl = multpim.multiplier_netlist(4)
    rng = np.random.default_rng(2)
    inputs = jnp.array(rng.integers(0, 2, (64, len(nl.inputs))).astype(bool))
    key = jax.random.PRNGKey(11)
    as_float = netlist.execute(nl, inputs, key=key, p_gate=0.05)
    as_model = netlist.execute(nl, inputs, key=key,
                               p_gate=TransientGateFaults(0.05))
    assert (np.asarray(as_float) == np.asarray(as_model)).all()
    # stuck-at through the scan path is idempotent under a fixed key
    model = StuckAtFaults(0.1, 0.1)
    once = netlist.execute(nl, inputs, key=key, p_gate=model)
    again = netlist.execute(nl, inputs, key=key, p_gate=model)
    assert (np.asarray(once) == np.asarray(again)).all()


# --- builder CSE + golden netlist shapes -------------------------------------

def test_cse_collapses_structural_duplicates():
    bld = netlist.NetlistBuilder()
    x, y = bld.input_bits(2)
    w1 = bld.xor(x, y)
    n1 = len(bld._gates)
    w2 = bld.xor(x, y)                    # re-emission hits the CSE cache
    assert w2 == w1 and len(bld._gates) == n1
    assert bld.min3(y, x, bld.ONE) == bld.nor(x, y)   # commutative match

    raw = netlist.NetlistBuilder(cse=False)
    x, y = raw.input_bits(2)
    raw.xor(x, y)
    n1 = len(raw._gates)
    raw.xor(x, y)
    assert len(raw._gates) == 2 * n1      # duplicates kept without CSE


#: golden (gates, depth) for the MultPIM multiplier, before and after CSE.
#: The builder's folding already emits a duplication-free netlist, so CSE
#: leaves the multiplier untouched (cse_saved=0 in netlist_bench) — the
#: equality below is the regression guard for both counts.
GOLDEN = {8: (760, 66), 16: (3312, 146), 32: (13792, 306)}


@pytest.mark.parametrize("nb", sorted(GOLDEN))
def test_golden_multiplier_gate_and_depth_counts(nb):
    gates, depth = GOLDEN[nb]
    nl = multpim.multiplier_netlist(nb)
    nl_raw = multpim.multiplier_netlist(nb, cse=False)
    assert nl.n_gates == gates
    assert nl_raw.n_gates == gates
    assert scheduler.schedule(nl).depth == depth
