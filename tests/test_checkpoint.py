import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, restore_resharded


def _state(key):
    return {"params": {"w": jax.random.normal(key, (16, 8))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = _state(key)
    ck.save(7, state)
    out = ck.restore()
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert ck.latest_step() == 7


def test_gc_keeps_window(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(key))
    assert ck.all_steps() == [3, 4]


def test_async_save_is_consistent(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=3, async_save=True)
    state = _state(key)
    ck.save(1, state)
    ck.wait()
    out = ck.restore(1)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_atomicity_no_tmp_dirs_after_save(tmp_path, key):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, _state(key))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_resharded_places_leaves(tmp_path, key):
    """Elastic restore: host arrays placed with explicit (new) shardings."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    state = _state(key)
    ck.save(2, state)
    shardings = jax.tree.map(lambda _: None, state)
    out = restore_resharded(ck, shardings)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert isinstance(out["params"]["w"], jax.Array)


def test_crash_between_resave_renames_leaves_restorable_snapshot(tmp_path):
    """Regression: a re-save of an existing step moves it to step_X.old
    before publishing; if the process dies between the two renames, the
    aside copy must still be discoverable and restorable."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, {"x": np.arange(3)})
    final = os.path.join(str(tmp_path), "step_00000005")
    os.replace(final, final + ".old")        # simulate mid-_write crash
    ck2 = Checkpointer(str(tmp_path), async_save=False)
    assert ck2.latest_step() == 5
    assert np.array_equal(ck2.restore()["x"], np.arange(3))
    # a later save of the same step publishes normally and heals the aside
    ck2.save(5, {"x": np.arange(4)}, block=True)
    assert sorted(os.listdir(tmp_path)) == ["step_00000005"]
    assert np.array_equal(ck2.restore()["x"], np.arange(4))


def test_restore_missing_step_raises_filenotfound(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=1, async_save=False)
    ck.save(1, {"x": np.arange(2)})
    ck.save(2, {"x": np.arange(2)})          # keep=1 garbage-collects step 1
    with pytest.raises(FileNotFoundError):
        ck.restore(step=1)
