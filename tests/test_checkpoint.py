import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, restore_resharded


def _state(key):
    return {"params": {"w": jax.random.normal(key, (16, 8))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = _state(key)
    ck.save(7, state)
    out = ck.restore()
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert ck.latest_step() == 7


def test_gc_keeps_window(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(key))
    assert ck.all_steps() == [3, 4]


def test_async_save_is_consistent(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=3, async_save=True)
    state = _state(key)
    ck.save(1, state)
    ck.wait()
    out = ck.restore(1)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_atomicity_no_tmp_dirs_after_save(tmp_path, key):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, _state(key))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_resharded_places_leaves(tmp_path, key):
    """Elastic restore: host arrays placed with explicit (new) shardings."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    state = _state(key)
    ck.save(2, state)
    shardings = jax.tree.map(lambda _: None, state)
    out = restore_resharded(ck, shardings)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert isinstance(out["params"]["w"], jax.Array)
