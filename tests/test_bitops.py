import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import bitops


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
@settings(max_examples=25, deadline=None)
def test_bits_roundtrip(vals):
    x = jnp.array(np.array(vals, np.uint32))
    assert (bitops.from_bits(bitops.to_bits(x, 32)) == x).all()


@given(st.integers(0, 2**32 - 1), st.integers(0, 63))
@settings(max_examples=50, deadline=None)
def test_rotl_matches_python(v, r):
    got = int(bitops.rotl32(jnp.uint32(v), r))
    want = ((v << (r % 32)) | (v >> ((32 - r) % 32))) & 0xFFFFFFFF if r % 32 else v
    assert got == want


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_popcount_and_bitpos(v):
    assert int(bitops.popcount32(jnp.uint32(v))) == bin(v).count("1")
    if bin(v).count("1") == 1:
        assert int(bitops.bit_position(jnp.uint32(v))) == v.bit_length() - 1


def test_rotl_inverse():
    x = jnp.arange(16, dtype=jnp.uint32) * jnp.uint32(2654435761)
    for r in range(32):
        assert (bitops.rotr32(bitops.rotl32(x, r), r) == x).all()


def test_float_view_roundtrip(key):
    for dt in (jnp.float32, jnp.bfloat16):
        x = jax.random.normal(key, (33,), dt)
        v = bitops.float_view_u32(x)
        back = bitops.u32_view_float(v, dt)
        assert (back == x).all()
