"""Trace-driven mMPU cost model (costmodel/, DESIGN.md §17).

Contracts:
* golden cycle totals — hand-counted latency/energy for a tiny 3-gate
  netlist under hand-pickable crossbar geometries, bit-exact;
* closed forms — ECC/TMR event-stream totals match the analytical
  formulas they were derived from, and the scheme-grid ordering matches
  every scheme's `overhead()` CostReport;
* determinism — compile+fold twice is bit-identical, and the vmapped
  grid fold agrees with per-scheme folds;
* JSONL round-trip — dump -> load -> identical stream and fold;
* engine integration — `cost_spec` adds mmpu_* telemetry gauges, and
  costs nothing (no keys) when unset.
"""
import io
import math

import jax
import numpy as np
import pytest

from repro import costmodel as cm
from repro.configs import get_config
from repro.configs.mmpu_paper import get_device
from repro.core import arena, multpim, netlist, scheduler
from repro.costmodel import (DeviceSpec, EventArrays, MmpuEvent,
                             StepProfile, base_step_events, dump_jsonl,
                             ecc_events, evaluate_grid, fold,
                             load_jsonl, lower_schedule, lower_step,
                             scale_stream, tmr_transform, vote_events)
from repro.costmodel.device import EVENT_KINDS
from repro.launch.engine import GenerationEngine, fetch_telemetry
from repro.models import params as P
from repro.models import transformer as T
from repro.reliability import DiagParityEcc, Tmr, Unprotected, standard_grid

PAPER = get_device("paper")


def _tiny_netlist():
    """3 Min3 gates, 2 levels: XOR-ish tree nor(nor(a,b), nand(a,b))."""
    b = netlist.NetlistBuilder(cse=False)
    a, bb = b.input_bits(2)
    b.mark_outputs([b.nor(b.nor(a, bb), b.nand(a, bb))])
    return b.build()


# ------------------------------------------------------- device + events

def test_device_spec_validation_and_vectors():
    with pytest.raises(ValueError):
        DeviceSpec(name="bad", rows=0, cols=4, n_crossbars=1, clock_hz=1e9)
    with pytest.raises(ValueError):
        DeviceSpec(name="bad", rows=4, cols=4, n_crossbars=1, clock_hz=0)
    spec = PAPER
    assert spec.cycle_vector()[EVENT_KINDS.index("xor")] == spec.xor_cycles
    assert len(spec.cycle_vector()) == len(EVENT_KINDS)
    assert spec.row_issues(0) == 0
    assert spec.row_issues(1) == 1
    assert spec.row_issues(spec.rows) == 1
    assert spec.row_issues(spec.rows + 1) == 2
    fast = spec.replace(clock_hz=2e9)
    assert fast.seconds(2e9) == 1.0
    assert cm.spec_from_dict(spec.to_dict()) == spec


def test_event_validation_and_scaling():
    with pytest.raises(ValueError):
        MmpuEvent(kind="bogus", count=1, cells=1)
    with pytest.raises(ValueError):
        MmpuEvent(kind="xor", count=-1, cells=1)
    with pytest.raises(ValueError):
        MmpuEvent(kind="xor", count=1, cells=1, weight=0.0)
    e = MmpuEvent(kind="xor", count=3, cells=10, xbars=2, weight=0.5)
    s = e.scaled(count_x=0.1, cells_x=2, xbars_x=3, weight_x=2.0)
    assert (s.count, s.cells, s.xbars, s.weight) == (1, 20, 6, 1.0)
    assert MmpuEvent(kind="read", count=0, cells=0).scaled(count_x=5).count == 0
    doubled = scale_stream((e, e), 2)
    assert all(ev.count == 6 and ev.cells == 20 for ev in doubled)


def test_schedule_issue_counts():
    sch = scheduler.schedule(_tiny_netlist())
    assert list(sch.widths) == [2, 1]
    assert list(sch.issue_counts(1024)) == [1, 1]
    assert list(sch.issue_counts(1)) == [2, 1]
    with pytest.raises(ValueError):
        sch.issue_counts(0)


# ------------------------------------------------ golden netlist lowering

def test_golden_tiny_netlist_cycles():
    """Hand-counted: load(2 inputs) + 2 levels x (init+min3) + read(1).

    rows=1024 -> every level is one issue:
      1 write + (1+1) + (1+1) + 1 read = 6 cycles.
    """
    sch = scheduler.schedule(_tiny_netlist())
    spec = DeviceSpec(name="t", rows=1024, cols=4, n_crossbars=2,
                      clock_hz=1e9)
    cost = fold(lower_schedule(sch, spec, trials=1, n_outputs=1), spec)
    assert cost.latency_cycles == 6.0
    # energy: write 2 cells, init 3, min3 3, read 1 (trials=1)
    exp_pj = (2 * spec.write_energy_pj + 3 * spec.init_energy_pj
              + 3 * spec.min3_energy_pj + 1 * spec.read_energy_pj)
    assert cost.energy_pj == pytest.approx(exp_pj, rel=1e-5)


def test_golden_tiny_netlist_row_capped():
    """rows=1 serializes width-2 work: 2 write + 2*(1+1) + (1+1) + 1 = 9."""
    sch = scheduler.schedule(_tiny_netlist())
    spec = DeviceSpec(name="t1", rows=1, cols=4, n_crossbars=1, clock_hz=1e9)
    cost = fold(lower_schedule(sch, spec, trials=1, n_outputs=1), spec)
    assert cost.latency_cycles == 9.0


def test_golden_tiny_netlist_column_wrap():
    """trials = 2*cols doubles every issue count, cells scale by trials."""
    sch = scheduler.schedule(_tiny_netlist())
    spec = DeviceSpec(name="t", rows=1024, cols=4, n_crossbars=2,
                      clock_hz=1e9)
    one = fold(lower_schedule(sch, spec, trials=1, n_outputs=1), spec)
    wrap = fold(lower_schedule(sch, spec, trials=2 * spec.cols,
                               n_outputs=1), spec)
    assert wrap.latency_cycles == 2 * one.latency_cycles
    assert wrap.energy_pj == pytest.approx(
        2 * spec.cols * one.energy_pj, rel=1e-5)
    with pytest.raises(ValueError):
        lower_schedule(sch, spec, trials=0)


def test_multiplier_schedule_matches_issue_counts():
    """Closed form: latency = write_issues + sum(issues)*(init+min3) +
    read_issues, straight from Schedule.issue_counts."""
    sch = scheduler.schedule(multpim.multiplier_netlist(4))
    spec = PAPER
    stream = lower_schedule(sch, spec, trials=1, n_outputs=8)
    cost = fold(stream, spec)
    issues = int(sch.issue_counts(spec.rows).sum())
    exp = (spec.row_issues(sch.base - 2) * spec.write_cycles
           + issues * (spec.init_cycles + spec.min3_cycles)
           + spec.row_issues(8) * spec.read_cycles)
    assert cost.latency_cycles == float(exp)


# ------------------------------------------------------ scheme closed forms

def test_ecc_events_closed_form():
    profile = StepProfile(weight_words=100, macs_per_token=1,
                          scrub_interval=10)
    slopes = (1, 2)
    stream = ecc_events(profile, PAPER, slopes)
    n_blocks = math.ceil(100 / arena.BLOCK)
    rounds = PAPER.row_issues(n_blocks)
    S, B = len(slopes), arena.BLOCK
    cost = fold(stream, PAPER)
    exp_cycles = (2 * (B - 1) * S * rounds * PAPER.xor_cycles
                  + (S * rounds + rounds) * PAPER.write_cycles) / 10
    assert cost.latency_cycles == pytest.approx(exp_cycles, rel=1e-5)
    exp_pj = (2 * S * (B - 1) * 32 * n_blocks * PAPER.xor_energy_pj
              + (S + 1) * 32 * n_blocks * PAPER.write_energy_pj) / 10
    assert cost.energy_pj == pytest.approx(exp_pj, rel=1e-5)
    # copies=3 (per-copy parity under TMR) scales everything by 3
    tripled = fold(ecc_events(profile, PAPER, slopes, copies=3), PAPER)
    assert tripled.energy_pj == pytest.approx(3 * cost.energy_pj, rel=1e-5)


def test_tmr_transform_disciplines():
    profile = StepProfile(weight_words=1 << 12, macs_per_token=1 << 12)
    base = base_step_events(profile, PAPER)
    b = fold(base, PAPER)
    par = fold(tmr_transform(base, "parallel"), PAPER)
    ser = fold(tmr_transform(base, "serial"), PAPER)
    semi = fold(tmr_transform(base, "semi_parallel"), PAPER)
    # parallel: same latency on 3x arrays; serial/semi: 3x latency on 1x
    assert par.latency_cycles == b.latency_cycles
    assert ser.latency_cycles == semi.latency_cycles == 3 * b.latency_cycles
    # occupancy (the cycles/token axis) is exactly 3x for all disciplines
    for c in (par, ser, semi):
        assert c.occupancy_cycles == pytest.approx(
            3 * b.occupancy_cycles, rel=1e-5)
        assert c.energy_pj == pytest.approx(3 * b.energy_pj, rel=1e-5)
    with pytest.raises(ValueError):
        tmr_transform(base, "bogus")
    # the full Tmr scheme additionally pays the Min3+NOT vote
    full = fold(lower_step(Tmr("parallel"), profile, PAPER), PAPER)
    assert full.occupancy_cycles > par.occupancy_cycles
    assert len(vote_events(profile, PAPER)) == 4


def test_grid_ordering_matches_overhead():
    """Acceptance: off < ecc < every tmr-* < every ecc+tmr, and the
    event-stream ordering equals the analytical overhead() ordering
    (occupancy == latency_x * area_x / throughput_x)."""
    profile = StepProfile(weight_words=1 << 12, macs_per_token=1 << 14,
                          mac_bits=8)
    costs = evaluate_grid(standard_grid(), profile, PAPER)
    cyc = {n: c.cycles_per_token for n, c in costs.items()}
    tmrs = [v for n, v in cyc.items() if n.startswith("tmr-")]
    joint = [v for n, v in cyc.items() if n.startswith("ecc+")]
    assert cyc["unprotected"] < cyc["ecc"] < min(tmrs)
    assert max(tmrs) < min(joint)
    occ = {s.name: s.overhead().latency_x * s.overhead().area_x
           / s.overhead().throughput_x for s in standard_grid()}
    assert sorted(cyc, key=cyc.get) == \
        sorted(occ, key=lambda n: (occ[n], cyc[n]))


# --------------------------------------------- determinism + round-trips

def test_compile_and_fold_deterministic():
    profile = StepProfile(weight_words=1 << 10, macs_per_token=1 << 10)
    for scheme in (Unprotected(), DiagParityEcc(), Tmr("serial")):
        s1 = lower_step(scheme, profile, PAPER)
        s2 = lower_step(scheme, profile, PAPER)
        assert s1 == s2                       # dataclass equality, exact
        c1, c2 = fold(s1, PAPER), fold(s2, PAPER)
        assert (c1.latency_cycles, c1.occupancy_cycles, c1.energy_pj) == \
            (c2.latency_cycles, c2.occupancy_cycles, c2.energy_pj)


def test_jsonl_round_trip(tmp_path):
    profile = StepProfile(weight_words=1 << 10, macs_per_token=1 << 10)
    stream = lower_step(DiagParityEcc(), profile, PAPER)
    path = str(tmp_path / "events.jsonl")
    assert dump_jsonl(stream, path) == len(stream)
    loaded = load_jsonl(path)
    assert loaded == stream                   # weights round-trip exactly
    a, b = fold(stream, PAPER), fold(loaded, PAPER)
    assert (a.latency_cycles, a.occupancy_cycles, a.energy_pj) == \
        (b.latency_cycles, b.occupancy_cycles, b.energy_pj)
    # file-object form too
    buf = io.StringIO()
    dump_jsonl(stream, buf)
    buf.seek(0)
    assert load_jsonl(buf) == stream


def test_vmapped_grid_agrees_with_per_scheme_folds():
    """The padded vmapped fold must agree with independent per-scheme
    folds — padding rows contribute exactly nothing."""
    profile = StepProfile(weight_words=1 << 10, macs_per_token=1 << 12)
    grid = evaluate_grid(standard_grid(), profile, PAPER)
    for scheme in standard_grid():
        solo = fold(lower_step(scheme, profile, PAPER), PAPER,
                    tokens=profile.tokens)
        g = grid[scheme.name]
        assert g.n_events == solo.n_events
        np.testing.assert_allclose(g.occupancy_cycles,
                                   solo.occupancy_cycles, rtol=1e-6)
        np.testing.assert_allclose(g.energy_pj, solo.energy_pj, rtol=1e-6)


def test_event_arrays_padding_is_inert():
    e = MmpuEvent(kind="min3", count=5, cells=7, xbars=2)
    plain = fold(( e,), PAPER)
    padded = cm.fold_arrays(EventArrays.from_events((e,), pad_to=16), PAPER)
    assert plain.latency_cycles == padded.latency_cycles
    assert plain.occupancy_cycles == padded.occupancy_cycles
    assert plain.energy_pj == padded.energy_pj


# -------------------------------------------------------- profile + engine

def test_step_profile_from_model_config():
    cfg = get_config("phi3-mini-3.8b").smoke()
    p = StepProfile.from_model_config(cfg, batch=3, mac_bits=8)
    assert p.tokens == 3 and p.mac_bits == 8
    assert p.weight_words > 0 and p.macs_per_token > 0
    assert p.n_blocks == math.ceil(p.weight_words / arena.BLOCK)
    with pytest.raises(ValueError):
        StepProfile(weight_words=0, macs_per_token=1)


def test_engine_mmpu_telemetry():
    cfg = get_config("phi3-mini-3.8b").smoke()
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab)}

    engine = GenerationEngine(cfg, DiagParityEcc(), gen=3, cost_spec=PAPER)
    store, _ = engine.prepare(params, key=key)
    _, telem = engine.generate_scan(store, batch)
    stats = fetch_telemetry(telem)
    assert float(stats["mmpu_cycles_per_token"]) > 0
    assert float(stats["mmpu_energy_pj_per_token"]) > 0
    assert int(stats["mmpu_events"]) > 0
    # projection is compiled once per batch geometry and cached
    assert engine.mmpu_projection(2) is engine.mmpu_projection(2)
    stream, cost = engine.mmpu_projection(2)
    assert float(stats["mmpu_cycles_per_token"]) == \
        pytest.approx(cost.cycles_per_token, rel=1e-5)

    plain = GenerationEngine(cfg, DiagParityEcc(), gen=3)
    store, _ = plain.prepare(params, key=key)
    _, telem = plain.generate_scan(store, batch)
    assert "mmpu_cycles_per_token" not in fetch_telemetry(telem)
    assert plain.mmpu_projection(2) is None
