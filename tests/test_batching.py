"""Continuous-batching reliable serving (DESIGN.md §16).

The acceptance bar: a request admitted into a LIVE batch mid-stream
produces exactly the tokens — and exactly the vote counters — it produces
when served through the scheduler alone (same bucket shapes), for every
standard_grid() scheme, on one device and on a forced-host 2x2 mesh; a
scheduler tick performs at most one device->host sync (the batched
completion fetch), enforced by the transfer guard; and continuous batching
beats sequential whole-batch serving >= 2x in decode slot-steps on a
skewed trace.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.faults import TransientBitFlips
from repro.launch import (BatchSpec, ContinuousBatcher, GenerationEngine,
                          PagedKVPool, Request, fetch_telemetry,
                          poisson_trace, sequential_slot_steps)
from repro.launch.mesh import make_test_mesh
from repro.models import params as P
from repro.models import transformer as T
from repro.obs import count_host_transfers
from repro.reliability.scheme import parse_scheme, standard_grid

MULTI = jax.device_count() >= 4
P_BIT = 2e-3          # dense enough that ECC counters are live
SPEC = BatchSpec(slots=2, page_tokens=8, chunk=3, prompt_buckets=(4, 8),
                 gen_cap=6)


def _cfg():
    # micro config (shared with test_sharded_engine): tiny but with every
    # shardable dim divisible by the test meshes
    return get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32, vocab=512)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    prompts = {n: np.asarray(jax.random.randint(
        jax.random.fold_in(key, n), (n,), 0, cfg.vocab)) for n in (4, 8)}
    return cfg, key, params, prompts


def _serve_alone(cfg, params, key, scheme, req, mesh=None):
    b = ContinuousBatcher(cfg, scheme, SPEC, mesh=mesh)
    b.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    return b.run([req])[0]


# -- the acceptance bar: join-live-batch == served-alone ---------------------

@pytest.mark.parametrize("scheme", standard_grid(), ids=lambda s: s.name)
def test_join_live_batch_matches_alone(setup, scheme):
    """rid=9 arrives while both slots are busy, queues, and is admitted
    mid-stream when the short request frees its slot; its tokens and
    per-request vote counter must match the alone run bit for bit."""
    cfg, key, params, prompts = setup
    b = ContinuousBatcher(cfg, scheme, SPEC)
    b.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    reqs = [Request(0, prompts[8], 6, arrival_s=0.0),
            Request(1, prompts[4], 2, arrival_s=0.0),
            Request(9, prompts[8], 5, arrival_s=0.1)]
    res = {r.rid: r for r in b.run(reqs)}
    alone = _serve_alone(cfg, params, key, scheme, Request(9, prompts[8], 5))
    np.testing.assert_array_equal(res[9].tokens, alone.tokens)
    assert res[9].vote_disagreements == alone.vote_disagreements
    # the mid-stream batch really was live: rid=9 queued behind a full batch
    assert res[9].ttft_s > 0 and len(res[9].tokens) == 5


def test_fault_counters_live(setup):
    """The bit-exactness runs must exercise real corruption — a fault rate
    that never fires would pass vacuously."""
    cfg, key, params, prompts = setup
    b = ContinuousBatcher(cfg, parse_scheme("ecc"), SPEC)
    prep = b.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    b.run([Request(0, prompts[8], 4)])
    stats = fetch_telemetry({**prep, **b.telemetry()})
    assert int(stats["ecc_corrected"]) > 0
    assert int(stats["tokens_emitted"]) == 4


# -- zero-sync scheduler contract --------------------------------------------

def test_tick_single_transfer_contract(setup):
    """Extends the PR-7 transfer guard to the scheduler: the only
    device->host sync a tick may perform is ONE batched device_get of
    finished rows — so total syncs over a run equal the number of ticks
    on which some request completed, and the telemetry fetch stays one."""
    cfg, key, params, prompts = setup
    scheme = parse_scheme("ecc+tmr")          # worst case: pool parity +
    b = ContinuousBatcher(cfg, scheme, SPEC,  # copy axis + device scrubs
                          scrub_every=2)
    prep = b.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    b.run([Request(99, prompts[8], 3)])       # warmup: compile everything
    reqs = [Request(0, prompts[8], 6), Request(1, prompts[4], 2),
            Request(2, prompts[8], 5), Request(3, prompts[4], 3)]
    for r in reqs:
        b.submit(r)
    completion_ticks = 0
    with count_host_transfers() as ledger:
        b.admit()
        while b.active or b.queue:
            if b.tick():
                completion_ticks += 1
            b.admit()
    assert completion_ticks > 0
    assert ledger.syncs == completion_ticks, ledger.sites
    assert completion_ticks <= b.ticks
    with count_host_transfers() as ledger2:
        stats = fetch_telemetry({**prep, **b.telemetry()})
    assert ledger2.syncs == 1, ledger2.sites
    assert int(stats["tokens_emitted"]) == 3 + sum(r.gen for r in reqs)
    assert int(stats["ecc_corrected"]) > 0


# -- goodput: continuous batching vs whole-batch serving ---------------------

def test_slot_steps_beat_sequential_2x(setup):
    """On a skewed short/long trace the scheduler recycles the short
    requests' slots while the long ones run; whole-batch serving pads
    every row of a group to the group max.  Machine-independent decode
    slot-step accounting must show >= 2x."""
    cfg, key, params, prompts = setup
    spec = BatchSpec(slots=4, page_tokens=8, chunk=2, prompt_buckets=(4,),
                     gen_cap=16)
    b = ContinuousBatcher(cfg, None, spec)
    b.prepare(params, key=key)
    reqs = [Request(i, prompts[4], 2 if i % 4 else 16,
                    arrival_s=i * 1e-3) for i in range(16)]
    res = b.run(reqs)
    useful = sum(r.gen for r in reqs)
    assert sum(len(r.tokens) for r in res) == useful
    seq = sequential_slot_steps(reqs, spec.slots)
    assert seq >= 2 * b.decode_slot_steps, (seq, b.decode_slot_steps)


def test_poisson_trace_shape():
    trace = poisson_trace(32, rate_rps=8.0, spec=SPEC, vocab=512, seed=3)
    assert len(trace) == 32
    assert all(len(r.prompt) in SPEC.prompt_buckets for r in trace)
    assert all(1 <= r.gen <= SPEC.gen_cap for r in trace)
    arr = [r.arrival_s for r in trace]
    assert arr == sorted(arr) and arr[-1] > 0
    # skewed mix: both short and long generations present
    gens = {r.gen for r in trace}
    assert len(gens) >= 2


# -- scheduler/pool mechanics ------------------------------------------------

def test_admission_validation_and_pool_exhaustion(setup):
    cfg, key, params, prompts = setup
    b = ContinuousBatcher(cfg, None, SPEC)
    b.prepare(params, key=key)
    with pytest.raises(ValueError, match="buckets"):
        b.submit(Request(0, np.zeros(5, np.int32), 2))
    with pytest.raises(ValueError, match="gen"):
        b.submit(Request(0, prompts[4], SPEC.gen_cap + 1))
    # a request whose reservation exceeds the whole pool can never start
    tiny = BatchSpec(slots=2, page_tokens=8, chunk=3, prompt_buckets=(8,),
                     gen_cap=6, n_pages=1)
    b2 = ContinuousBatcher(cfg, None, tiny)
    b2.prepare(params, key=key)
    b2.submit(Request(0, prompts[8], 6))
    with pytest.raises(RuntimeError, match="pool too small"):
        b2.drain()


def test_page_allocator_reuse_and_double_free():
    pool = PagedKVPool(_cfg(), SPEC, copies=False)
    a = pool.alloc(3)
    assert a is not None and pool.free_pages == SPEC.pool_pages - 3
    assert pool.alloc(SPEC.pool_pages) is None    # short -> None, no change
    assert pool.free_pages == SPEC.pool_pages - 3
    pool.free(a)
    assert pool.free_pages == SPEC.pool_pages
    b = pool.alloc(3)
    assert set(map(int, b)) == set(map(int, a))   # freed pages reused
    with pytest.raises(ValueError, match="double free"):
        pool.free(np.concatenate([b, b]))
    with pytest.raises(ValueError, match="bad page"):
        pool.free(np.asarray([0], np.int32))      # scratch is not freeable


def test_page_zero_is_scratch(setup):
    """Empty slots and unreserved table entries point at page 0; whatever
    lands there must never leak into an active request's tokens — covered
    by the join test, but assert the invariant directly."""
    cfg, key, params, prompts = setup
    b = ContinuousBatcher(cfg, None, SPEC)
    b.prepare(params, key=key)
    b.submit(Request(0, prompts[4], 3))
    b.admit()
    assert (b.table[0] == 0).sum() >= 1          # unreserved entries
    assert (b.table[1] == 0).all()               # empty slot
    assert all(p >= 1 for p in b._slots[0].pages)


# -- engine chunk-compile cache (satellite) ----------------------------------

def test_chunk_cache_bounded_and_bit_exact(setup):
    """Sweeping chunk sizes across one engine keeps the compiled-chunk
    cache LRU-bounded at CHUNK_CACHE_MAX while every chunking stays
    bit-exact against the unchunked scan."""
    cfg, key, params, prompts = setup
    eng = GenerationEngine(cfg, parse_scheme("ecc"), gen=16)
    store, _ = eng.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    batch = {"tokens": np.asarray(prompts[8])[None, :]}
    ref = np.asarray(eng.generate_scan(store, batch)[0])
    sizes = set()
    for chunk in (1, 3, 5, 6, 7, 9, 11, 15):
        toks, _, _ = eng.generate_chunked(store, batch, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(toks), ref,
                                      err_msg=f"chunk={chunk}")
        sizes.update(eng._chunk_sizes(chunk))
        assert len(eng._chunk_built) <= eng.CHUNK_CACHE_MAX
    assert len(sizes) > eng.CHUNK_CACHE_MAX      # eviction actually fired
    # tail decomposition covers gen-1 steps from {chunk} | {2^k < chunk}
    for chunk in range(1, 20):
        parts = list(eng._chunk_sizes(chunk))
        assert sum(parts) == eng.gen - 1
        assert all(n == chunk or (n & (n - 1)) == 0 for n in parts)


# -- forced-host mesh (subprocess on single-device hosts) --------------------

needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

MESH_SCHEMES = ["ecc", "tmr-parallel", "ecc+tmr-serial"]


@needs_devices
@pytest.mark.parametrize("name", MESH_SCHEMES)
def test_join_matches_alone_on_mesh(setup, name):
    """The acceptance bar's second half: same join-vs-alone bit-exactness
    with the scheduler running on a forced-host 2x2 mesh."""
    cfg, key, params, prompts = setup
    scheme = parse_scheme(name)
    mesh = make_test_mesh(2, 2)
    b = ContinuousBatcher(cfg, scheme, SPEC, mesh=mesh)
    b.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    reqs = [Request(0, prompts[8], 6, arrival_s=0.0),
            Request(1, prompts[4], 2, arrival_s=0.0),
            Request(9, prompts[8], 5, arrival_s=0.1)]
    res = {r.rid: r for r in b.run(reqs)}
    alone = _serve_alone(cfg, params, key, scheme,
                         Request(9, prompts[8], 5), mesh=mesh)
    np.testing.assert_array_equal(res[9].tokens, alone.tokens)
    assert res[9].vote_disagreements == alone.vote_disagreements
    # and the mesh run matches the single-device scheduler bit for bit
    single = _serve_alone(cfg, params, key, scheme,
                          Request(9, prompts[8], 5))
    np.testing.assert_array_equal(alone.tokens, single.tokens)


@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="already running with >= 4 devices")
def test_mesh_suite_subprocess():
    """Single-device hosts: re-run this file's native mesh tests with 4
    forced host devices (jax pins the device count at first init)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=src)
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__), "-k", "mesh and not subprocess"],
        env=env, capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
