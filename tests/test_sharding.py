"""Logical-axis sharding resolution + an 8-device mini dry-run in a
subprocess (the main test process must keep 1 device)."""
import json
import os
import subprocess
import sys

import pytest

from repro.models.params import Spec
from repro.pshard import DEFAULT_RULES, ShardingRules, spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_divisibility_degrades_to_replication():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv heads = 8 do not divide model=16 -> replicated
    spec = spec_for((8, 128), ("kv_heads", None), mesh, DEFAULT_RULES)
    assert spec == type(spec)(None, None)


def test_axis_never_used_twice():
    mesh = FakeMesh({"data": 4, "model": 4})
    spec = spec_for((64, 64, 64), ("batch", "kv_seq", "kv_heads"), mesh,
                    DEFAULT_RULES)
    # batch -> data, kv_seq -> model, kv_heads would reuse model -> None
    assert spec[2] is None


def test_fsdp_two_dim_sharding():
    mesh = FakeMesh({"data": 8, "model": 8})
    spec = spec_for((512, 1024), ("model_dim", "ff"), mesh, DEFAULT_RULES)
    assert spec[0] == "data" and spec[1] == "model"


def test_rules_replace():
    r = DEFAULT_RULES.replace(kv_seq=())
    assert r.axes_for("kv_seq") == ()
    assert DEFAULT_RULES.axes_for("kv_seq") == ("model",)


def test_copy_axis_rule_degrades_without_copy_mesh():
    # the "copy" logical axis resolves only on fold_copy_axis meshes;
    # plain data x model meshes replicate the stacked copies
    plain = FakeMesh({"data": 4, "model": 4})
    spec = spec_for((3, 64), ("copy", None), plain, DEFAULT_RULES)
    assert spec[0] is None
    folded = FakeMesh({"copy": 3, "data": 2, "model": 4})
    spec = spec_for((3, 64), ("copy", None), folded, DEFAULT_RULES)
    assert spec[0] == "copy"


def test_arena_block_rule_whole_mesh():
    mesh = FakeMesh({"data": 4, "model": 4})
    spec = spec_for((160, 3), ("arena_block", None), mesh, DEFAULT_RULES)
    assert spec[0] == ("data", "model")
    # indivisible block counts degrade to replication, never error
    spec = spec_for((7, 3), ("arena_block", None), mesh, DEFAULT_RULES)
    assert spec[0] is None


def test_mesh_guard_names_xla_flags():
    from repro.launch.mesh import make_test_mesh
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_test_mesh(64, 64)   # no host exposes 4096 devices


def test_fold_copy_axis_indivisible():
    from repro.launch.mesh import fold_copy_axis, make_test_mesh
    mesh = make_test_mesh(1, 1)
    assert fold_copy_axis(mesh) is None   # data=1 cannot host 3 copies


@pytest.mark.slow
def test_mini_dryrun_8_devices(tmp_path):
    """Lower+compile a smoke config against a forced 8-device mesh in a
    subprocess; proves the sharding rules produce a coherent program."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import sys
sys.path.insert(0, %r)
from repro.configs import get_config
from repro.models import params as P, transformer as T
from repro.models.steps import make_train_step, init_train_state
from repro.optim import AdamWConfig
from repro.pshard import DEFAULT_RULES, use_mesh_and_rules
from repro.models.params import abstractify

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("qwen2.5-14b").smoke().replace(d_model=64, d_ff=128, vocab=256)
with use_mesh_and_rules(mesh, DEFAULT_RULES):
    specs = T.model_specs(cfg)
    params = abstractify(specs, mesh)
    state = {"params": params,
             "opt": {"m": abstractify(specs, mesh),
                     "v": abstractify(specs, mesh),
                     "count": jax.ShapeDtypeStruct((), jnp.int32)}}
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    step = make_train_step(cfg, AdamWConfig())
    compiled = jax.jit(step).lower(state, batch).compile()
    print("COMPILED", compiled.memory_analysis().temp_size_in_bytes >= 0)
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script % os.path.abspath(src)],
                         capture_output=True, text=True, timeout=600)
    assert "COMPILED True" in out.stdout, out.stderr[-2000:]
