"""Launch-layer units: HLO collective parsing, shape registry, policies."""
import pytest

from repro.configs import get_config, get_train_policy, list_archs
from repro.launch.hlo_stats import parse_collectives
from repro.launch.specs import SHAPES, applicable, arch_rules, skip_reason

SAMPLE_HLO = """
  %all-reduce.1 = f32[2,32768,8192]{2,1,0} all-reduce(%x), channel_id=17, replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[8,5120,16384]{2,0,1} all-gather(%w), dims={1}, replica_groups={{0,1,2,3},{4,5,6,7}}
  %rs = (f32[128]{0}, f32[128]{0}) reduce-scatter(%a, %b), replica_groups=[2,8]<=[16]
  %cp = bf16[1,4096]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a = f32[64,64]{1,0} all-to-all(%z), replica_groups=[4,4]<=[16]
  %ard = f32[9]{0} all-reduce-done(%start)
"""


def test_parse_collectives_bytes_and_groups():
    st = parse_collectives(SAMPLE_HLO)
    assert st.per_op_count["all-reduce"] == 1       # -done skipped
    assert st.per_op_bytes["all-reduce"] == 2 * 32768 * 8192 * 4
    assert st.per_op_bytes["all-gather"] == 8 * 5120 * 16384 * 2
    assert st.per_op_bytes["reduce-scatter"] == 2 * 128 * 4
    assert st.per_op_group["all-gather"] == 4       # explicit groups
    assert st.per_op_group["all-reduce"] == 16      # iota groups [rows,cols]
    assert st.link_traffic_bytes() > 0


def test_ring_model_all_reduce_factor():
    st = parse_collectives(
        "%ar = f32[100]{0} all-reduce(%x), replica_groups=[1,4]<=[4]")
    # 2*(n-1)/n with n=4 -> 1.5x result bytes
    assert st.link_traffic_bytes() == pytest.approx(400 * 1.5)


def test_shape_applicability():
    assert skip_reason(get_config("deepseek-67b"), SHAPES["long_500k"])
    assert applicable(get_config("mamba2-130m"), SHAPES["long_500k"])
    assert applicable(get_config("recurrentgemma-2b"), SHAPES["long_500k"])
    for arch in list_archs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert applicable(get_config(arch), SHAPES[s])


def test_train_policies_resolve():
    for arch in list_archs():
        p = get_train_policy(arch)
        assert set(p) >= {"microbatches", "param_dtype", "opt_dtype", "grad_dtype"}
    assert get_train_policy("llama4-maverick-400b-a17b")["param_dtype"] == "bfloat16"


def test_serve_rules_override_only_in_serve_mode():
    base = arch_rules("llama4-maverick-400b-a17b", serve=False)
    serve = arch_rules("llama4-maverick-400b-a17b", serve=True)
    assert base.axes_for("expert") == ("model",)
    assert serve.axes_for("expert") == ("data",)
    assert serve.axes_for("model_dim") == ()


def test_roofline_param_counts_sane():
    from benchmarks.roofline import param_count
    n = param_count(get_config("deepseek-67b"))
    assert 6.2e10 < n["total"] < 7.2e10              # ~67B
    m = param_count(get_config("llama4-maverick-400b-a17b"))
    assert 3.5e11 < m["total"] < 4.6e11              # ~400B
    assert 1.4e10 < m["active"] < 2.2e10             # ~17B active
    s = param_count(get_config("mamba2-130m"))
    assert 0.8e8 < s["total"] < 2.0e8
