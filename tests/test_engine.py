"""Scan-compiled generation engine (DESIGN.md §13).

Bit-exactness contracts:
* one-launch prefill+scan generation == the interpreted Python-loop
  reference, for every config family and every scheme in standard_grid();
* the engine's TMR/Compose paths == the legacy PR-4 sequential path
  (three full generations + one final vote) under identical fault keys;
* vote-every-k == vote-at-end when no faults are injected.

Plus engine telemetry (on-device counters, single fetch), TTFT, the
and the TrainLoop eval hook.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.faults import TransientBitFlips
from repro.launch.engine import (GenerationEngine, fetch_telemetry,
                                 make_eval_hook)
from repro.models import params as P
from repro.models import transformer as T
from repro.models.steps import make_decode_step, make_prefill_step
from repro.reliability import Compose, DiagParityEcc, Tmr, parse_scheme, \
    standard_grid

B, PROMPT, GEN = 2, 8, 5

ARCH_BY_FAMILY = {
    "dense": "phi3-mini-3.8b",
    "moe": "phi3.5-moe-42b-a6.6b",
    "vlm": "llama-3.2-vision-11b",
    "encdec": "seamless-m4t-medium",
    "ssm": "mamba2-130m",
}


def _setup(family):
    cfg = get_config(ARCH_BY_FAMILY[family]).smoke()
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_emb"] = jax.random.normal(
            key, (B, cfg.vis_tokens, cfg.vis_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(
            key, (B, PROMPT, cfg.d_model), jnp.float32)
    return cfg, key, params, batch


def _assert_scan_matches_loop(family, spec, p_bit=0.0):
    cfg, key, params, batch = _setup(family)
    engine = GenerationEngine(cfg, parse_scheme(spec), gen=GEN)
    fault = TransientBitFlips(p_bit) if p_bit else None
    store, _ = engine.prepare(params, key=key, fault=fault)
    scan, _ = engine.generate_scan(store, batch)
    loop, _ = engine.generate_loop(store, batch)
    assert scan.shape == (B, GEN) and scan.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(scan), np.asarray(loop))


# every config family through the tentpole paths (single scan, vmapped
# copy axis, fused 3-copy scrub + copy axis) ...
@pytest.mark.parametrize("family", sorted(ARCH_BY_FAMILY))
@pytest.mark.parametrize("spec", ["off", "tmr-parallel",
                                  "ecc+tmr-parallel"])
def test_scan_matches_loop_per_family(family, spec):
    _assert_scan_matches_loop(family, spec, p_bit=1e-4)


# ... and the remaining standard_grid() schemes on the dense family, so
# every scheme in the grid is covered scan-vs-loop
@pytest.mark.parametrize("spec", ["ecc", "tmr-serial", "tmr-semi",
                                  "ecc+tmr"])
def test_scan_matches_loop_remaining_grid_schemes(spec):
    _assert_scan_matches_loop("dense", spec, p_bit=1e-4)


def test_standard_grid_is_fully_covered():
    """The two parametrizations above must jointly cover standard_grid()
    (fails if the grid grows without this file keeping up)."""
    covered = {parse_scheme(s).name for s in
               ("off", "tmr-parallel", "ecc+tmr-parallel", "ecc",
                "tmr-serial", "tmr-semi", "ecc+tmr")}
    assert {s.name for s in standard_grid()} <= covered


def test_engine_tmr_matches_legacy_sequential_path():
    """Acceptance: engine TMR generations are bit-exact vs the PR-4 path
    (three sequential full generations, one final per-bit vote) under
    identical fault keys (fold_in(key, 100+i) per copy)."""
    cfg, key, params, batch = _setup("dense")
    fault = TransientBitFlips(1e-4)
    prefill = jax.jit(make_prefill_step(cfg, cache_len=PROMPT + GEN))
    decode = jax.jit(make_decode_step(cfg))

    def run_copy(p):
        tok, _, cache = prefill(p, batch)
        toks = [tok]
        for _ in range(GEN - 1):
            tok, _, cache = decode(p, tok, cache)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    copies = [fault.corrupt(params, jax.random.fold_in(key, 100 + i))
              for i in range(3)]
    for disc in ("serial", "parallel", "semi_parallel"):
        scheme = Tmr(disc)
        legacy = scheme.wrap(run_copy, sequential=True)(*copies)
        engine = GenerationEngine(cfg, scheme, gen=GEN)
        store, _ = engine.prepare(params, key=key, fault=fault)
        out, _ = engine.generate(store, batch)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy),
                                      err_msg=disc)


def test_engine_compose_matches_legacy_sequential_path():
    """Compose: per-copy ECC scrub (legacy: a Python loop of three) + TMR
    vote must be bit-exact vs the engine's one-launch scrub + copy axis."""
    cfg, key, params, batch = _setup("dense")
    fault = TransientBitFlips(2e-4)
    scheme = Compose(DiagParityEcc(), Tmr("parallel"))
    prefill = jax.jit(make_prefill_step(cfg, cache_len=PROMPT + GEN))
    decode = jax.jit(make_decode_step(cfg))

    def run_copy(p):
        tok, _, cache = prefill(p, batch)
        toks = [tok]
        for _ in range(GEN - 1):
            tok, _, cache = decode(p, tok, cache)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    prot = scheme.ecc.protect(params)
    fixed_copies = []
    for i in range(3):
        bad = fault.corrupt(params, jax.random.fold_in(key, 100 + i))
        fixed, _ = scheme.ecc.scrub(scheme.ecc.adopt(bad, prot.redundancy))
        fixed_copies.append(fixed.payload)
    legacy = scheme.tmr.wrap(run_copy, sequential=True)(*fixed_copies)

    engine = GenerationEngine(cfg, scheme, gen=GEN)
    store, prep = engine.prepare(params, key=key, fault=fault)
    out, _ = engine.generate(store, batch)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))
    stats = fetch_telemetry(prep)
    assert stats["ecc_corrected"] > 0      # the injection actually landed


def test_vote_every_matches_vote_at_end_without_faults():
    """In-scan voting every k steps must be a no-op when the copies are
    identical (no faults): same tokens as vote-at-end and as a single
    unprotected generation."""
    cfg, key, params, batch = _setup("dense")
    single, _ = GenerationEngine(cfg, gen=GEN).generate(params, batch)
    scheme = Tmr("parallel")
    outs = []
    for kw in (dict(vote_every=0), dict(vote_every=2),
               dict(vote_every=2, vote_cache=True), dict(vote_every=1)):
        engine = GenerationEngine(cfg, scheme, gen=GEN, **kw)
        store, _ = engine.prepare(params)
        out, _ = engine.generate(store, batch)
        outs.append((kw, out))
    for kw, out in outs:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(single),
                                      err_msg=str(kw))


def test_in_scan_voting_stops_divergence_compounding():
    """With one heavily corrupted copy and two clean ones, in-scan voting
    (tokens + caches, every step) pins the token stream to the 2-of-3
    clean majority, and the stacked per-step disagreement counters come
    back one per generated token (prefill token included)."""
    cfg, key, params, batch = _setup("dense")
    bad = TransientBitFlips(3e-3).corrupt(params, jax.random.fold_in(key, 7))
    store = jax.tree.map(lambda a, b, c: jnp.stack([a, b, c]),
                         params, bad, params)
    clean, _ = GenerationEngine(cfg, gen=GEN).generate(params, batch)
    engine = GenerationEngine(cfg, Tmr("parallel"), gen=GEN, vote_every=1,
                              vote_cache=True)
    out, telem = engine.generate(store, batch)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))
    stats = fetch_telemetry(telem)
    # counters are sampled BEFORE each vote: the corrupted copy's divergent
    # proposals must be visible even though voting then overrides them
    assert stats["tmr_step_disagreements"].sum() > 0
    assert stats["tmr_step_disagreements"].shape == (GEN,)


def test_telemetry_stays_on_device_until_fetch():
    cfg, key, params, batch = _setup("dense")
    engine = GenerationEngine(cfg, Tmr("parallel"), gen=GEN)
    store, _ = engine.prepare(params, key=key, fault=TransientBitFlips(1e-4))
    out, telem = engine.generate(store, batch)
    for v in telem.values():
        assert isinstance(v, jax.Array)     # no host transfer yet
    stats = fetch_telemetry(telem)
    assert set(stats) == {"tmr_step_disagreements",
                          "tmr_final_disagreements", "tokens_emitted"}
    assert int(stats["tokens_emitted"]) == B * GEN


def test_ttft_returns_first_token():
    cfg, key, params, batch = _setup("dense")
    engine = GenerationEngine(cfg, gen=GEN)
    tok = engine.ttft(params, batch)
    full, _ = engine.generate(params, batch)
    np.testing.assert_array_equal(np.asarray(tok[:, 0]),
                                  np.asarray(full[:, 0]))
    tmr_engine = GenerationEngine(cfg, Tmr("parallel"), gen=GEN)
    store, _ = tmr_engine.prepare(params)
    np.testing.assert_array_equal(np.asarray(tmr_engine.ttft(store, batch)),
                                  np.asarray(tok))


def test_make_eval_hook_in_train_loop(tmp_path):
    """The engine-backed eval hook fires every eval_every steps with
    device-resident tokens from the loop's current params."""
    from repro.checkpoint import Checkpointer
    from repro.runtime import LoopConfig, TrainLoop

    cfg, key, params, batch = _setup("dense")
    engine = GenerationEngine(cfg, gen=3)

    def train_step(state, b):
        return state, {"loss": jnp.zeros(())}

    loop = TrainLoop(train_step, {"params": params},
                     lambda s: jnp.zeros((2,)),
                     LoopConfig(total_steps=6, checkpoint_every=0,
                                log_every=0, eval_every=3),
                     ckpt=Checkpointer(str(tmp_path), async_save=False),
                     log=lambda *_: None,
                     eval_fn=make_eval_hook(engine, batch))
    loop.run()
    assert [e["step"] for e in loop.eval_history] == [3, 6]
    ref, _ = engine.generate(params, batch)
    for e in loop.eval_history:
        assert isinstance(e["tokens"], jax.Array)
        np.testing.assert_array_equal(np.asarray(e["tokens"]),
                                      np.asarray(ref))


def test_engine_rejects_unknown_execution():
    cfg = get_config("phi3-mini-3.8b").smoke()
    with pytest.raises(ValueError, match="scan"):
        GenerationEngine(cfg, gen=4, execution="turbo")


def test_engine_rejects_silent_vote_noops():
    """Every vote-flag combination that would silently do nothing must
    raise: no copy axis, loop execution, cache votes without vote points,
    and the serial discipline (copies never run concurrently)."""
    cfg = get_config("phi3-mini-3.8b").smoke()
    with pytest.raises(ValueError, match="copy axis"):
        GenerationEngine(cfg, gen=4, vote_every=2)
    with pytest.raises(ValueError, match="scan"):
        GenerationEngine(cfg, Tmr("parallel"), gen=4, vote_every=2,
                         execution="loop")
    with pytest.raises(ValueError, match="vote_every"):
        GenerationEngine(cfg, Tmr("parallel"), gen=4, vote_cache=True)
    with pytest.raises(ValueError, match="serial"):
        GenerationEngine(cfg, Tmr("serial"), gen=4, vote_every=2)
    GenerationEngine(cfg, Tmr("serial"), gen=4)          # vote-at-end: fine
