"""Crossbar stateful-logic semantics (paper §II-A, §III-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crossbar import Crossbar, ErrorModel


@pytest.fixture
def xb():
    rng = np.random.default_rng(0)
    return Crossbar.from_array(rng.integers(0, 2, (16, 16)))


def test_row_gate_all_rows_one_cycle(xb):
    out = xb.row_gate("nor", [0, 1], 5)
    want = ~(xb.state[:, 0] | xb.state[:, 1])
    assert (out.state[:, 5] == want).all()
    assert out.counter.cycles == 1
    assert out.counter.gate_evals == 16      # row parallelism is free


def test_col_gate_all_cols_one_cycle(xb):
    out = xb.col_gate("min3", [0, 1, 2], 7)
    a, b, c = xb.state[0], xb.state[1], xb.state[2]
    want = ~((a & b) | (b & c) | (a & c))
    assert (out.state[7, :] == want).all()
    assert out.counter.cycles == 1


def test_partitioned_row_gate(xb):
    out = xb.partitioned_row_gate("nor", 4, [0, 1], 3)
    view = xb.state.reshape(16, 4, 4)
    want = ~(view[:, :, 0] | view[:, :, 1])
    got = out.state.reshape(16, 4, 4)[:, :, 3]
    assert (got == want).all()
    assert out.counter.cycles == 1           # partitions multiply throughput
    assert out.counter.gate_evals == 16 * 4


def test_xor_costs_five_cycles(xb):
    out = xb.row_gate("xor", [0, 1], 6)
    want = xb.state[:, 0] ^ xb.state[:, 1]
    assert (out.state[:, 6] == want).all()
    assert out.counter.cycles == 5


def test_direct_errors_flip_outputs():
    rng = np.random.default_rng(1)
    xb = Crossbar.from_array(rng.integers(0, 2, (512, 8)),
                             errors=ErrorModel(p_gate=0.2))
    out = xb.row_gate("nor", [0, 1], 5, key=jax.random.PRNGKey(0))
    want = ~(xb.state[:, 0] | xb.state[:, 1])
    frac = float((out.state[:, 5] != want).mean())
    assert 0.1 < frac < 0.3


def test_indirect_errors_corrupt_inputs():
    rng = np.random.default_rng(2)
    xb = Crossbar.from_array(rng.integers(0, 2, (4096, 4)),
                             errors=ErrorModel(p_input=0.05))
    out = xb.row_gate("nor", [0, 1], 3, key=jax.random.PRNGKey(1))
    changed = float((out.state[:, :2] != xb.state[:, :2]).mean())
    assert 0.02 < changed < 0.10


def test_retention_drift():
    xb = Crossbar.zeros(64, 64, errors=ErrorModel(p_retention=0.01))
    out = xb.drift(jax.random.PRNGKey(0), dt=10.0)
    frac = float(out.state.mean())
    assert 0.03 < frac < 0.2                 # ~1-(0.99)^10 ~ 9.6%
