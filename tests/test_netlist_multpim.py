"""Min3 netlists + MultPIM-style multiplier (paper §VI-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import multpim, netlist


def test_builder_folding():
    b = netlist.NetlistBuilder()
    (x,) = b.input_bits(1)
    assert b.min3(b.ZERO, b.ONE, b.ONE) == b.ZERO   # const folded, no gate
    assert b.and_(x, b.ZERO) == b.ZERO
    assert b.or_(x, b.ONE) == b.ONE
    assert b.xor(x, x) == b.ZERO
    n_before = len(b._gates)
    b.xor(x, b.ZERO)
    assert len(b._gates) == n_before               # xor with 0 is free


@pytest.mark.parametrize("impl", ["scan", "level", "kernel"])
@pytest.mark.parametrize("nb", [2, 4, 8])
def test_multiplier_exact(nb, impl):
    rng = np.random.default_rng(nb)
    n = 200 if nb > 2 else 16
    a = rng.integers(0, 2**nb, n).astype(np.uint32)
    b = rng.integers(0, 2**nb, n).astype(np.uint32)
    bits = multpim.multiply_bits(jnp.array(a), jnp.array(b), nb, impl=impl)
    want = multpim.true_product_bits(a, b, nb)
    assert (np.asarray(bits) == want).all()


def test_multiplier_exhaustive_4bit():
    a, b = np.meshgrid(np.arange(16, dtype=np.uint32),
                       np.arange(16, dtype=np.uint32))
    a, b = a.reshape(-1), b.reshape(-1)
    bits = multpim.multiply_bits(jnp.array(a), jnp.array(b), 4)
    assert (np.asarray(bits) == multpim.true_product_bits(a, b, 4)).all()


def test_single_fault_injection_flips_exactly_target_gate():
    nl = multpim.multiplier_netlist(4)
    rng = np.random.default_rng(0)
    a = jnp.array(rng.integers(0, 16, nl.n_gates).astype(np.uint32))
    b = jnp.array(rng.integers(0, 16, nl.n_gates).astype(np.uint32))
    # fault at gate g for trial g: some faults must corrupt, some are masked
    out = multpim.multiply_bits(a, b, 4,
                                fault_gate=jnp.arange(nl.n_gates, dtype=jnp.int32))
    want = multpim.true_product_bits(a, b, 4)
    wrong = (np.asarray(out) != want).any(axis=1)
    assert 0.0 < wrong.mean() < 1.0   # masking exists but is not total


def test_iid_faults_monotone_in_p():
    nl = multpim.multiplier_netlist(8)
    rng = np.random.default_rng(1)
    a = jnp.array(rng.integers(0, 256, 256).astype(np.uint32))
    b = jnp.array(rng.integers(0, 256, 256).astype(np.uint32))
    want = multpim.true_product_bits(np.asarray(a), np.asarray(b), 8)
    rates = []
    for p in (1e-4, 1e-3, 1e-2):
        out = multpim.multiply_bits(a, b, 8, key=jax.random.PRNGKey(0), p_gate=p)
        rates.append(float((np.asarray(out) != want).any(axis=1).mean()))
    assert rates[0] <= rates[1] <= rates[2]


def test_tmr_multiplication_beats_baseline():
    nb, trials, p = 8, 512, 2e-3
    rng = np.random.default_rng(2)
    a = jnp.array(rng.integers(0, 256, trials).astype(np.uint32))
    b = jnp.array(rng.integers(0, 256, trials).astype(np.uint32))
    want = multpim.true_product_bits(np.asarray(a), np.asarray(b), nb)
    base = multpim.multiply_bits(a, b, nb, key=jax.random.PRNGKey(1), p_gate=p)
    tmr = multpim.multiply_tmr_bits(a, b, nb, jax.random.PRNGKey(2), p_gate=p)
    r_base = float((np.asarray(base) != want).any(axis=1).mean())
    r_tmr = float((np.asarray(tmr) != want).any(axis=1).mean())
    assert r_tmr < r_base


def test_gate_counts_reasonable():
    assert multpim.multiplier_netlist(8).n_gates < 1000
    assert multpim.multiplier_netlist(32).n_gates < 16000
