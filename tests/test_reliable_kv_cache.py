"""Beyond-paper extension: ECC-protected KV caches.

At decode time the KV cache is the largest HBM tenant (e.g. 816 GB for
deepseek-67b decode_32k) and lives across thousands of steps — exactly the
long-residency, silently-read access pattern the paper's indirect-soft-error
analysis targets for weights.  The word-level diagonal ECC store applies
unchanged to the bf16 cache pytree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.reliability import ReliableStore
from repro.faults import inject_bit_flips
from repro.models import params as P
from repro.models import transformer as T
from repro.models.steps import make_decode_step, make_prefill_step


def test_scrubbed_cache_decodes_identically():
    cfg = get_config("qwen2.5-14b").smoke().replace(
        d_model=64, d_ff=128, vocab=128, n_layers=2, compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    prefill = jax.jit(make_prefill_step(cfg, cache_len=24))
    decode = jax.jit(make_decode_step(cfg))

    tok, _, cache = prefill(params, batch)
    kv = {"k": cache["k"], "v": cache["v"]}
    store = ReliableStore.protect(kv)

    # silent corruption of the resident cache between decode steps
    bad_kv = inject_bit_flips(kv, jax.random.fold_in(key, 1), 2e-5)
    fixed, rep = ReliableStore(bad_kv, store.parity).scrub()
    if int(rep.uncorrectable):
        pytest.skip("double-flip in one block for this seed")
    for name in ("k", "v"):
        assert np.array_equal(np.asarray(fixed.params[name], np.float32),
                              np.asarray(kv[name], np.float32))

    clean_cache = dict(cache)
    scrub_cache = dict(cache, k=fixed.params["k"], v=fixed.params["v"])
    corrupt_cache = dict(cache, k=bad_kv["k"], v=bad_kv["v"])
    _, l_clean, _ = decode(params, tok, clean_cache)
    _, l_scrub, _ = decode(params, tok, scrub_cache)
    _, l_bad, _ = decode(params, tok, corrupt_cache)
    assert np.array_equal(np.asarray(l_clean), np.asarray(l_scrub))
    # the corrupted cache generally changes the logits (SDC would propagate)
    assert np.asarray(l_bad).shape == np.asarray(l_clean).shape


def test_cache_parity_overhead_is_small():
    cfg = get_config("qwen2.5-14b").smoke()
    key = jax.random.PRNGKey(1)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    tok, _, cache = jax.jit(make_prefill_step(cfg, cache_len=16))(params, batch)
    kv = {"k": cache["k"], "v": cache["v"]}
    store = ReliableStore.protect(kv)
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(kv))
    par_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(store.parity))
    assert par_bytes / cache_bytes <= 3 / 32 + 0.02   # ~9.4%
