"""Beyond-paper extension: ECC-protected KV caches.

At decode time the KV cache is the largest HBM tenant (e.g. 816 GB for
deepseek-67b decode_32k) and lives across thousands of steps — exactly the
long-residency, silently-read access pattern the paper's indirect-soft-error
analysis targets for weights.  The word-level diagonal ECC store applies
unchanged to the bf16 cache pytree, and the paged pool (DESIGN.md §16)
carries the same protection as one block-aligned arena: page lifecycle,
scrub-repairs-decode and pool-vs-dedicated-cache bit-exactness under the
TMR disciplines are covered here."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.reliability import ReliableStore
from repro.faults import TransientBitFlips, inject_bit_flips
from repro.launch import (BatchSpec, ContinuousBatcher, GenerationEngine,
                          PagedKVPool, Request)
from repro.models import params as P
from repro.models import transformer as T
from repro.models.steps import make_decode_step, make_prefill_step
from repro.reliability.scheme import parse_scheme


def test_scrubbed_cache_decodes_identically():
    cfg = get_config("qwen2.5-14b").smoke().replace(
        d_model=64, d_ff=128, vocab=128, n_layers=2, compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    prefill = jax.jit(make_prefill_step(cfg, cache_len=24))
    decode = jax.jit(make_decode_step(cfg))

    tok, _, cache = prefill(params, batch)
    kv = {"k": cache["k"], "v": cache["v"]}
    store = ReliableStore.protect(kv)

    # silent corruption of the resident cache between decode steps
    bad_kv = inject_bit_flips(kv, jax.random.fold_in(key, 1), 2e-5)
    fixed, rep = ReliableStore(bad_kv, store.parity).scrub()
    if int(rep.uncorrectable):
        pytest.skip("double-flip in one block for this seed")
    for name in ("k", "v"):
        assert np.array_equal(np.asarray(fixed.params[name], np.float32),
                              np.asarray(kv[name], np.float32))

    clean_cache = dict(cache)
    scrub_cache = dict(cache, k=fixed.params["k"], v=fixed.params["v"])
    corrupt_cache = dict(cache, k=bad_kv["k"], v=bad_kv["v"])
    _, l_clean, _ = decode(params, tok, clean_cache)
    _, l_scrub, _ = decode(params, tok, scrub_cache)
    _, l_bad, _ = decode(params, tok, corrupt_cache)
    assert np.array_equal(np.asarray(l_clean), np.asarray(l_scrub))
    # the corrupted cache generally changes the logits (SDC would propagate)
    assert np.asarray(l_bad).shape == np.asarray(l_clean).shape


def test_cache_parity_overhead_is_small():
    cfg = get_config("qwen2.5-14b").smoke()
    key = jax.random.PRNGKey(1)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    tok, _, cache = jax.jit(make_prefill_step(cfg, cache_len=16))(params, batch)
    kv = {"k": cache["k"], "v": cache["v"]}
    store = ReliableStore.protect(kv)
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(kv))
    par_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(store.parity))
    assert par_bytes / cache_bytes <= 3 / 32 + 0.02   # ~9.4%


# -- paged ECC-protected pool (DESIGN.md §16) ---------------------------------

SPEC = BatchSpec(slots=2, page_tokens=8, chunk=4, prompt_buckets=(16,),
                 gen_cap=12)


def _micro():
    return get_config("qwen2.5-14b").smoke().replace(
        d_model=64, d_ff=128, vocab=128, n_layers=2,
        compute_dtype="float32")


@pytest.fixture(scope="module")
def pool_setup():
    cfg = _micro()
    key = jax.random.PRNGKey(7)
    params = P.materialize(key, T.model_specs(cfg))
    prompt = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 16), (16,), 0, cfg.vocab))
    return cfg, key, params, prompt


def test_pool_scrub_repairs_flipped_page_decode(pool_setup):
    """A bit flipped in a live request's resident KV page is repaired by
    one fused pool scrub, and the subsequent decode matches a clean run
    bit for bit — the KV-residency analogue of the weight-scrub tests."""
    cfg, key, params, prompt = pool_setup

    def run(corrupt):
        b = ContinuousBatcher(cfg, parse_scheme("ecc"), SPEC)
        b.prepare(params, key=key)
        b.submit(Request(0, prompt, 8))
        b.admit()
        if corrupt:
            page = int(b._slots[0].pages[0])
            b.pool.corrupt_page(page, bit=13)
            counts = np.asarray(b.pool.scrub())
            assert counts.tolist() == [1, 0, 0]   # exactly the flip, fixed
        b.drain()
        return b.results[0].tokens

    np.testing.assert_array_equal(run(True), run(False))


def test_pool_fused_inject_scrub_counts(pool_setup):
    """The pool's inject_scrub is the same single fused launch the weight
    arena uses: with a zero-rate fault model it repairs a pre-planted flip
    and reports (injected=0, corrected=1, 0, 0); with a live rate the
    injected counter fires."""
    cfg, _, _, _ = pool_setup
    ecc = parse_scheme("ecc")
    pool = PagedKVPool(cfg, SPEC, copies=False, ecc=ecc)
    pool.corrupt_page(1, bit=3)
    counts = np.asarray(pool.inject_scrub(jax.random.PRNGKey(0),
                                          TransientBitFlips(0.0)))
    assert counts.tolist() == [0, 1, 0, 0]
    counts = np.asarray(pool.inject_scrub(jax.random.PRNGKey(1),
                                          TransientBitFlips(2e-3)))
    assert int(counts[0]) > 0                     # injection really fired
    # at this rate some blocks take double flips; every injected flip is
    # accounted for as corrected or attributed uncorrectable
    assert int(counts[1]) + int(counts[3]) > 0


TMR_SCHEMES = ["tmr-parallel", "tmr-serial", "ecc+tmr-semi"]


@pytest.mark.parametrize("name", TMR_SCHEMES)
def test_pool_matches_dedicated_cache_under_tmr(pool_setup, name):
    """Pool-vs-dedicated bit-exactness under the TMR disciplines: a
    request served through the paged pool produces exactly the tokens the
    whole-batch engine (dedicated contiguous cache, same fault keys and
    scrub schedule) produces — for the full gen_cap and truncated."""
    cfg, key, params, prompt = pool_setup
    scheme = parse_scheme(name)
    fault = TransientBitFlips(2e-4)
    b = ContinuousBatcher(cfg, scheme, SPEC)
    b.prepare(params, key=key, fault=fault)
    res = {r.rid: r for r in b.run([Request(0, prompt, SPEC.gen_cap),
                                    Request(1, prompt, 5)])}
    eng = GenerationEngine(cfg, scheme, gen=SPEC.gen_cap,
                           cache_len=SPEC.cache_tokens)
    store, _ = eng.prepare(params, key=key, fault=fault)
    ref, _ = eng.generate(store, {"tokens": prompt[None, :]})
    ref = np.asarray(ref)[0]
    np.testing.assert_array_equal(res[0].tokens, ref)
    np.testing.assert_array_equal(res[1].tokens, ref[:5])
    # pages all returned once both requests drained
    assert b.pool.free_pages == SPEC.pool_pages
