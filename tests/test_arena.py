"""Packed parameter arena: layout invariants and round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arena


def _tree(key):
    return {"w": jax.random.normal(key, (65, 7), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (129,), jnp.bfloat16),
            "i": jax.random.randint(jax.random.fold_in(key, 2), (40,), 0, 100, jnp.int32),
            "s": jax.random.normal(jax.random.fold_in(key, 3), (1,), jnp.bfloat16)}


def test_pack_unpack_roundtrip(key):
    params = _tree(key)
    buf, spec = arena.pack(params)
    assert buf.dtype == jnp.uint32 and buf.shape[0] == spec.n_words
    back = arena.unpack(buf, spec)
    for k in params:
        assert back[k].dtype == params[k].dtype
        assert np.array_equal(np.asarray(back[k], np.float32),
                              np.asarray(params[k], np.float32)), k


def test_leaves_block_aligned(key):
    _, spec = arena.pack(_tree(key))
    for l in spec.leaves:
        assert l.offset % arena.BLOCK == 0
        assert (l.n_words + l.pad_words) % arena.BLOCK == 0
    assert spec.n_words % arena.BLOCK == 0
    ends = [l.offset + l.n_words + l.pad_words for l in spec.leaves]
    assert ends == sorted(ends) and ends[-1] == spec.n_words


def test_padding_is_zero(key):
    buf, spec = arena.pack(_tree(key))
    buf = np.asarray(buf)
    for l in spec.leaves:
        pad = buf[l.offset + l.n_words:l.offset + l.n_words + l.pad_words]
        assert (pad == 0).all()


def test_leaf_of_block_attribution(key):
    buf, spec = arena.pack(_tree(key))
    for i, l in enumerate(spec.leaves):
        first = l.offset // arena.BLOCK
        assert spec.leaf_of_block(first) == i
        assert spec.leaf_of_block(first + l.n_blocks - 1) == i


def test_pack_is_jittable(key):
    params = _tree(key)
    _, spec = arena.pack(params)

    @jax.jit
    def roundtrip(p):
        buf, s = arena.pack(p)
        return arena.unpack(buf, s)

    back = roundtrip(params)
    for k in params:
        assert np.array_equal(np.asarray(back[k], np.float32),
                              np.asarray(params[k], np.float32)), k


def test_unsupported_dtype_raises():
    with pytest.raises(TypeError):
        arena.pack({"x": jnp.zeros((4,), jnp.int8)})


def test_empty_pytree_protect_scrub():
    """Regression: a 0-word arena must not crash the kernel dispatch."""
    from repro.core.reliability import ReliableStore
    store = ReliableStore.protect({})
    assert store.parity.shape == (0, 3)
    fixed, rep = store.scrub()
    assert int(rep.corrected) == 0 and int(rep.uncorrectable) == 0
    assert fixed.params == {}
