"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.multpim import multiplier_netlist
from repro.kernels.diag_parity import (encode_parity, encode_parity_ref,
                                       scrub, scrub_ref)
from repro.kernels.inject_scrub import inject_scrub, inject_scrub_ref
from repro.kernels.tmr_vote import vote, vote_ref
from repro.kernels.crossbar_nor import execute_netlist, execute_netlist_ref
from repro.kernels.netlist_exec import execute_packed, execute_packed_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref


# --- diag_parity -------------------------------------------------------------

@pytest.mark.parametrize("n_blocks", [1, 7, 256, 1000])
@pytest.mark.parametrize("slopes", [(1, 2, -1), (1, 2)])
def test_diag_parity_sweep(n_blocks, slopes):
    key = jax.random.PRNGKey(n_blocks)
    buf = jax.random.randint(key, (n_blocks * 32,), 0, 1 << 30,
                             jnp.int32).astype(jnp.uint32)
    got = encode_parity(buf, slopes=slopes)
    want = encode_parity_ref(buf, slopes=slopes)
    assert (np.asarray(got) == np.asarray(want)).all()


# --- fused scrub: bit-exact vs the jnp oracle across an injection sweep ------

def _ecc_case(n_blocks, seed):
    from repro.core.reliability import encode_words
    key = jax.random.PRNGKey(seed)
    buf = jax.random.randint(key, (n_blocks * 32,), 0, 1 << 30,
                             jnp.int32).astype(jnp.uint32)
    return buf, encode_words(buf)


def _assert_scrub_matches_oracle(buf, parity):
    got = scrub(buf, parity)
    want = scrub_ref(buf, parity)
    for g, w, name in zip(got, want, ["words", "parity", "counts"]):
        assert (np.asarray(g) == np.asarray(w)).all(), name
    return [int(c) for c in got[2]]


@pytest.mark.parametrize("n_blocks", [1, 7, 256, 300])
def test_scrub_kernel_clean(n_blocks):
    buf, par = _ecc_case(n_blocks, n_blocks)
    counts = _assert_scrub_matches_oracle(buf, par)
    assert counts == [0, 0, 0]


@pytest.mark.parametrize("block,word,bit", [(0, 0, 0), (3, 31, 31), (7, 13, 5)])
def test_scrub_kernel_single_data_flip(block, word, bit):
    buf, par = _ecc_case(8, 17)
    bad = buf.at[block * 32 + word].set(buf[block * 32 + word] ^ jnp.uint32(1 << bit))
    counts = _assert_scrub_matches_oracle(bad, par)
    assert counts == [1, 0, 0]
    fixed, _, _ = scrub(bad, par)
    assert (np.asarray(fixed) == np.asarray(buf)).all()


@pytest.mark.parametrize("family,bit", [(0, 0), (1, 9), (2, 31)])
def test_scrub_kernel_parity_word_flip(family, bit):
    buf, par = _ecc_case(8, 23)
    bad_par = par.at[2, family].set(par[2, family] ^ jnp.uint32(1 << bit))
    counts = _assert_scrub_matches_oracle(buf, bad_par)
    assert counts == [0, 1, 0]
    _, par2, _ = scrub(buf, bad_par)
    assert (np.asarray(par2) == np.asarray(par)).all()


@pytest.mark.parametrize("flips", [
    [(0, 0, 0), (0, 5, 17)],              # 2 flips, different words, same block
    [(2, 3, 4), (2, 3, 9)],               # 2 flips, same word
    [(1, 0, 0), (1, 1, 1), (1, 2, 2)],    # 3 flips, one block
])
def test_scrub_kernel_multi_flip_uncorrectable(flips):
    buf, par = _ecc_case(4, 29)
    bad = buf
    for b, w, bit in flips:
        bad = bad.at[b * 32 + w].set(bad[b * 32 + w] ^ jnp.uint32(1 << bit))
    counts = _assert_scrub_matches_oracle(bad, par)
    assert counts[2] == 1


def test_scrub_kernel_mixed_random_sweep():
    """Random mixture of clean / single-flip / multi-flip / parity-flip
    blocks stays bit-exact vs the oracle."""
    buf, par = _ecc_case(64, 31)
    rng = np.random.default_rng(0)
    bad, bad_par = buf, par
    for b in range(0, 64, 3):               # single data flips
        w, bit = rng.integers(32), rng.integers(32)
        bad = bad.at[b * 32 + w].set(bad[b * 32 + w] ^ jnp.uint32(1 << int(bit)))
    for b in range(1, 64, 7):               # double flips -> uncorrectable
        for _ in range(2):
            w, bit = rng.integers(32), rng.integers(32)
            bad = bad.at[b * 32 + w].set(bad[b * 32 + w] ^ jnp.uint32(1 << int(bit)))
    for b in range(2, 64, 11):              # parity-word flips
        f, bit = rng.integers(3), rng.integers(32)
        bad_par = bad_par.at[b, f].set(bad_par[b, f] ^ jnp.uint32(1 << int(bit)))
    _assert_scrub_matches_oracle(bad, bad_par)


# --- fused inject+scrub: bit-exact vs the jnp oracle under 0/1/2+ flips ------

def _assert_inject_scrub_matches_oracle(buf, parity, mask):
    got = inject_scrub(buf, parity, mask)
    want = inject_scrub_ref(buf, parity, mask)
    for g, w, name in zip(got, want, ["words", "parity", "counts"]):
        assert (np.asarray(g) == np.asarray(w)).all(), name
    return [int(c) for c in got[2]]


@pytest.mark.parametrize("n_blocks", [1, 7, 256, 300])
def test_inject_scrub_zero_mask_is_scrub(n_blocks):
    """Zero injection: the fused kernel degenerates to the plain scrub."""
    buf, par = _ecc_case(n_blocks, n_blocks + 1)
    mask = jnp.zeros_like(buf)
    counts = _assert_inject_scrub_matches_oracle(buf, par, mask)
    assert counts == [0, 0, 0, 0]
    fixed, par2, _ = inject_scrub(buf, par, mask)
    s_fixed, s_par2, _ = scrub(buf, par)
    assert (np.asarray(fixed) == np.asarray(s_fixed)).all()
    assert (np.asarray(par2) == np.asarray(s_par2)).all()


@pytest.mark.parametrize("block,word,bit", [(0, 0, 0), (3, 31, 31), (7, 13, 5)])
def test_inject_scrub_single_flip_corrected(block, word, bit):
    buf, par = _ecc_case(8, 41)
    mask = jnp.zeros_like(buf).at[block * 32 + word].set(jnp.uint32(1 << bit))
    counts = _assert_inject_scrub_matches_oracle(buf, par, mask)
    assert counts == [1, 1, 0, 0]
    fixed, _, _ = inject_scrub(buf, par, mask)
    assert (np.asarray(fixed) == np.asarray(buf)).all()   # healed in-launch


@pytest.mark.parametrize("flips", [
    [(0, 0, 0), (0, 5, 17)],              # 2 flips, different words, same block
    [(2, 3, 4), (2, 3, 9)],               # 2 flips, same word
    [(1, 0, 0), (1, 1, 1), (1, 2, 2)],    # 3 flips, one block
])
def test_inject_scrub_multi_flip_uncorrectable(flips):
    buf, par = _ecc_case(4, 43)
    mask = jnp.zeros_like(buf)
    for b, w, bit in flips:
        mask = mask.at[b * 32 + w].set(mask[b * 32 + w] | jnp.uint32(1 << bit))
    counts = _assert_inject_scrub_matches_oracle(buf, par, mask)
    assert counts == [len(flips), 0, 0, 1]


def test_inject_scrub_random_fault_model_sweep():
    """Random TransientBitFlips masks across a rate sweep stay bit-exact,
    and injected counts equal the mask popcount."""
    from repro.faults import TransientBitFlips
    buf, par = _ecc_case(64, 47)
    for i, p in enumerate([1e-4, 1e-3, 1e-2]):
        key = jax.random.PRNGKey(100 + i)
        mask = TransientBitFlips(p).word_mask(key, buf)
        counts = _assert_inject_scrub_matches_oracle(buf, par, mask)
        n_inj = sum(bin(int(x)).count("1") for x in np.asarray(mask))
        assert counts[0] == n_inj
        assert counts[1] + counts[3] <= 64    # <= one event class per block


# --- tmr_vote ----------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5,), (33, 7), (4, 3, 17), (128, 512),
                                   (300, 512),      # >256 rows, not a 256-multiple
                                   (257, 512),      # 256 + 1 rows
                                   (769, 640),      # odd row count, odd lanes
                                   (50257,)])       # vocab-sized odd leaf
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_tmr_vote_sweep(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 1000)
    if dtype == jnp.int32:
        a = jax.random.randint(key, shape, -1000, 1000, jnp.int32)
    else:
        a = jax.random.normal(key, shape, dtype)
    from repro.faults import inject_bit_flips
    bad = inject_bit_flips(a, jax.random.fold_in(key, 1), 0.02)
    got = vote(a, bad, a)
    want = vote_ref(a, bad, a)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(got) == np.asarray(a)).all()


# --- crossbar_nor (netlist interpreter) ---------------------------------------

@pytest.mark.parametrize("nb,trials", [(4, 3), (4, 32), (8, 70), (8, 130)])
def test_netlist_interpreter_sweep(nb, trials):
    nl = multiplier_netlist(nb)
    rng = np.random.default_rng(trials)
    inputs = jnp.array(rng.integers(0, 2, (trials, len(nl.inputs))).astype(bool))
    got = execute_netlist(nl, inputs)
    want = execute_netlist_ref(nl, inputs)
    assert (np.asarray(got) == np.asarray(want)).all()


# --- netlist_exec (levelized executor) ----------------------------------------

@pytest.mark.parametrize("nb,trials,tile_tw", [
    (4, 3, 8),          # single partial lane word
    (4, 64, 1),         # one word per tile, multi-tile grid
    (8, 70, 8),         # padded lanes, single tile
    (8, 300, 4),        # padded lanes AND padded tile, multi-tile grid
])
def test_netlist_exec_sweep(nb, trials, tile_tw):
    """Levelized kernel vs its jnp oracle across tilings, clean and under
    iid + single-fault injection (shared schedule-ordered masks)."""
    nl = multiplier_netlist(nb)
    rng = np.random.default_rng(trials)
    inputs = jnp.array(rng.integers(0, 2, (trials, len(nl.inputs))).astype(bool))
    key = jax.random.PRNGKey(nb)
    fg = jnp.array(rng.integers(-1, nl.n_gates, trials).astype(np.int32))
    for kw in (dict(), dict(key=key, p_gate=0.05),
               dict(key=key, p_gate=0.05, fault_gate=fg)):
        got = execute_packed(nl, inputs, tile_tw=tile_tw, **kw)
        want = execute_packed_ref(nl, inputs, **kw)
        assert (np.asarray(got) == np.asarray(want)).all(), kw


# --- flash_attention -----------------------------------------------------------

FLASH_CASES = [
    dict(B=2, H=4, KV=2, S=128, hd=64, causal=True, window=0, bq=32, bk=32),
    dict(B=1, H=8, KV=1, S=64, hd=32, causal=True, window=0, bq=16, bk=16),
    dict(B=2, H=4, KV=4, S=64, hd=16, causal=False, window=0, bq=32, bk=32),
    dict(B=1, H=2, KV=1, S=128, hd=32, causal=True, window=48, bq=32, bk=32),
]


@pytest.mark.parametrize("c", FLASH_CASES,
                         ids=lambda c: f"S{c['S']}kv{c['KV']}w{c['window']}")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(c, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    qh = jax.random.normal(ks[0], (c["B"], c["H"], c["S"], c["hd"]), dtype)
    kh = jax.random.normal(ks[1], (c["B"], c["KV"], c["S"], c["hd"]), dtype)
    vh = jax.random.normal(ks[2], (c["B"], c["KV"], c["S"], c["hd"]), dtype)
    got = flash_attention(qh.transpose(0, 2, 1, 3), kh.transpose(0, 2, 1, 3),
                          vh.transpose(0, 2, 1, 3), causal=c["causal"],
                          window=c["window"], q_block=c["bq"], kv_block=c["bk"])
    want = flash_attention_ref(qh, kh, vh, causal=c["causal"],
                               window=c["window"]).transpose(0, 2, 1, 3)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
