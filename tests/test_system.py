"""End-to-end behaviour tests: the paper's reliability mechanisms composed
with the full training/serving system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core.reliability import ReliableStore
from repro.faults import inject_bit_flips
from repro.core.tmr import vote_array
from repro.data.synthetic import SyntheticLM
from repro.models import params as P
from repro.models import transformer as T
from repro.models.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.optim import AdamWConfig
from repro.runtime import LoopConfig, TrainLoop


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("qwen2.5-14b").smoke().replace(
        d_model=64, d_ff=128, vocab=128, n_layers=2, compute_dtype="float32")
    params = P.materialize(jax.random.PRNGKey(0), T.model_specs(cfg))
    return cfg, params


def test_train_loop_with_ecc_and_restart(tmp_path, small_lm):
    """Full composition: train -> scrub -> checkpoint -> preempt -> resume."""
    cfg, params = small_lm
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch_per_rank=4, seed=0)
    ts = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=20)))
    ck = Checkpointer(str(tmp_path), async_save=False)
    loop = TrainLoop(ts, init_train_state(params),
                     lambda s: {"tokens": jnp.asarray(data.batch_at(s))},
                     LoopConfig(total_steps=16, checkpoint_every=4,
                                scrub_every=4, log_every=0,
                                inject_p_bit=1e-6),
                     ckpt=ck, log=lambda *_: None)
    loop.attach_scheme()
    with pytest.raises(RuntimeError):
        loop.run(fail_at=10)
    loop2 = TrainLoop(ts, init_train_state(params),
                      lambda s: {"tokens": jnp.asarray(data.batch_at(s))},
                      LoopConfig(total_steps=16, checkpoint_every=4,
                                 scrub_every=4, log_every=0),
                      ckpt=ck, log=lambda *_: None)
    assert loop2.restore() and loop2.step == 8
    out = loop2.run()
    assert out["final_step"] == 16
    assert np.isfinite(np.asarray(jax.tree.leaves(loop2.state["params"])[0])).all()


def test_tmr_serving_corrects_corrupted_copy(small_lm):
    """Paper §V at system level: one corrupted model copy, per-bit voted
    generation equals the clean generation."""
    cfg, params = small_lm
    key = jax.random.PRNGKey(3)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    prefill = jax.jit(make_prefill_step(cfg, cache_len=24))
    decode = jax.jit(make_decode_step(cfg))

    def generate(p):
        tok, _, cache = prefill(p, batch)
        toks = [tok]
        for _ in range(7):
            tok, _, cache = decode(p, tok, cache)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    clean = generate(params)
    corrupted = generate(inject_bit_flips(params, key, 1e-4))
    voted = vote_array(generate(params), corrupted, generate(params))
    assert (voted == clean).all()


def test_ecc_protects_weights_over_time(small_lm):
    """Paper Fig. 5 at system level: repeated access corruption, scrubbed
    each 'batch', leaves weights intact; without ECC they drift."""
    cfg, params = small_lm
    key = jax.random.PRNGKey(4)
    store = ReliableStore.protect(params)
    protected = params
    unprotected = params
    uncorrectable = 0
    for t in range(8):
        k = jax.random.fold_in(key, t)
        protected = inject_bit_flips(protected, k, 2e-7)
        unprotected = inject_bit_flips(unprotected, k, 2e-7)
        fixed, rep = ReliableStore(protected, store.parity).scrub()
        protected = fixed.params
        store = fixed
        uncorrectable += int(rep.uncorrectable)

    def diff(a, b):
        return sum(int((np.asarray(x) != np.asarray(y)).sum())
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    if uncorrectable == 0:
        assert diff(protected, params) == 0
    assert diff(unprotected, params) >= diff(protected, params)
