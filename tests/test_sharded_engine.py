"""Sharded generation engine (DESIGN.md §14): bit-exactness against the
single-device engine on forced-host-device meshes.

The native tests need >= 4 devices and skip otherwise; on single-device
hosts the slow wrapper test re-invokes this file in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (jax locks the
device count at first init, so the flag cannot be set in-process).  The CI
sharded smoke job sets the flag and runs the native tests directly.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.faults import TransientBitFlips
from repro.launch.engine import GenerationEngine, fetch_telemetry
from repro.launch.mesh import fold_copy_axis, make_test_mesh
from repro.models import params as P
from repro.models import transformer as T
from repro.reliability.scheme import parse_scheme, standard_grid

MULTI = jax.device_count() >= 4
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

#: >= 3 shapes per the acceptance bar: pure DP, DP x TP, and the
#: data%3==0 shape where concurrent TMR folds its copy axis
MESHES = [(2, 1), (2, 2), (3, 1)]
P_BIT = 2e-3   # dense enough that ECC/vote counters are nonzero
B, PROMPT, GEN = 2, 4, 3


def _cfg():
    # micro config with every shardable dim divisible by the test meshes
    return get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32, vocab=512)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)}
    return cfg, key, params, batch


@pytest.fixture(scope="module")
def references(setup):
    """Single-device tokens + telemetry per scheme, under the same fault
    keys every sharded run replays."""
    cfg, key, params, batch = setup
    fault = TransientBitFlips(P_BIT)
    refs = {}
    for scheme in standard_grid():
        eng = GenerationEngine(cfg, scheme, gen=GEN)
        store, prep = eng.prepare(params, key=key, fault=fault)
        toks, tel = eng.generate(store, batch)
        refs[scheme.name] = (np.asarray(toks),
                            fetch_telemetry({**prep, **tel}))
    return refs


@needs_devices
@pytest.mark.parametrize("mesh_shape", MESHES,
                         ids=lambda s: f"{s[0]}x{s[1]}")
@pytest.mark.parametrize("scheme", standard_grid(), ids=lambda s: s.name)
def test_sharded_bit_exact(setup, references, scheme, mesh_shape):
    """Identical tokens AND identical scrub/vote counters vs single-device
    for every standard_grid scheme on every test mesh."""
    cfg, key, params, batch = setup
    eng = GenerationEngine(cfg, scheme, gen=GEN,
                           mesh=make_test_mesh(*mesh_shape))
    store, prep = eng.prepare(params, key=key,
                              fault=TransientBitFlips(P_BIT))
    toks, tel = eng.generate(store, batch)
    got = fetch_telemetry({**prep, **tel})
    ref_toks, ref_tel = references[scheme.name]
    np.testing.assert_array_equal(np.asarray(toks), ref_toks)
    assert set(got) == set(ref_tel)
    for k in ref_tel:
        np.testing.assert_array_equal(got[k], ref_tel[k], err_msg=k)


@needs_devices
def test_fault_counters_nonzero(references):
    """The bit-exactness assertions must compare *live* counters — a fault
    rate that never fires would vacuously pass."""
    assert int(references["ecc"][1]["ecc_corrected"]) > 0
    assert int(references["ecc+tmr-serial"][1]["ecc_corrected"]) > 0


@needs_devices
def test_fold_copy_axis_and_exec_mesh():
    base = make_test_mesh(3, 1)
    folded = fold_copy_axis(base)
    assert folded.axis_names == ("copy", "data", "model")
    assert folded.shape["copy"] == 3 and folded.shape["data"] == 1
    # idempotent on an already-folded mesh
    assert fold_copy_axis(folded) is folded
    cfg = _cfg()
    par = GenerationEngine(cfg, parse_scheme("tmr-parallel"), gen=2,
                           mesh=base)
    assert "copy" in par.exec_mesh.axis_names
    # serial runs one copy at a time — nothing to fold
    ser = GenerationEngine(cfg, parse_scheme("tmr-serial"), gen=2,
                           mesh=base)
    assert "copy" not in ser.exec_mesh.axis_names
    # 2x2: data=2 cannot host 3 copies -> unfolded
    par22 = GenerationEngine(cfg, parse_scheme("tmr-parallel"), gen=2,
                             mesh=make_test_mesh(2, 2))
    assert par22.exec_mesh.axis_names == ("data", "model")


@needs_devices
def test_protected_device_put_roundtrip(setup):
    """Protected stores round-trip through jax.device_put with the
    scheme-aware sharded PartitionSpecs: same bits, same scrub reports."""
    cfg, key, params, _ = setup
    from repro.models.params import partition_specs
    mesh = make_test_mesh(2, 2)
    pspecs = partition_specs(T.model_specs(cfg), mesh)
    fault = TransientBitFlips(P_BIT)
    for scheme in standard_grid():
        dirty = scheme.corrupt_store(scheme.protect(params), fault, key)
        placed = jax.device_put(dirty, scheme.shardings(params, pspecs,
                                                        mesh))
        for a, b in zip(jax.tree.leaves(dirty), jax.tree.leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        out0, rep0 = scheme.scrub(dirty)
        out1, rep1 = scheme.scrub(placed, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(rep0.corrected),
                                      np.asarray(rep1.corrected))
        np.testing.assert_array_equal(np.asarray(rep0.uncorrectable),
                                      np.asarray(rep1.uncorrectable))
        for a, b in zip(jax.tree.leaves(scheme.read(out0)),
                        jax.tree.leaves(scheme.read(out1))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_devices
def test_canonical_parts_mixed_shardings():
    """jax 0.4.x concatenates eager arrays with MIXED shardings wrong on
    multi-device (an unreduced cross-replica sum doubles every value);
    `arena.canonical_parts` is the guard `pack`/`scrub_copies` rely on."""
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.core.arena import canonical_parts
    mesh = make_test_mesh(2, 2)
    a = jnp.arange(8, dtype=jnp.uint32)
    b = jnp.arange(100, 108, dtype=jnp.uint32)
    aa = jax.device_put(a, NamedSharding(mesh, PartitionSpec("data")))
    bb = jax.device_put(b, NamedSharding(mesh, PartitionSpec(None)))
    got = jnp.concatenate(canonical_parts([aa, bb]))
    np.testing.assert_array_equal(
        np.asarray(got), np.concatenate([np.arange(8, dtype=np.uint32),
                                         np.arange(100, 108,
                                                   dtype=np.uint32)]))


@needs_devices
def test_sharded_scrub_ops_match():
    """scrub_sharded / inject_scrub_sharded == their single-launch ops —
    fixed words, parity and counts — including a block count that does NOT
    divide the shard count (zero-padding path)."""
    from repro.kernels.diag_parity import encode_parity, scrub, scrub_sharded
    from repro.kernels.inject_scrub import (inject_scrub,
                                            inject_scrub_sharded)
    mesh = make_test_mesh(2, 2)
    key = jax.random.PRNGKey(3)
    nb = 37   # not a multiple of the 4-way shard count
    buf = jax.random.bits(key, (nb * 32,), dtype=jnp.uint32)
    parity = encode_parity(buf)
    bits = jax.random.bernoulli(jax.random.fold_in(key, 1), 5e-4,
                                (nb * 32, 32))
    mask = (bits.astype(jnp.uint32)
            << jnp.arange(32, dtype=jnp.uint32)).sum(axis=1,
                                                     dtype=jnp.uint32)
    corrupted = buf ^ mask

    f0, p0, c0 = scrub(corrupted, parity)
    f1, p1, c1 = scrub_sharded(corrupted, parity, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    assert int(c0[0]) > 0   # live counters, not vacuous zeros

    g0, q0, d0 = inject_scrub(buf, parity, mask)
    g1, q1, d1 = inject_scrub_sharded(buf, parity, mask, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    assert int(d0[0]) > 0


@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="already running with >= 4 devices")
def test_sharded_suite_subprocess():
    """Single-device hosts: run this file's native tests in a subprocess
    with 4 forced host devices, so tier-1 covers the sharded engine
    everywhere (the CI sharded job runs them natively)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=src)
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
