"""The pay-as-you-fault scrub controller (DESIGN.md §18): the hysteresis
law (halve on storms/uncorrectables, double only after a patience streak
of quiet scrubs), the drift-detector veto on relaxation, prior seeding
from the closed-form fault model and from recorded trajectories, strict
replay determinism, and the serving/training integrations — a batcher
under fault storms converges its interval DOWN, a quiet one backs off
UP, and a forced-schedule replay of an adaptive run's realized scrub
ticks reproduces its tokens bit for bit."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.faults import TransientBitFlips
from repro.launch import BatchSpec, ContinuousBatcher, Request
from repro.models import params as P
from repro.models import transformer as T
from repro.obs import DriftDetector
from repro.reliability import parse_scheme
from repro.runtime import (AdaptiveScrub, AdaptiveScrubConfig, LoopConfig,
                           TrainLoop)

CFG = AdaptiveScrubConfig(interval0=8, min_interval=1, max_interval=64,
                          low_events=0.5, high_events=4.0, patience=2)


# -- the law ------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError):
        AdaptiveScrubConfig(interval0=4, min_interval=8)
    with pytest.raises(ValueError):
        AdaptiveScrubConfig(interval0=8, max_interval=4)
    with pytest.raises(ValueError):
        AdaptiveScrubConfig(low_events=5.0, high_events=1.0)
    with pytest.raises(ValueError):
        AdaptiveScrubConfig(patience=0)


def test_storm_halves_immediately_and_clamps():
    ctl = AdaptiveScrub(CFG)
    assert ctl.due(8) and not ctl.due(7)
    for i, want in zip(range(5), (4, 2, 1, 1, 1)):   # clamped at floor
        ctl.record(10 * i, corrected=10)             # events=10 > high=4
        assert ctl.interval == want
    assert ctl.next_due == 40 + 1


def test_any_uncorrectable_slams_regardless_of_band():
    ctl = AdaptiveScrub(CFG)
    ctl.record(0, corrected=0, uncorrectable=1)      # events=2, mid-band
    assert ctl.interval == 4                         # ...but still halves


def test_quiet_streak_doubles_after_patience_and_clamps():
    ctl = AdaptiveScrub(CFG)
    intervals = [ctl.record(i, corrected=0) for i in range(12)]
    # patience=2: holds, doubles, holds, doubles ... then rails at 64
    assert intervals == [8, 16, 16, 32, 32, 64, 64, 64, 64, 64, 64, 64]


def test_hysteresis_mid_band_resets_quiet_streak():
    ctl = AdaptiveScrub(CFG)
    ctl.record(0, corrected=0)                       # quiet 1/2
    ctl.record(8, corrected=2)                       # mid-band: reset
    ctl.record(16, corrected=0)                      # quiet 1/2 again
    assert ctl.interval == 8                         # never lengthened
    ctl.record(24, corrected=0)                      # quiet 2/2
    assert ctl.interval == 16


def test_parity_fixed_never_moves_the_interval():
    ctl = AdaptiveScrub(CFG)
    ctl.record(0, corrected=0, parity_fixed=100)
    ctl.record(8, corrected=0, parity_fixed=100)
    assert ctl.interval == 16      # counted as quiet despite parity heals


def test_replay_determinism():
    """Same (index, counts) stream -> bit-identical schedule and history;
    `due` is pure."""
    stream = [(0, 3, 0), (8, 0, 0), (16, 0, 0), (32, 9, 1), (34, 0, 0)]
    a, b = AdaptiveScrub(CFG), AdaptiveScrub(CFG)
    for idx, c, u in stream:
        assert a.due(idx) == b.due(idx) == a.due(idx)
        a.record(idx, c, u)
        b.record(idx, c, u)
    assert a.history == b.history and a.next_due == b.next_due
    assert a.summary() == b.summary()


# -- priors -------------------------------------------------------------------

def test_from_prior_sizes_interval_to_target_events():
    # hot prior -> short interval; cold prior -> long; zero -> default
    hot = AdaptiveScrub.from_prior(1e-3, 1024, max_interval=1024)
    cold = AdaptiveScrub.from_prior(1e-7, 64, max_interval=1024)
    assert hot.interval < cold.interval
    assert cold.interval <= 1024 and hot.interval >= 1
    assert AdaptiveScrub.from_prior(0.0, 1024).interval == \
        AdaptiveScrubConfig().interval0


def test_from_trajectory_prior():
    from repro.core.analytics import ScrubTrajectory
    traj = ScrubTrajectory(n_blocks=64)
    for step in range(0, 40, 4):
        traj.add(step, 8, 0, 0)                      # 2 events/step
    ctl = AdaptiveScrub.from_trajectory(traj, target_events=2.0)
    assert ctl.interval == 1                         # hot history
    quiet = ScrubTrajectory(n_blocks=64)
    for step in range(0, 4000, 400):
        quiet.add(step, 1, 0, 0)
    assert AdaptiveScrub.from_trajectory(quiet).interval > 100


# -- drift-detector gate ------------------------------------------------------

def test_hot_detector_vetoes_relaxation():
    det = DriftDetector(1e-7, 4)                     # expects ~nothing
    ctl = AdaptiveScrub(CFG, detector=det, feed_detector=True)
    # sustained unexplained corrections: detector runs hot with evidence
    for i in range(10):
        ctl.record(i * 8, corrected=1)               # 1 < high, >= low
    assert det.status().hot
    # a lucky quiet streak must NOT lengthen while the verdict is hot
    iv = ctl.interval
    for i in range(10, 16):
        ctl.record(i * 8, corrected=0)
    assert ctl.interval == iv
    # detector cools off (on-model silence drains the window), veto lifts
    for i in range(16, 80):
        ctl.record(i * 8, corrected=0)
    assert ctl.interval > iv


def test_feed_detector_false_never_ingests():
    det = DriftDetector(1e-3, 10)
    ctl = AdaptiveScrub(CFG, detector=det, feed_detector=False)
    for i in range(6):
        ctl.record(i * 8, corrected=50)
    assert det.status().n_scrubs == 0                # untouched


def test_drift_evidence_floor_boundary():
    """The `confident` accessor at the exact floor: evidence() counts
    max(observed, expected) per scrub, and the verdict unlocks on the
    scrub that reaches min_events — not one earlier."""
    det = DriftDetector(1e-7, 4, min_events=5.0)
    assert det.evidence() == 0.0 and not det.confident
    for _ in range(4):
        det.observe(1)
    assert det.evidence() == pytest.approx(4.0) and not det.confident
    assert not det.status().hot                      # floor not reached
    det.observe(1)
    assert det.evidence() == pytest.approx(5.0) and det.confident
    assert det.status().hot                          # ...and now it is


# -- serving integration ------------------------------------------------------

def _serving_setup():
    cfg = get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32, vocab=512)
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    spec = BatchSpec(slots=2, page_tokens=8, chunk=2, prompt_buckets=(4,),
                     gen_cap=16)
    prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, 3),
                                           (4,), 0, cfg.vocab))
    reqs = [Request(i, prompt, 14, arrival_s=0.0) for i in range(4)]
    return cfg, key, params, spec, reqs


def _batcher(cfg, key, params, spec, *, adaptive=None, scrub_every=0,
             forced=None, p_bit=0.0):
    b = ContinuousBatcher(cfg, parse_scheme("hsiao"), spec,
                          scrub_every=scrub_every, adaptive=adaptive,
                          forced_scrub_ticks=forced)
    b.prepare(params, key=key)
    if p_bit > 0:
        fault = TransientBitFlips(p_bit)
        k0 = jax.random.PRNGKey(99)

        def inject(bb):
            bb.pool.corrupt(jax.random.fold_in(k0, bb.ticks), fault)
        b.on_tick = inject
    return b


def test_batcher_interval_backs_off_when_quiet():
    cfg, key, params, spec, reqs = _serving_setup()
    ctl = AdaptiveScrub(AdaptiveScrubConfig(
        interval0=1, max_interval=64, patience=1))
    b = _batcher(cfg, key, params, spec, adaptive=ctl)
    b.run(reqs)
    assert ctl.interval > 1 and len(b.scrub_ticks) >= 2
    # scrub cadence actually sparsified: gaps grow along the run
    gaps = np.diff(b.scrub_ticks)
    assert len(gaps) == 0 or gaps[-1] >= gaps[0]


def test_batcher_interval_slams_under_fault_storm():
    cfg, key, params, spec, reqs = _serving_setup()
    ctl = AdaptiveScrub(AdaptiveScrubConfig(
        interval0=8, min_interval=1, max_interval=64, patience=1))
    b = _batcher(cfg, key, params, spec, adaptive=ctl, p_bit=5e-3)
    b.run(reqs)
    assert ctl.interval < 8                          # storms shortened it
    assert any(e > ctl.cfg.high_events for _, e, _ in ctl.history)


def test_forced_replay_is_bit_exact_with_adaptive_run():
    """The replay contract end to end: record an adaptive run's realized
    scrub ticks, then re-serve with that exact schedule forced and no
    controller — tokens must match bit for bit (same launches, same
    order), and the forced schedule must override everything else."""
    cfg, key, params, spec, reqs = _serving_setup()
    ctl = AdaptiveScrub(AdaptiveScrubConfig(
        interval0=1, max_interval=32, patience=1))
    ba = _batcher(cfg, key, params, spec, adaptive=ctl, p_bit=1e-3)
    res_a = {r.rid: r.tokens for r in ba.run(reqs)}
    assert ba.scrub_ticks, "adaptive run never scrubbed"

    br = _batcher(cfg, key, params, spec, forced=ba.scrub_ticks,
                  scrub_every=3, p_bit=1e-3)         # scrub_every ignored
    res_r = {r.rid: r.tokens for r in br.run(reqs)}
    assert br.scrub_ticks == ba.scrub_ticks
    for rid in res_a:
        np.testing.assert_array_equal(res_r[rid], res_a[rid])


# -- training integration -----------------------------------------------------

def test_train_loop_arms_and_drives_adaptive(tmp_path):
    from repro.checkpoint import Checkpointer

    def train_step(state, batch):
        p = state["params"]["w"] - 0.1 * batch.mean()
        return {"params": {"w": p}}, {"loss": jnp.abs(p).sum()}

    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    cfg = LoopConfig(total_steps=24, checkpoint_every=100, log_every=0,
                     scrub_every=2, scheme=parse_scheme("hsiao"),
                     inject_p_bit=1e-4, adaptive_scrub=True)
    tl = TrainLoop(train_step, {"params": {"w": jnp.ones(64)}},
                   lambda s: jnp.full((4,), float(s % 3)),
                   cfg, ckpt=ck, log=lambda *_: None)
    tl.attach_scheme()
    tl.run()
    assert tl.adaptive is not None and tl.adaptive.history
    # the controller owns cadence: scrubs landed at ITS schedule
    idxs = [i for i, _, _ in tl.adaptive.history]
    assert idxs == sorted(idxs) and len(idxs) >= 2
    # an explicit controller instance is honored as-is
    ctl = AdaptiveScrub(AdaptiveScrubConfig(interval0=4))
    cfg2 = LoopConfig(total_steps=8, checkpoint_every=100, log_every=0,
                      scheme=parse_scheme("hsiao"), adaptive_scrub=ctl)
    tl2 = TrainLoop(train_step, {"params": {"w": jnp.ones(64)}},
                    lambda s: jnp.full((4,), float(s % 3)),
                    cfg2, ckpt=Checkpointer(str(tmp_path / "b"), keep=2,
                                            async_save=False),
                    log=lambda *_: None)
    tl2.attach_scheme()
    tl2.run()
    assert tl2.adaptive is ctl
