"""Observability subsystem (DESIGN.md §15).

Covers the four pillars end to end:

* `MetricsRegistry` — fixed schema, device-side accumulation that
  round-trips under jit / vmap / shard_map (psum'd sharded counters ==
  the single-device counts), and `fetch` as the one host sync;
* `Tracer` — Chrome-trace (Perfetto-loadable) JSON validity and the
  JSONL metrics log;
* latency tails — `Histogram` / `LatencyTimeline` math on synthetic
  timestamps, and chunk-compiled generation bit-exact vs the one-launch
  scan for every scheme in `standard_grid()`;
* drift + monitor — `DriftDetector` hot/cold/evidence-floor verdicts,
  the structured `ScrubMetrics` monitor record (and the deprecated
  bare-int shim).

The transfer-guard tests are the acceptance teeth: with telemetry AND
tracing enabled, the engine's timed generation region performs exactly
ONE device->host sync (the `fetch_telemetry` call) for every scheme in
the grid.  Like test_sharded_engine.py, the shard_map test needs >= 4
devices and is re-run in a subprocess with forced host devices on
single-device hosts.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.analytics import ScrubTrajectory, expected_scrub_rates
from repro.faults import TransientBitFlips
from repro.launch.engine import GenerationEngine, fetch_telemetry
from repro.models import params as P
from repro.models import transformer as T
from repro.obs import (DEFAULT_REGISTRY, NULL_TRACER, DriftDetector,
                       Histogram, LatencyTimeline, MetricsRegistry,
                       MetricSpec, ScrubMetrics, Tracer,
                       count_host_transfers)
from repro.reliability import DiagParityEcc, parse_scheme, standard_grid
from repro.runtime.monitor import Decision, HeartbeatMonitor

MULTI = jax.device_count() >= 4
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

B, PROMPT, GEN = 2, 4, 6
P_BIT = 2e-3   # dense enough that scrub/vote counters are nonzero


def _cfg():
    return get_config("phi3-mini-3.8b").smoke().replace(
        n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32, vocab=512)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)}
    return cfg, key, params, batch


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_schema_is_closed():
    reg = DEFAULT_REGISTRY
    assert "ecc_corrected" in reg.names and "tokens_emitted" in reg.names
    with pytest.raises(KeyError, match="unknown metric"):
        reg.spec("adhoc_counter")
    with pytest.raises(KeyError, match="adhoc_counter"):
        reg.fetch({"adhoc_counter": jnp.zeros(())})
    with pytest.raises(ValueError, match="duplicate"):
        MetricsRegistry([MetricSpec("a"), MetricSpec("a")])
    with pytest.raises(ValueError, match="kind"):
        MetricSpec("a", kind="histogram")


def test_registry_accumulate_semantics():
    reg = DEFAULT_REGISTRY
    m = reg.zeros(["ecc_corrected", "tmr_step_disagreements"])
    assert m["ecc_corrected"].shape == ()
    assert m["tmr_step_disagreements"].shape == (0,)
    m = reg.accumulate(m, {"ecc_corrected": 3,
                           "tmr_step_disagreements": jnp.array([1, 2])})
    m = reg.accumulate(m, {"ecc_corrected": 4,
                           "tmr_step_disagreements": 7})
    fetched = reg.fetch(m)
    assert int(fetched["ecc_corrected"]) == 7          # counter: adds
    np.testing.assert_array_equal(fetched["tmr_step_disagreements"],
                                  [1, 2, 7])           # series: stacks


def test_registry_accumulate_under_jit_and_vmap():
    reg = DEFAULT_REGISTRY

    @jax.jit
    def run(xs):
        m = reg.zeros(["ecc_corrected", "faults_injected"])
        for x in xs:                      # unrolled device-side adds
            m = reg.accumulate(m, {"ecc_corrected": x,
                                   "faults_injected": 2 * x})
        return m

    out = reg.fetch(run(jnp.arange(5, dtype=jnp.int32)))
    assert int(out["ecc_corrected"]) == 10
    assert int(out["faults_injected"]) == 20

    per_row = jax.vmap(lambda x: reg.accumulate(
        reg.zeros(["ecc_corrected"]), {"ecc_corrected": x})["ecc_corrected"])
    xs = jnp.arange(8, dtype=jnp.int32)
    assert int(per_row(xs).sum()) == int(xs.sum())


@needs_devices
def test_registry_psum_matches_single_device():
    """Counters accumulated per shard and psum'd inside shard_map equal
    the single-device totals bit for bit (DESIGN.md §14)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    reg = DEFAULT_REGISTRY
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    xs = jnp.arange(16, dtype=jnp.int32)

    def body(x):
        m = reg.accumulate(reg.zeros(["ecc_corrected", "faults_injected"]),
                           {"ecc_corrected": x.sum(),
                            "faults_injected": (x * 2).sum()})
        return reg.psum(m, "data")

    sharded = shard_map(body, mesh=mesh,
                        in_specs=PartitionSpec("data"),
                        out_specs=PartitionSpec())(xs)
    single = reg.accumulate(reg.zeros(["ecc_corrected", "faults_injected"]),
                            {"ecc_corrected": xs.sum(),
                             "faults_injected": (xs * 2).sum()})
    got, want = reg.fetch(sharded), reg.fetch(single)
    assert int(got["ecc_corrected"]) == int(want["ecc_corrected"]) == 120
    assert int(got["faults_injected"]) == int(want["faults_injected"])


@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="already running with >= 4 devices")
def test_psum_subprocess():
    """Single-device hosts: run the psum test with 4 forced host devices
    (jax locks the device count at first init)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=src)
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         "-k", "psum_matches_single_device", os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]


def test_scrub_into_accumulates_on_device(setup):
    """scheme.scrub_into folds ScrubReports into a registry accumulator
    with device adds; repeated scrubs sum; one fetch at the end."""
    cfg, key, params, _ = setup
    scheme = DiagParityEcc()
    prot = scheme.corrupt_store(scheme.protect(params),
                                TransientBitFlips(P_BIT), key)
    names = ["ecc_corrected", "ecc_parity_fixed", "ecc_uncorrectable"]
    metrics = DEFAULT_REGISTRY.zeros(names)
    prot, metrics = scheme.scrub_into(prot, metrics)
    once = fetch_telemetry(metrics)
    assert once["ecc_corrected"] > 0          # live counters, not vacuous
    # second scrub of the now-clean store adds zero
    _, metrics = scheme.scrub_into(prot, metrics)
    twice = fetch_telemetry(metrics)
    assert int(twice["ecc_corrected"]) == int(once["ecc_corrected"])
    for v in metrics.values():
        assert isinstance(v, jax.Array)       # never left the device

    tmr = parse_scheme("tmr-parallel")
    tprot = tmr.corrupt_store(tmr.protect(params),
                              TransientBitFlips(P_BIT), key)
    tmet = DEFAULT_REGISTRY.zeros(["ecc_corrected", "ecc_parity_fixed",
                                   "ecc_uncorrectable",
                                   "tmr_final_disagreements"])
    _, tmet = tmr.scrub_into(tprot, tmet)
    tstats = fetch_telemetry(tmet)
    # voting schemes surface their vote share through the registry
    assert int(tstats["tmr_final_disagreements"]) > 0


# --------------------------------------------------------------------------
# tracer: Chrome trace + JSONL
# --------------------------------------------------------------------------

def test_chrome_trace_is_valid(tmp_path):
    tracer = Tracer(enabled=True, pid=7)
    with tracer.trace("outer", scheme="ecc"):
        with tracer.trace("inner"):
            pass
    tracer.instant("restore", step=3)
    tracer.counter("step_s", 0.25)
    tracer.metrics({"loss": jnp.float32(1.5), "step": 2}, kind="heartbeat")

    doc = tracer.chrome_trace()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert [e["name"] for e in doc["traceEvents"]] == [
        "inner", "outer", "restore", "step_s"]     # spans close inner-first
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i", "C")
        assert isinstance(ev["ts"], float) and ev["ts"] >= 0
        assert ev["pid"] == 7
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    outer = doc["traceEvents"][1]
    assert outer["args"] == {"scheme": "ecc"}
    # spans nest: inner lies within outer
    inner = doc["traceEvents"][0]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3

    path = tmp_path / "trace.json"
    tracer.write_chrome(str(path))
    with open(path) as f:
        assert json.load(f) == json.loads(json.dumps(doc))

    jl = tmp_path / "metrics.jsonl"
    tracer.write_jsonl(str(jl), extra=[{"kind": "extra", "v": 1}])
    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert lines[0]["kind"] == "heartbeat"
    assert lines[0]["loss"] == 1.5             # jnp scalar -> plain float
    assert lines[1] == {"kind": "extra", "v": 1}


def test_null_tracer_records_nothing(tmp_path):
    with NULL_TRACER.trace("span"):
        NULL_TRACER.instant("i")
        NULL_TRACER.counter("c", 1.0)
        NULL_TRACER.metrics({"x": 1})
    assert NULL_TRACER.events == [] and NULL_TRACER.records == []


# --------------------------------------------------------------------------
# latency tails
# --------------------------------------------------------------------------

def test_histogram_tails():
    h = Histogram([1.0, 2.0, 3.0])
    h.record(4.0)
    h.extend([5.0, 6.0])
    m = h.merge(Histogram([7.0]))
    assert len(m) == 7 and m.percentile(50) == 4.0
    s = m.summary()
    assert s["count"] == 7 and s["min"] == 1.0 and s["max"] == 7.0
    assert Histogram().summary() == {"count": 0}
    assert np.isnan(Histogram().percentile(99))
    # ndarray input (the LatencyTimeline.summary path) must not be
    # truth-tested
    assert len(Histogram(np.arange(3.0))) == 3


def test_latency_timeline_math():
    tl = LatencyTimeline(start=10.0,
                         marks=[(10.5, 1), (10.9, 2), (11.5, 3)])
    assert tl.ttft_s == pytest.approx(0.5)
    np.testing.assert_allclose(tl.tpot_samples(),
                               [0.2, 0.2, 0.2, 0.2, 0.2])
    assert tl.tokens() == 6 and tl.total_s() == pytest.approx(1.5)
    s = tl.summary()
    assert s["tpot_p50"] == pytest.approx(0.2)
    assert s["tokens"] == 6
    fresh = LatencyTimeline()
    with pytest.raises(RuntimeError, match="begin"):
        fresh.mark(1)
    assert np.isnan(fresh.ttft_s)


# --------------------------------------------------------------------------
# chunked generation: bit-exact + timeline
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", standard_grid(), ids=lambda s: s.name)
def test_chunked_matches_unchunked(setup, scheme):
    """Chunk-compiled generation (including a remainder chunk) is
    bit-exact vs the one-launch scan, with a populated timeline."""
    cfg, key, params, batch = setup
    eng = GenerationEngine(cfg, scheme, gen=GEN)
    store, prep = eng.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    ref, ref_tel = eng.generate(store, batch)
    out, tel, tl = eng.generate_chunked(store, batch, chunk=4)  # 1+4+1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                  err_msg=scheme.name)
    want = fetch_telemetry({**prep, **ref_tel})
    got = fetch_telemetry({**prep, **tel})
    assert set(got) == set(want)
    for k in want:
        if k != "tmr_step_disagreements":   # chunked samples at chunk ends
            np.testing.assert_array_equal(np.asarray(got[k]).sum(),
                                          np.asarray(want[k]).sum(),
                                          err_msg=k)
    assert tl.tokens() == GEN
    assert len(tl.marks) == 3 and not np.isnan(tl.ttft_s)


def test_chunked_matches_vote_every(setup):
    """The in-scan vote schedule survives chunking at ANY chunk size: the
    chunk launches thread the global step offset, so (step+1) %
    vote_every fires at the same steps as the unchunked scan."""
    cfg, key, params, batch = setup
    eng = GenerationEngine(cfg, parse_scheme("tmr-parallel"), gen=GEN,
                           vote_every=2, vote_cache=True)
    store, _ = eng.prepare(params, key=key, fault=TransientBitFlips(P_BIT))
    ref, ref_tel = eng.generate(store, batch)
    for chunk in (1, 3, GEN):
        out, tel, _ = eng.generate_chunked(store, batch, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=f"chunk={chunk}")
        np.testing.assert_array_equal(
            np.asarray(fetch_telemetry(tel)["tmr_step_disagreements"]),
            np.asarray(fetch_telemetry(ref_tel)["tmr_step_disagreements"]),
            err_msg=f"chunk={chunk}")


def test_chunked_gen_one_edge(setup):
    cfg, key, params, batch = setup
    eng = GenerationEngine(cfg, gen=1)
    ref, _ = eng.generate(params, batch)
    out, _, tl = eng.generate_chunked(params, batch, chunk=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert tl.tokens() == 1                     # prefill mark only


# --------------------------------------------------------------------------
# the transfer guard: single-sync telemetry invariant (acceptance)
# --------------------------------------------------------------------------

def test_transfer_guard_counts_explicit_reads():
    x = jnp.arange(4)
    with count_host_transfers() as ledger:
        jax.block_until_ready(x)            # sync point, NOT a transfer
        assert ledger.syncs == 0
        jax.device_get([x, x * 2, {"a": x}])   # one call, one sync
        assert ledger.syncs == 1
        x.tolist()
        (x + 1).item(0)
        assert ledger.syncs == 3
    assert any("jax.device_get" in s for s in ledger.sites)
    # restored outside the context
    jax.device_get(x)
    assert ledger.syncs == 3


@pytest.mark.parametrize("scheme", standard_grid(), ids=lambda s: s.name)
def test_generation_region_single_sync(setup, scheme):
    """THE invariant (ISSUE 7 acceptance): with telemetry enabled, the
    timed region — generate + block_until_ready + fetch_telemetry —
    performs exactly one device->host sync, for every grid scheme."""
    cfg, key, params, batch = setup
    eng = GenerationEngine(cfg, scheme, gen=GEN)
    store, prep = eng.prepare(params, key=key,
                              fault=TransientBitFlips(P_BIT))
    jax.block_until_ready(eng.generate(store, batch)[0])      # warmup
    store = jax.block_until_ready(store)
    with count_host_transfers() as ledger:
        out, telem = eng.generate(store, batch)
        jax.block_until_ready(out)
        stats = fetch_telemetry({**prep, **telem})
    assert ledger.syncs == 1, ledger.sites
    assert "tokens_emitted" in stats


def test_chunked_region_single_sync_with_tracing(setup):
    """Chunked generation with an ENABLED tracer and live timeline marks
    still performs exactly one sync — spans and marks are wall-clock
    reads, not device transfers."""
    cfg, key, params, batch = setup
    scheme = parse_scheme("ecc+tmr-parallel")
    eng = GenerationEngine(cfg, scheme, gen=GEN)
    store, prep = eng.prepare(params, key=key,
                              fault=TransientBitFlips(P_BIT))
    jax.block_until_ready(
        eng.generate_chunked(store, batch, chunk=2)[0])       # warmup
    store = jax.block_until_ready(store)
    tracer = Tracer(enabled=True)
    with count_host_transfers() as ledger:
        out, telem, tl = eng.generate_chunked(store, batch, chunk=2,
                                              tracer=tracer)
        stats = fetch_telemetry({**prep, **telem})
    assert ledger.syncs == 1, ledger.sites
    assert int(stats["tokens_emitted"]) == B * GEN
    assert tl.tokens() == GEN
    assert any(e["name"] == "decode_chunk" for e in tracer.events) \
        or any(e["name"] == "tmr_decode_chunk" for e in tracer.events)


# --------------------------------------------------------------------------
# drift detector
# --------------------------------------------------------------------------

def test_drift_detector_verdicts():
    det = DriftDetector(1e-3, 10)
    exp = det.expected_per_scrub
    assert exp > 0
    # on-model stream: never drifts
    for _ in range(40):
        status = det.observe(int(round(exp)))
    assert not status.drifting and 0.5 < status.ratio < 2.0

    hot = DriftDetector(1e-3, 10)
    for _ in range(4):
        status = hot.observe(int(round(exp * 10)))
    assert status.drifting and status.hot

    cold = DriftDetector(1e-3, 10)
    for _ in range(4):
        status = cold.observe(0)
    assert status.drifting and not status.hot and status.ratio == 0.0

    d = status.as_dict()
    assert d["drifting"] and not d["drift_hot"]
    assert d["drift_n_scrubs"] == 4


def test_drift_detector_evidence_floor():
    """Sparse-fault runs (expected events << 1 per scrub) never flag on
    noise: the verdict needs min_events of evidence first."""
    det = DriftDetector(1e-7, 4)     # expectation ~1e-3 events/scrub
    for _ in range(20):
        status = det.observe(0)
    assert not status.drifting
    # one unexplained burst is still below the floor...
    assert not det.observe(2).drifting
    # ...but a sustained hot stream accumulates evidence and fires
    for _ in range(10):
        status = det.observe(2)
    assert status.drifting and status.hot

    with pytest.raises(ValueError, match="p_bit"):
        DriftDetector(-1e-3, 4)


def test_drift_detector_no_prior():
    """p_bit=0 (no model): silence is fine, any corrections are
    unexplained (ratio inf) once evidence accumulates."""
    det = DriftDetector(0.0, 0)
    assert not det.observe(0).drifting
    for _ in range(8):
        status = det.observe(1)
    assert status.ratio == float("inf") and status.drifting and status.hot


def test_drift_from_trajectory_and_analytics():
    traj = ScrubTrajectory(n_blocks=10)
    exp = expected_scrub_rates(1e-3, 10)
    per_scrub = exp["corrected_per_scrub"] + 2 * exp["uncorrectable_per_scrub"]
    for step in range(12):
        traj.add(step, int(round(per_scrub)), 0, 0)
    assert traj.rate_per_scrub() == pytest.approx(round(per_scrub))
    assert traj.drift_ratio(1e-3) == pytest.approx(1.0, rel=0.15)
    assert "drift_ratio" in traj.summary(p_bit=1e-3)
    det, status = DriftDetector.from_trajectory(traj, 1e-3)
    assert status.n_scrubs == 12 and not status.drifting
    # observed corrections with no model prior -> inf
    assert traj.drift_ratio(0.0) == float("inf")


# --------------------------------------------------------------------------
# monitor: structured scrub records (bare-int triple removed)
# --------------------------------------------------------------------------

def test_monitor_structured_scrub_record():
    mon = HeartbeatMonitor()
    rec = ScrubMetrics(corrected=5, parity_fixed=1, uncorrectable=0,
                       injected=3, vote_disagreements=2)
    assert mon.record_scrub(rec) == Decision.CONTINUE
    s = mon.summary()
    assert s["bits_corrected"] == 5 and s["parity_fixed"] == 1
    assert s["vote_disagreements"] == 2 and s["faults_injected"] == 3
    assert mon.record_scrub(
        ScrubMetrics(corrected=0, uncorrectable=2)) == Decision.RESTART
    assert any("uncorrectable" in f for f in mon.flags)


def test_monitor_drift_integration():
    det = DriftDetector(1e-3, 10)
    mon = HeartbeatMonitor(drift=det)
    hot = int(round(det.expected_per_scrub * 10))
    for _ in range(4):
        mon.record_scrub(ScrubMetrics(corrected=hot))
    assert any("drift" in f and "hot" in f for f in mon.flags)
    # the flag fires once on the transition, not every scrub
    assert sum("drift" in f for f in mon.flags) == 1
    assert mon.summary()["drift"]["drift_hot"]


def test_scrub_metrics_from_fetched():
    rec = ScrubMetrics.from_fetched(
        {"ecc_corrected": jnp.int32(3), "ecc_uncorrectable": 1,
         "ecc_injected": np.int32(7),
         "tmr_step_disagreements": jnp.array([1, 0, 2]),
         "tmr_final_disagreements": jnp.int32(4)})
    assert rec.corrected == 3 and rec.uncorrectable == 1
    assert rec.injected == 7
    assert rec.vote_disagreements == 4 + 3      # final + summed series
