import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.params import Spec
from repro.optim import (AdamWConfig, adamw_update, compress_decompress,
                         init_error_state, init_opt_state, opt_spec_tree,
                         warmup_cosine)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5, total_steps=200)
    params = {"x": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        g = {"x": 2 * (params["x"] - target)}
        params, opt, m = adamw_update(cfg, g, opt, params)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=0.05)


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"x": jnp.zeros(4)}
    opt = init_opt_state(params)
    _, _, m = adamw_update(cfg, {"x": jnp.full(4, 100.0)}, opt, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(warmup_cosine(cfg, jnp.array(5))) == pytest.approx(0.5)
    assert float(warmup_cosine(cfg, jnp.array(10))) == pytest.approx(1.0, abs=0.02)
    assert float(warmup_cosine(cfg, jnp.array(100))) == pytest.approx(0.1, abs=0.01)


def test_bf16_moments_supported():
    params = {"x": jnp.ones(8)}
    opt = init_opt_state(params, dtype=jnp.bfloat16)
    p2, o2, _ = adamw_update(AdamWConfig(), {"x": jnp.ones(8)}, opt, params)
    assert o2["m"]["x"].dtype == jnp.bfloat16


# --- error-feedback compression ------------------------------------------------

@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_identity(seed):
    """Q(g+e) + e' == g + e exactly (the error carries all rounding)."""
    key = jax.random.PRNGKey(seed)
    g = {"w": 0.01 * jax.random.normal(key, (300,))}
    e = init_error_state(g)
    deq, e2 = compress_decompress(g, e)
    np.testing.assert_allclose(np.asarray(deq["w"] + e2["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-7)


def test_compression_error_stays_bounded():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (1024,))}
    e = init_error_state(g)
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.fold_in(key, i), (1024,))}
        deq, e = compress_decompress(gi, e)
    # per-tile int8: error bounded by ~max|g|/127 per element (few steps of slack)
    assert float(jnp.abs(e["w"]).max()) < 0.2


# --- ZeRO-1 spec derivation ------------------------------------------------------

def test_opt_spec_assigns_zero_axis():
    specs = {"w": Spec((512, 1024), ("model_dim", "ff")),
             "b": Spec((64,), ("ff",))}
    out = opt_spec_tree(specs)
    assert "zero" in out["w"].axes          # largest replicated dim tagged
    assert out["w"].init == "zeros"
    assert out["b"].axes == ("ff",)          # nothing replicated to tag
