"""Flash (custom-VJP) attention vs naive oracle: forward + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blocked_attention, naive_attention

CASES = [
    dict(B=2, Sq=64, Sk=64, H=4, KV=2, hd=16, causal=True, window=0),
    dict(B=1, Sq=128, Sk=128, H=8, KV=8, hd=8, causal=True, window=0),
    dict(B=2, Sq=64, Sk=64, H=4, KV=1, hd=16, causal=True, window=24),
    dict(B=2, Sq=32, Sk=32, H=4, KV=4, hd=8, causal=False, window=0),
    dict(B=1, Sq=48, Sk=48, H=2, KV=2, hd=32, causal=True, window=0),  # odd blocks
]


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"S{c['Sq']}kv{c['KV']}w{c['window']}")
def test_forward_and_grads_match_naive(case):
    c = dict(case)
    causal, window = c.pop("causal"), c.pop("window")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (c["B"], c["Sq"], c["H"], c["hd"]), jnp.float32)
    k = jax.random.normal(ks[1], (c["B"], c["Sk"], c["KV"], c["hd"]), jnp.float32)
    v = jax.random.normal(ks[2], (c["B"], c["Sk"], c["KV"], c["hd"]), jnp.float32)

    out_b = blocked_attention(q, k, v, causal=causal, window=window,
                              q_block=16, kv_block=16)
    out_n = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_n),
                               rtol=1e-5, atol=1e-5)

    f_b = lambda q, k, v: blocked_attention(q, k, v, causal=causal,
                                            window=window, q_block=16,
                                            kv_block=16).sum()
    f_n = lambda q, k, v: naive_attention(q, k, v, causal=causal,
                                          window=window).sum()
    g_b = jax.grad(f_b, argnums=(0, 1, 2))(q, k, v)
    g_n = jax.grad(f_n, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_b, g_n):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_block_sizes_do_not_change_result():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    outs = [blocked_attention(q, k, v, q_block=bq, kv_block=bk)
            for bq, bk in [(8, 8), (16, 32), (64, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_q_offset_consistency():
    """Attention over a suffix with q_offset equals the suffix of the full."""
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    full = naive_attention(q, k, v, causal=True)
    part = naive_attention(q[:, 32:], k, v, causal=True, q_offset=32)
    np.testing.assert_allclose(np.asarray(full[:, 32:]), np.asarray(part),
                               rtol=1e-5, atol=1e-5)
