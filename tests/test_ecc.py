"""Diagonal-parity ECC properties (paper §IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import ecc

CFGS = [ecc.EccConfig(m=16, slopes=(1, -1, 2)),
        ecc.EccConfig(m=15, slopes=(1, -1)),       # paper-faithful odd m
        ecc.EccConfig(m=8, slopes=(1, 2))]


def _data(seed, rows, cols):
    return jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (rows, cols))


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"m{c.m}")
def test_encode_verify_clean(cfg):
    d = _data(0, cfg.m * 3, cfg.m * 2)
    par = ecc.encode(d, cfg)
    assert bool(ecc.verify(d, par, cfg))


@given(seed=st.integers(0, 100), r=st.integers(0, 47), c=st.integers(0, 31))
@settings(max_examples=40, deadline=None)
def test_single_error_corrected(seed, r, c):
    cfg = CFGS[0]
    d = _data(seed, 48, 32)
    par = ecc.encode(d, cfg)
    bad = d.at[r, c].set(~d[r, c])
    fixed, par2, stats = ecc.correct(bad, par, cfg)
    assert (fixed == d).all()
    assert int(stats["corrected_data"]) == 1
    assert int(stats["uncorrectable"]) == 0


@given(seed=st.integers(0, 100), slope_i=st.integers(0, 2),
       bi=st.integers(0, 2), bj=st.integers(0, 1), k=st.integers(0, 15))
@settings(max_examples=25, deadline=None)
def test_parity_bit_error_corrected(seed, slope_i, bi, bj, k):
    cfg = CFGS[0]
    d = _data(seed, 48, 32)
    par = ecc.encode(d, cfg)
    s = cfg.slopes[slope_i]
    bad_par = dict(par)
    bad_par[s] = bad_par[s].at[bi, bj, k].set(~bad_par[s][bi, bj, k])
    fixed, par2, stats = ecc.correct(d, bad_par, cfg)
    assert (fixed == d).all()
    assert int(stats["corrected_parity"]) == 1
    assert all((par2[sl] == par[sl]).all() for sl in cfg.slopes)


def test_double_error_in_block_flagged_uncorrectable():
    cfg = CFGS[0]
    d = _data(3, 32, 32)
    par = ecc.encode(d, cfg)
    bad = d.at[1, 2].set(~d[1, 2]).at[5, 9].set(~d[5, 9])  # same 16x16 block
    _, _, stats = ecc.correct(bad, par, cfg)
    assert int(stats["uncorrectable"]) >= 1 or int(stats["corrected_data"]) == 0


def test_errors_in_different_blocks_all_corrected():
    cfg = CFGS[0]
    d = _data(4, 32, 32)
    par = ecc.encode(d, cfg)
    bad = d.at[1, 2].set(~d[1, 2]).at[20, 25].set(~d[20, 25])
    fixed, _, stats = ecc.correct(bad, par, cfg)
    assert (fixed == d).all()
    assert int(stats["corrected_data"]) == 2


# --- the paper's O(1) incremental-update property --------------------------

@given(seed=st.integers(0, 50), col=st.integers(0, 31))
@settings(max_examples=20, deadline=None)
def test_incremental_column_update_matches_full_encode(seed, col):
    """An in-row vectored op rewrites a column; parity updates in O(1)."""
    cfg = CFGS[0]
    d = _data(seed, 48, 32)
    par = ecc.encode(d, cfg)
    new_col = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), 0.5, (48,))
    inc = ecc.update_parity_col(par, d[:, col], new_col, col, cfg)
    full = ecc.encode(d.at[:, col].set(new_col), cfg)
    for s in cfg.slopes:
        assert (inc[s] == full[s]).all()


@given(seed=st.integers(0, 50), row=st.integers(0, 47))
@settings(max_examples=20, deadline=None)
def test_incremental_row_update_matches_full_encode(seed, row):
    """An in-column vectored op rewrites a row — the case where horizontal
    parity costs O(n) (Fig. 2a) and diagonal parity stays O(1)."""
    cfg = CFGS[0]
    d = _data(seed, 48, 32)
    par = ecc.encode(d, cfg)
    new_row = jax.random.bernoulli(jax.random.PRNGKey(seed + 2), 0.5, (32,))
    inc = ecc.update_parity_row(par, d[row, :], new_row, row, cfg)
    full = ecc.encode(d.at[row, :].set(new_row), cfg)
    for s in cfg.slopes:
        assert (inc[s] == full[s]).all()


# --- incremental updates with non-coprime slopes ---------------------------
# gcd(s, m) != 1 means a slope's diagonal visits only m/gcd groups per
# column write, so several local rows fold into the same parity group; the
# scatter-add (mod 2) in update_parity_* must still match a full re-encode.

NONCOPRIME_CFGS = [ecc.EccConfig(m=16, slopes=(1, 2, 4)),   # gcd(2,16)=2, gcd(4,16)=4
                   ecc.EccConfig(m=8, slopes=(1, 2, 6))]    # gcd(2,8)=2, gcd(6,8)=2


@pytest.mark.parametrize("cfg", NONCOPRIME_CFGS, ids=lambda c: f"m{c.m}s{c.slopes}")
@pytest.mark.parametrize("col", [0, 3, 7])
def test_incremental_column_update_noncoprime_slopes(cfg, col):
    rows, cols = cfg.m * 3, cfg.m * 2
    d = _data(11, rows, cols)
    par = ecc.encode(d, cfg)
    new_col = jax.random.bernoulli(jax.random.PRNGKey(12 + col), 0.5, (rows,))
    inc = ecc.update_parity_col(par, d[:, col], new_col, col, cfg)
    full = ecc.encode(d.at[:, col].set(new_col), cfg)
    for s in cfg.slopes:
        assert (inc[s] == full[s]).all(), f"slope {s}"


@pytest.mark.parametrize("cfg", NONCOPRIME_CFGS, ids=lambda c: f"m{c.m}s{c.slopes}")
@pytest.mark.parametrize("row", [0, 5, 11])
def test_incremental_row_update_noncoprime_slopes(cfg, row):
    rows, cols = cfg.m * 3, cfg.m * 2
    d = _data(13, rows, cols)
    par = ecc.encode(d, cfg)
    new_row = jax.random.bernoulli(jax.random.PRNGKey(14 + row), 0.5, (cols,))
    inc = ecc.update_parity_row(par, d[row, :], new_row, row, cfg)
    full = ecc.encode(d.at[row, :].set(new_row), cfg)
    for s in cfg.slopes:
        assert (inc[s] == full[s]).all(), f"slope {s}"


def test_overhead():
    assert ecc.parity_overhead(CFGS[0]) == pytest.approx(3 / 16)
    assert ecc.parity_overhead(ecc.EccConfig(m=15, slopes=(1, -1))) == pytest.approx(2 / 15)


def test_even_m_two_slope_rejected():
    with pytest.raises(ValueError):
        ecc.EccConfig(m=16, slopes=(1, -1))
