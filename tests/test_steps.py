import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as P
from repro.models import transformer as T
from repro.models.nn import softmax_xent
from repro.models.steps import (chunked_xent, init_train_state, make_loss_fn,
                                make_train_step)
from repro.optim import AdamWConfig


def test_chunked_xent_matches_oracle(key):
    B, S, D, V = 2, 64, 16, 50
    h = jax.random.normal(key, (B, S, D))
    head = jax.random.normal(jax.random.fold_in(key, 1), (D, V))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    got = chunked_xent(h, head, labels, chunk=16)
    want = softmax_xent((h @ head), labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_xent_with_mask(key):
    B, S, D, V = 2, 32, 8, 20
    h = jax.random.normal(key, (B, S, D))
    head = jax.random.normal(jax.random.fold_in(key, 1), (D, V))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = (jnp.arange(S)[None, :] < 20).astype(jnp.float32) * jnp.ones((B, 1))
    got = chunked_xent(h, head, labels, mask=mask, chunk=8)
    want = softmax_xent((h @ head), labels, mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_microbatched_grads_match_full_batch(key):
    """Gradient accumulation must be numerically equivalent (fp32)."""
    cfg = get_config("qwen2.5-14b").smoke().replace(
        d_model=64, d_ff=128, vocab=128, n_layers=2, compute_dtype="float32")
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab)}
    s1, m1 = jax.jit(make_train_step(cfg, AdamWConfig(), microbatches=1))(
        init_train_state(params), batch)
    s2, m2 = jax.jit(make_train_step(cfg, AdamWConfig(), microbatches=4))(
        init_train_state(params), batch)
    np.testing.assert_allclose(float(m1["total"]), float(m2["total"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-6)


def test_grad_compression_path_runs(key):
    cfg = get_config("qwen2.5-14b").smoke().replace(
        d_model=32, d_ff=64, vocab=64, n_layers=1)
    params = P.materialize(key, T.model_specs(cfg))
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab)}
    ts = make_train_step(cfg, AdamWConfig(), grad_compression=True)
    state = init_train_state(params, grad_compression=True)
    state, m = jax.jit(ts)(state, batch)
    assert np.isfinite(float(m["total"]))
    assert "err" in state


def test_loss_decreases_on_learnable_data(key):
    from repro.data.synthetic import SyntheticLM
    cfg = get_config("qwen2.5-14b").smoke().replace(
        d_model=64, d_ff=128, vocab=64, n_layers=2, compute_dtype="float32")
    params = P.materialize(key, T.model_specs(cfg))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch_per_rank=8, seed=1)
    ts = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=10,
                                                  total_steps=60)))
    state = init_train_state(params)
    losses = []
    for i in range(60):
        state, m = ts(state, {"tokens": jnp.asarray(data.batch_at(i))})
        losses.append(float(m["total"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3
