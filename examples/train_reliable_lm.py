"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full production substrate — synthetic data pipeline, AdamW,
atomic checkpointing, straggler monitoring, ECC-protected weights with
periodic scrubbing under injected soft errors, and a simulated preemption
mid-run that the loop recovers from.

Default is a CPU-sized model; --full-100m builds an actual 100M-parameter
config (slower on CPU; the code path is identical).

Run: PYTHONPATH=src python examples/train_reliable_lm.py --steps 300
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.engine import GenerationEngine, make_eval_hook
from repro.models import params as P
from repro.models import transformer as T
from repro.models.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime import LoopConfig, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/reliable_lm_ckpt")
    args = ap.parse_args()

    if args.full_100m:
        # ~100M params: 12L x 768 with a 32k vocab (GPT-2-small-ish)
        cfg = get_config("qwen2.5-14b").replace(
            n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
            vocab=32000, q_block=128, kv_block=128, compute_dtype="float32")
    else:
        cfg = get_config("qwen2.5-14b").smoke().replace(compute_dtype="float32")

    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name}-derived LM: {n/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       batch_per_rank=args.batch, seed=0)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps)))
    ck = Checkpointer(args.ckpt_dir, keep=2)
    # periodic sample generation through the scan-compiled engine — one
    # jitted launch per eval instead of an interpreted decode loop
    eval_batch = {"tokens": jnp.asarray(data.batch_at(0))[:2, :32]}
    eval_hook = make_eval_hook(GenerationEngine(cfg, gen=16), eval_batch)
    loop = TrainLoop(step_fn, init_train_state(params),
                     lambda s: {"tokens": jnp.asarray(data.batch_at(s))},
                     LoopConfig(total_steps=args.steps, checkpoint_every=50,
                                scrub_every=25, log_every=25,
                                eval_every=max(args.steps // 3, 1),
                                inject_p_bit=1e-8),
                     ckpt=ck, eval_fn=eval_hook)
    loop.attach_scheme()

    # simulated preemption mid-run; the loop restores and replays
    fail_at = args.steps // 2
    t0 = time.time()
    try:
        loop.run(fail_at=fail_at)
    except RuntimeError as e:
        print(f"!! {e} — restoring from checkpoint and continuing")
        loop.restore()
        loop.run()
    dt = time.time() - t0

    first = loop.metrics_history[0][1] if loop.metrics_history else float("nan")
    last = loop.metrics_history[-1][1] if loop.metrics_history else float("nan")
    print(f"done in {dt:.1f}s: loss {first:.3f} -> {last:.3f}")
    scrubbed = sum(int(r.corrected) for _, r in loop.scrub_reports)
    print(f"reliability: {len(loop.scrub_reports)} scrubs, "
          f"{scrubbed} bit flips corrected, "
          f"{sum(int(r.uncorrectable) for _, r in loop.scrub_reports)} uncorrectable")
    if loop.eval_history:
        ev = loop.eval_history[-1]
        print(f"eval @ step {ev['step']}: sample "
              f"{jax.device_get(ev['tokens'])[0, :8].tolist()}")


if __name__ == "__main__":
    main()
