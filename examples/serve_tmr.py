"""TMR-protected batched serving (paper §V at system scale).

Serves batched requests from a small LM three ways: clean, with injected
weight corruption (silent data corruption), and with TMR voting over three
copies — showing the voted output matches the clean generation even when a
copy is corrupted.

Run: PYTHONPATH=src python examples/serve_tmr.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.faults import inject_bit_flips
from repro.models import params as P
from repro.models import transformer as T
from repro.models.steps import make_decode_step, make_prefill_step
from repro.reliability import Tmr


def main():
    cfg = get_config("phi3-mini-3.8b").smoke().replace(compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    B, PROMPT, GEN = 4, 32, 24
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)}

    prefill = jax.jit(make_prefill_step(cfg, cache_len=PROMPT + GEN))
    decode = jax.jit(make_decode_step(cfg))

    def generate(p):
        tok, _, cache = prefill(p, batch)
        toks = [tok]
        for _ in range(GEN - 1):
            tok, _, cache = decode(p, tok, cache)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    clean = generate(params)

    p_bit = 3e-5
    corrupted_params = inject_bit_flips(params, jax.random.fold_in(key, 1), p_bit)
    corrupted = generate(corrupted_params)
    n_diff = int((corrupted != clean).sum())
    print(f"SDC demo: corrupting weights at p_bit={p_bit:g} changed "
          f"{n_diff}/{clean.size} generated tokens — silently.")

    # serial TMR through the unified scheme API (DESIGN.md §12): copy 2 is
    # the corrupted replica; per-bit voting over the three generations
    scheme = Tmr("serial")
    voted = scheme.wrap(generate)(params, corrupted_params, params)
    print(f"TMR(serial, per-bit vote): voted output matches clean: "
          f"{bool((voted == clean).all())} "
          f"(cost: {scheme.overhead().describe()})")
    print("sample (clean): ", np.asarray(clean[0, :12]).tolist())
    print("sample (corrupt):", np.asarray(corrupted[0, :12]).tolist())
    print("sample (voted):  ", np.asarray(voted[0, :12]).tolist())


if __name__ == "__main__":
    main()
