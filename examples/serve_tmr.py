"""TMR-protected batched serving (paper §V at system scale).

Serves batched requests from a small LM three ways: clean, with injected
weight corruption (silent data corruption), and with TMR voting over three
copies — showing the voted output matches the clean generation even when a
copy is corrupted.  Generation runs through the scan-compiled
`launch.engine.GenerationEngine` (DESIGN.md §13): the whole 24-token
generation is ONE jitted launch, and the TMR copies ride a vmapped copy
axis instead of three sequential runs.

Run: PYTHONPATH=src python examples/serve_tmr.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.faults import inject_bit_flips
from repro.launch.engine import GenerationEngine, fetch_telemetry
from repro.models import params as P
from repro.models import transformer as T
from repro.reliability import Tmr


def main():
    cfg = get_config("phi3-mini-3.8b").smoke().replace(compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = P.materialize(key, T.model_specs(cfg))
    B, PROMPT, GEN = 4, 32, 24
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)}

    engine = GenerationEngine(cfg, gen=GEN)            # unprotected baseline
    clean, _ = engine.generate(params, batch)

    p_bit = 3e-5
    corrupted_params = inject_bit_flips(params, jax.random.fold_in(key, 1),
                                        p_bit)
    corrupted, _ = engine.generate(corrupted_params, batch)
    n_diff = int(np.asarray(corrupted != clean).sum())
    print(f"SDC demo: corrupting weights at p_bit={p_bit:g} changed "
          f"{n_diff}/{clean.size} generated tokens — silently.")

    # parallel TMR through the engine (DESIGN.md §13): copy 1 is the
    # corrupted replica; the three copies are stacked on a leading copy
    # axis and the generation is vmapped over it, with per-bit voting of
    # the generated token ids
    scheme = Tmr("parallel")
    tmr_engine = GenerationEngine(cfg, scheme, gen=GEN)
    store = jax.tree.map(lambda a, b, c: jax.numpy.stack([a, b, c]),
                         params, corrupted_params, params)
    voted, telem = tmr_engine.generate(store, batch)
    stats = fetch_telemetry(telem)                     # single host fetch
    print(f"TMR(parallel, per-bit vote): voted output matches clean: "
          f"{bool(np.asarray(voted == clean).all())} "
          f"(cost: {scheme.overhead().describe()}; copies disagreed on "
          f"{int(stats['tmr_final_disagreements'])} token positions)")
    print("sample (clean): ", np.asarray(clean[0, :12]).tolist())
    print("sample (corrupt):", np.asarray(corrupted[0, :12]).tolist())
    print("sample (voted):  ", np.asarray(voted[0, :12]).tolist())


if __name__ == "__main__":
    main()
