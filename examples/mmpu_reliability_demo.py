"""Mini reproduction of the paper's case study (Figs. 4-5) at 8-bit scale —
runs in ~a minute on CPU and prints the three headline effects:

  (a) multiplication failure vs p_gate, baseline vs TMR (Monte-Carlo);
  (b) logical masking measured by exhaustive single-fault injection;
  (c) weight degradation with/without diagonal-ECC scrubbing.

Run: PYTHONPATH=src python examples/mmpu_reliability_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics as A
from repro.core import multpim

NB, TRIALS = 8, 1024


def main():
    nl = multpim.multiplier_netlist(NB)
    rng = np.random.default_rng(0)
    a = jnp.array(rng.integers(0, 2**NB, TRIALS).astype(np.uint32))
    b = jnp.array(rng.integers(0, 2**NB, TRIALS).astype(np.uint32))
    want = multpim.true_product_bits(np.asarray(a), np.asarray(b), NB)

    # (b) masking
    af = jnp.array(rng.integers(0, 2**NB, nl.n_gates).astype(np.uint32))
    bf = jnp.array(rng.integers(0, 2**NB, nl.n_gates).astype(np.uint32))
    single = multpim.multiply_bits(af, bf, NB,
                                   fault_gate=jnp.arange(nl.n_gates, dtype=jnp.int32))
    wantf = multpim.true_product_bits(np.asarray(af), np.asarray(bf), NB)
    alpha = float((np.asarray(single) != wantf).any(axis=1).mean())
    print(f"(b) exhaustive single-fault injection over {nl.n_gates} gates: "
          f"{(1-alpha)*100:.1f}% of faults are logically masked (alpha={alpha:.3f})")

    # (a) p_mult vs p_gate
    print(f"(a) {NB}-bit multiplication failure ({TRIALS} trials):")
    print(f"    {'p_gate':>8s} {'baseline':>9s} {'TMR':>9s}")
    for p in (3e-4, 1e-3, 3e-3):
        base = multpim.multiply_bits(a, b, NB, key=jax.random.PRNGKey(1), p_gate=p)
        tmrb = multpim.multiply_tmr_bits(a, b, NB, jax.random.PRNGKey(2), p_gate=p)
        rb = float((np.asarray(base) != want).any(axis=1).mean())
        rt = float((np.asarray(tmrb) != want).any(axis=1).mean())
        print(f"    {p:8.0e} {rb:9.4f} {rt:9.4f}")

    # (c) weight degradation (analytic, paper constants)
    T = np.array([1e5, 1e6, 1e7])
    base = A.expected_corrupted_weights(A.weight_corruption_baseline(1e-9, T))
    ecc = A.expected_corrupted_weights(A.weight_corruption_ecc_refined(1e-9, T))
    print("(c) E[corrupted weights] of 62M @ p_input=1e-9:")
    for i, t in enumerate(T):
        print(f"    after {t:8.0e} batches: baseline {base[i]:12.1f}   "
              f"with ECC {ecc[i]:8.3f}")


if __name__ == "__main__":
    main()
