"""Quickstart: the paper's reliability mechanisms in 60 seconds.

1. Simulate a memristive crossbar computing a vectored NOR (stateful logic).
2. Protect data with diagonal-parity ECC, flip a bit, locate + correct it.
3. Protect a JAX parameter tree with the word-level ECC store, corrupt it,
   scrub it clean.
4. TMR: run a fault-prone computation three times and vote per bit.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecc
from repro.core.crossbar import Crossbar, ErrorModel
from repro.core.reliability import ReliableStore
from repro.faults import inject_bit_flips
from repro.core.tmr import tmr, vote_array

key = jax.random.PRNGKey(0)

# -- 1. stateful logic in a crossbar -----------------------------------------
xb = Crossbar.from_array(np.random.default_rng(0).integers(0, 2, (64, 64)))
xb2 = xb.row_gate("nor", in_cols=[0, 1], out_col=5)   # all 64 rows, 1 cycle
print(f"1) vectored NOR across {xb.shape[0]} rows in "
      f"{xb2.counter.cycles} crossbar cycle(s)")

# -- 2. diagonal-parity ECC ----------------------------------------------------
data = jax.random.bernoulli(key, 0.5, (64, 64))
cfg = ecc.EccConfig(m=16)
parity = ecc.encode(data, cfg)
corrupted = data.at[13, 37].set(~data[13, 37])
fixed, _, stats = ecc.correct(corrupted, parity, cfg)
print(f"2) flipped bit (13,37); ECC corrected {int(stats['corrected_data'])} "
      f"bit(s); restored == original: {bool((fixed == data).all())}")

# -- 3. ECC-protected parameters ------------------------------------------------
params = {"w": jax.random.normal(key, (256, 128), jnp.float32)}
store = ReliableStore.protect(params)
bad = inject_bit_flips(params, jax.random.fold_in(key, 1), 1e-5)
fixed_store, report = ReliableStore(bad, store.parity).scrub()
ok = np.array_equal(np.asarray(fixed_store.params["w"]), np.asarray(params["w"]))
print(f"3) injected sparse bit flips into weights; scrub corrected "
      f"{int(report.corrected)} block(s), uncorrectable "
      f"{int(report.uncorrectable)}; weights restored: {ok}")

# -- 4. TMR ------------------------------------------------------------------------
def flaky(k, x):
    flips = jax.random.bernoulli(k, 0.05, x.shape)
    return jnp.where(flips, -x, x)

x = jax.random.normal(key, (1000,))
voted = tmr(flaky, mode="serial")(key, x)
single = flaky(jax.random.fold_in(key, 2), x)
print(f"4) single-copy error rate {float((single != x).mean()):.3f} -> "
      f"TMR-voted {float((voted != x).mean()):.3f}")
